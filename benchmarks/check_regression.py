"""Bench-regression CI gate: compare the current ``bench.json`` against the
committed ``benchmarks/baseline.json`` and exit non-zero on regression.

Gated metrics, chosen for CI-runner robustness:

* **Kernel speedups** (and the geomean) are analytic cost-model ratios —
  deterministic across hosts — so they get a tight tolerance
  (``--kernel-tol``, default 10%). ``correct`` must stay True.
* **Serving tokens/s** is wall clock on a shared runner, so it gets a loose
  tolerance (``--serving-tol``, default 60%: a >2.5x slowdown fails; the
  CI workflow widens it to 0.85 because the committed baseline was
  recorded on a dev-class host). The *deterministic* serving counters —
  decode ``steps``, ``prefill_compiles`` (retrace explosions),
  ``preemptions`` (paged-pool behavior drift) — are compared exactly,
  which is where real regressions show up first.

Usage:
    python benchmarks/run.py --json --rounds 2        # writes bench.json
    python benchmarks/check_regression.py             # gate
    python benchmarks/check_regression.py --update    # refresh baseline

The baseline is refreshed *in the PR that changes the numbers* (with the
same ``--rounds`` the CI uses), so the diff shows the perf delta being
signed off.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
BENCH_JSON = os.path.join(HERE, "artifacts", "bench.json")
BASELINE = os.path.join(HERE, "baseline.json")

# serving counters that must match the baseline exactly (deterministic for
# a fixed seed; a change means the engine's behavior changed, not the
# host). ``sched_reorders`` pins scheduler-policy behavior: 0 under FCFS
# by construction, an exact reorder count for the priority_mix scenario.
# ``prefix_hit_tokens`` / ``cow_copies`` pin the radix prefix cache: an
# exact hit count for the shared_prefix mix, zero everywhere else (random
# prompts must never alias a 16-token page). The request-lifecycle
# counters pin the robustness layer: exact abort/reject/fail/recovery
# counts for the chaos_mix scenario, zero on every undisturbed row.
# ``readbacks`` pins the one-batched-host-readback-per-step property on
# every engine row, including the tensor-parallel ``device-sharded``
# twins (readbacks == steps by construction; a second readback per step
# would double it). ``accepted_per_step`` / ``draft_tokens`` pin the
# speculative-decoding verify program: the spec_mix scenario's self-draft
# drafter accepts deterministically (>1 token per step, exact float for a
# fixed seed), and every non-spec row must stay at exactly 0.
EXACT_SERVING = ("steps", "readbacks", "prefill_compiles", "preemptions",
                 "sched_reorders", "prefix_hit_tokens", "cow_copies",
                 "aborted", "rejected", "failed", "deadline_expired",
                 "recoveries", "accepted_per_step", "draft_tokens")


def _serving_key(row: dict) -> str:
    return f"{row['arch']}/{row['mix']}/{row.get('engine', 'device')}"


def extract(bench: dict) -> dict:
    """Slim the gated metrics out of a full bench.json payload."""
    out = {"kernels": {}, "geomean_speedup": round(
        bench.get("geomean_speedup", 0.0), 4), "serving": {}}
    failed = []
    for k in bench.get("kernels", []):
        if k.get("failed"):         # keep-going casualty: no metrics row
            failed.append(k["kernel"])
            continue
        out["kernels"][k["kernel"]] = {
            "speedup": round(k["speedup"], 4),
            "correct": bool(k["correct"]),
        }
    # search-infra counters are deterministic (no chaos in the CI bench
    # run): any nonzero quarantine/recovery or failed kernel means the
    # isolation layer fired when it shouldn't have
    st = bench.get("stage_totals", {})
    out["search_infra"] = {
        "quarantined": int(st.get("quarantined", 0)),
        "recoveries": int(st.get("recoveries", 0)),
        "failed_kernels": sorted(failed),
    }
    for row in bench.get("serving", []):
        # gate the device engine plus the shared_prefix no-cache,
        # chaos_mix no-chaos, and tensor-parallel sharded twins
        # (reference rows exist only under --compare and stay ungated)
        if row.get("engine", "device") not in ("device", "device-nocache",
                                               "device-nochaos",
                                               "device-nospec",
                                               "device-sharded"):
            continue
        slim = {"tok_per_s": round(row["tok_per_s"], 2)}
        for key in EXACT_SERVING:
            v = row.get(key)
            if v is not None:
                # accepted_per_step is the one float among the exact
                # counters (deterministic for a fixed seed; rounded the
                # same way on both sides of the comparison)
                slim[key] = round(v, 4) if isinstance(v, float) else int(v)
        out["serving"][_serving_key(row)] = slim
    return out


def compare(current: dict, baseline: dict, *, kernel_tol: float,
            serving_tol: float, exact: bool = True) -> list[str]:
    """Returns a list of human-readable regression messages (empty = pass)."""
    bad = []
    for name, base in baseline.get("kernels", {}).items():
        cur = current["kernels"].get(name)
        if cur is None:
            bad.append(f"kernel {name}: missing from bench.json "
                       f"(baseline speedup {base['speedup']:.2f}x)")
            continue
        if base["correct"] and not cur["correct"]:
            bad.append(f"kernel {name}: optimized variant went INCORRECT")
        floor = base["speedup"] * (1.0 - kernel_tol)
        if cur["speedup"] < floor:
            bad.append(f"kernel {name}: speedup {cur['speedup']:.3f}x < "
                       f"{floor:.3f}x (baseline {base['speedup']:.3f}x "
                       f"- {kernel_tol:.0%})")
    gbase = baseline.get("geomean_speedup")
    if gbase and current["geomean_speedup"] < gbase * (1.0 - kernel_tol):
        bad.append(f"geomean speedup {current['geomean_speedup']:.3f}x < "
                   f"baseline {gbase:.3f}x - {kernel_tol:.0%}")
    if exact and "search_infra" in baseline:
        base_si = baseline["search_infra"]
        cur_si = current.get("search_infra", {})
        for field in ("quarantined", "recoveries"):
            if base_si.get(field, 0) != cur_si.get(field, 0):
                bad.append(f"search_infra: {field} changed "
                           f"{base_si.get(field, 0)} -> "
                           f"{cur_si.get(field, 0)} (deterministic counter; "
                           f"if intended, refresh baseline.json)")
        if cur_si.get("failed_kernels"):
            bad.append(f"search_infra: kernels failed during the bench run: "
                       f"{cur_si['failed_kernels']}")
    for key, base in baseline.get("serving", {}).items():
        cur = current["serving"].get(key)
        if cur is None:
            bad.append(f"serving {key}: missing from bench.json")
            continue
        floor = base["tok_per_s"] * (1.0 - serving_tol)
        if cur["tok_per_s"] < floor:
            bad.append(f"serving {key}: {cur['tok_per_s']:.1f} tok/s < "
                       f"{floor:.1f} (baseline {base['tok_per_s']:.1f} "
                       f"- {serving_tol:.0%})")
        if exact:
            for field in EXACT_SERVING:
                if field in base and base[field] != cur.get(field):
                    bad.append(f"serving {key}: {field} changed "
                               f"{base[field]} -> {cur.get(field)} "
                               f"(deterministic counter; if intended, "
                               f"refresh baseline.json)")
            # structural spec gate, independent of the baseline values:
            # on a spec row that actually drafted, speculation must pay
            # (>1 committed token per step) without breaking the one-
            # batched-readback-per-step invariant
            if "/spec_mix/device" in key and cur.get("draft_tokens"):
                if cur.get("accepted_per_step", 0) <= 1.0:
                    bad.append(
                        f"serving {key}: accepted_per_step "
                        f"{cur.get('accepted_per_step')} <= 1.0 (the "
                        f"self-draft verify should accept nearly k+1)")
                if cur.get("readbacks") != cur.get("steps"):
                    bad.append(
                        f"serving {key}: readbacks {cur.get('readbacks')}"
                        f" != steps {cur.get('steps')} (spec decode must "
                        f"keep one batched readback per step)")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench", default=BENCH_JSON)
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--kernel-tol", type=float, default=0.10,
                    help="relative drop allowed on (deterministic, "
                         "cost-model) kernel speedups")
    ap.add_argument("--serving-tol", type=float, default=0.60,
                    help="relative drop allowed on (wall-clock, noisy) "
                         "serving tokens/s")
    ap.add_argument("--no-exact", action="store_true",
                    help="skip the exact serving-counter comparison")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the current bench.json")
    args = ap.parse_args(argv)

    if not os.path.exists(args.bench):
        print(f"# no bench.json at {args.bench}; run "
              "`python benchmarks/run.py --json` first", file=sys.stderr)
        return 2
    current = extract(json.load(open(args.bench)))

    if args.update:
        with open(args.baseline, "w") as f:
            json.dump(current, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# baseline refreshed -> {args.baseline}")
        return 0

    if not os.path.exists(args.baseline):
        # a missing baseline must FAIL (otherwise deleting it disables the
        # gate silently) — refresh + commit it instead
        print(f"# BASELINE MISSING: {args.baseline} "
              "(run with --update and commit it)", file=sys.stderr)
        return 2

    baseline = json.load(open(args.baseline))
    bad = compare(current, baseline, kernel_tol=args.kernel_tol,
                  serving_tol=args.serving_tol, exact=not args.no_exact)
    n_gates = (len(baseline.get("kernels", {}))
               + len(baseline.get("serving", {})) + 1
               + (1 if baseline.get("search_infra") else 0))
    if bad:
        print(f"# BENCH REGRESSION ({len(bad)} of {n_gates} gates):")
        for msg in bad:
            print(f"#   {msg}")
        return 1
    print(f"# bench-regression gate: {n_gates} gates pass "
          f"(kernel tol {args.kernel_tol:.0%}, "
          f"serving tol {args.serving_tol:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
