"""Serving-throughput benchmark for the continuous-batching engine.

Runs the device-resident engine (and optionally the host-driven reference
engine) over several request mixes and reports, per (arch, mix, engine):

    tokens/s        end-to-end decode throughput (wall clock, includes
                    compiles — the reference engine's per-length prefill
                    retraces are part of what this benchmark measures)
    ttft_ms         mean time-to-first-token (submit -> first prefill token)
    steps           fused decode dispatches
    prefill_compiles  prefill retraces (bucketed: bounded by the pow2
                    bucket count; reference: one per unique prompt length)

Mixes: ``uniform_short`` (one short length), ``long_tail`` (mostly short,
a few near-window prompts), ``ragged_burst`` (8+ distinct lengths arriving
at once), ``oversubscribed`` (long prompts x long generations whose total
token demand exceeds a deliberately undersized page pool — the paged
engine must admit by actual token count, grow slots page-by-page, and
preempt/swap the youngest occupant when the pool runs dry; rows then also
report ``preemptions`` and page utilization/fragmentation), and
``priority_mix`` (a ragged batch carrying deterministic rid-derived
priorities, admitted by the PriorityScheduler — the row's exact
``sched_reorders`` counter pins the policy's behavior in the regression
gate; per-request streams still match the FCFS reference for
slot-independent families, which is what ``--check`` asserts on the dense
arch), and ``shared_prefix`` (16 requests whose prompts are staircase
cuts of one 256-token base — ~90% of prompt tokens are radix-tree hits
once warm, including one exact-duplicate prompt that forces a
copy-on-write; rows report ``prefix_hit_tokens`` / ``prefix_hit_rate`` /
``cow_copies``, a ``device-nocache`` twin row runs the same engine with
the tree disabled, ``streams_match_nocache`` asserts bit-identical
streams and ``warm_ttft_ms`` compares first-token latency over the warm
requests), and ``chaos_mix`` (8 valid requests plus 2 that admission
must reject, run under a step-indexed ``ChaosInjector`` plan — two
aborts, one injected device-step fault recovered via quarantine +
swap-restore, one 5-page pool seizure — against an oversubscribed pool;
the ``device-nochaos`` twin runs the identical engine without the
injector, ``survivors_match_nochaos`` asserts surviving streams are
bit-identical and aborted streams exact prefixes, and the
``aborted/rejected/failed/recoveries`` lifecycle counters are
exact-gated; no host-reference row — the reference engine predates fault
recovery), and ``spec_mix`` (a ragged batch decoded speculatively: a
self-draft ``draft_model`` drafter — draft params = target params, so
greedy proposals deterministically match the target and the acceptance
counters are golden-stable — with ``k=3``; the ``device-nospec`` twin
runs the identical engine target-only, ``streams_match_nospec`` asserts
bit-identical streams, and the exact-gated ``accepted_per_step`` /
``draft_tokens`` / ``accept_rate`` counters pin the fused verify
program's acceptance behavior; no host-reference row — the reference
engine IS target-only decoding, which the twin already covers without a
subprocess cold start). Wall times on this host are CPU numbers — a
functional serving benchmark, not a TPU projection.

Device rows are driven through the ``LLMEngine`` facade
(``generate(prompts, sampling_params)``); the host-driven reference rows
keep the raw submit/run loop that engine predates.

    PYTHONPATH=src python benchmarks/serve_bench.py                # bench
    PYTHONPATH=src python benchmarks/serve_bench.py --compare      # + ref
    PYTHONPATH=src python benchmarks/serve_bench.py --check \
        --check-golden --arch qwen2-0.5b --mixes ragged_burst      # CI

``--check`` asserts bit-identical token streams between the two engines;
``--check-golden`` additionally compares against the recorded golden
streams in ``benchmarks/golden/`` (``--record-golden`` refreshes them).
Both exit non-zero on divergence. ``benchmarks/run.py --json`` embeds the
rows under ``bench.json["serving"]``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

# The sharded serving rows need >= 4 devices; forcing the host platform
# device count must happen before jax initializes its backend. Device
# rows are unaffected: single-device engines run on device 0, whose
# computation is identical with or without the virtual split.
if "jax" not in sys.modules and "--xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=4").strip()

import numpy as np

ART = os.path.join(os.path.dirname(__file__), "artifacts")
GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
SERVE_JSON = os.path.join(ART, "serve.json")

DEFAULT_ARCHS = ("qwen2-0.5b", "olmoe-1b-7b")   # two model families
SLOTS, MAX_SEQ, MAX_NEW, SEED = 4, 128, 8, 0

# (phase label, wall seconds) timings accumulated across the run and
# printed by --durations — the receipts that twin-only scenarios (chaos/
# spec) and --skip-reference runs really do skip the reference subprocess
_DURATIONS: list = []


def _mix_lengths(mix: str, rng) -> list[int]:
    if mix == "uniform_short":
        return [8] * 12
    if mix == "long_tail":
        return [int(n) for n in rng.integers(5, 11, 10)] + [48, 64]
    if mix == "ragged_burst":
        # 8+ distinct lengths, all submitted up front
        lens = [int(n) for n in rng.integers(4, 41, 16)]
        while len(set(lens)) < 8:
            lens.append(int(rng.integers(4, 41)))
        return lens
    if mix == "oversubscribed":
        # long-prompt burst: total demand (prompt + generation rounded up
        # to whole pages) far exceeds OVERSUB_PAGES * PAGE_SIZE rows, so a
        # paged engine must oversubscribe and preempt
        return [int(n) for n in rng.integers(40, 81, 10)]
    if mix == "priority_mix":
        # ragged batch with rid-derived priorities (see build_requests):
        # the PriorityScheduler must reorder admission deterministically
        return [int(n) for n in rng.integers(6, 33, 12)]
    if mix == "shared_prefix":
        # lengths only (frames fallback); token prompts share content too
        return _SHARED_PREFIX_LENS
    if mix == "chaos_mix":
        # 8 valid requests plus two that admission must reject up front:
        # rid 8 is empty, rid 9 cannot fit max_seq (no room to emit)
        return [int(n) for n in rng.integers(20, 61, 8)] + [0, 200]
    if mix == "spec_mix":
        # ragged batch for speculative decoding: enough generation per
        # request (MIX_MAX_NEW) that variable acceptance spans many steps
        return [int(n) for n in rng.integers(6, 33, 10)]
    raise KeyError(f"unknown mix {mix!r}; have {sorted(MIXES)}")


MIXES = ("uniform_short", "long_tail", "ragged_burst", "oversubscribed",
         "priority_mix", "shared_prefix", "chaos_mix", "spec_mix")

# chaos_mix has no host-reference oracle: the reference engine predates
# admission validation and fault recovery, so its twin row is instead the
# SAME device engine run without the injector (see bench_arch). spec_mix
# likewise: the reference engine IS the target-only stream, and its
# device-nospec twin covers that comparison in-process — spinning up the
# reference subprocess for it would cold-start an oracle nobody consults
MIX_NO_REFERENCE = frozenset({"chaos_mix", "spec_mix"})

SPEC_K = 3      # spec_mix draft length (verify scores k+1 = 4 positions)

# paged-pool geometry for the oversubscribed mix: 4 slots x 128 max_seq
# would fully subscribe 32 pages of 16; 12 pages force admission queueing
# and mid-decode preemption (the contiguous fallback of non-PAGED_OK
# families simply ignores these knobs)
PAGE_SIZE, OVERSUB_PAGES = 16, 12
MIX_ENGINE_KW = {"oversubscribed": {"page_size": PAGE_SIZE,
                                    "num_pages": OVERSUB_PAGES},
                 "priority_mix": {"scheduler": "priority"},
                 # long staircase prompts over one 256-token base need the
                 # bigger window (240-token prompt + 8 generated < 256)
                 "shared_prefix": {"max_seq": 256},
                 # chaos runs against an oversubscribed pool so the
                 # injected page seizure actually induces preemption
                 "chaos_mix": {"page_size": PAGE_SIZE, "num_pages": 18}}
MIX_MAX_NEW = {"oversubscribed": 24, "chaos_mix": 12, "spec_mix": 16}


def _chaos_plan():
    """The deterministic chaos_mix fault plan, all step-indexed (never
    wall-clock) so the surviving streams and lifecycle counters are
    golden-stable: one mid-decode abort, one abort while likely still
    queued, a device-step fault recovered by quarantine + swap-restore,
    and a 4-step seizure of 5 pool pages (paged engines only; the
    contiguous fallback marks it fired without effect)."""
    from repro.reliability import Fault
    return [Fault("abort", step=2, rid=1),
            Fault("abort", step=5, rid=5),
            Fault("device_fault", step=7, slot=1),
            Fault("pool_exhaustion", step=10, pages=5, steps=4)]

# shared_prefix recipe: r0-r11 are a page-aligned staircase over one base
# (64, 80, ..., 240 — every suffix after the cached prefix is exactly one
# 16-row page), r12 duplicates r5 exactly (the forced-CoW shape: full-
# prompt match, last page copied before re-prefill), r13-r15 cut the base
# at a page boundary and append a ragged uncached tail. ~90% of all
# prompt tokens are radix-tree hits once the tree is warm.
_SHARED_PREFIX_STAIRS = [64 + 16 * i for i in range(12)]
_SHARED_PREFIX_TAILS = ((208, 5), (96, 9), (176, 3))
_SHARED_PREFIX_LENS = (_SHARED_PREFIX_STAIRS + [144]
                       + [cut + extra for cut, extra
                          in _SHARED_PREFIX_TAILS])


def _shared_prefix_prompts(cfg, rng) -> list[np.ndarray]:
    base = rng.integers(0, cfg.vocab, (256,), dtype=np.int32)
    prompts = [base[:n].copy() for n in _SHARED_PREFIX_STAIRS]
    prompts.append(base[:144].copy())           # exact duplicate of r5
    for cut, extra in _SHARED_PREFIX_TAILS:
        tail = rng.integers(0, cfg.vocab, (extra,), dtype=np.int32)
        prompts.append(np.concatenate([base[:cut], tail]))
    return prompts


def build_requests(cfg, mix: str, *, seed: int = SEED,
                   max_new: int = None):
    """Deterministic request list for (cfg, mix, seed)."""
    from repro.serving.engine import Request
    if max_new is None:
        max_new = MIX_MAX_NEW.get(mix, MAX_NEW)
    rng = np.random.default_rng(seed)
    if mix == "shared_prefix" and cfg.frontend != "frames":
        return [Request(rid=rid, prompt=p, max_new_tokens=max_new)
                for rid, p in enumerate(_shared_prefix_prompts(cfg, rng))]
    reqs = []
    for rid, n in enumerate(_mix_lengths(mix, rng)):
        if cfg.frontend == "frames":
            prompt = rng.standard_normal((n, cfg.d_model)).astype(np.float32)
        else:
            prompt = rng.integers(0, cfg.vocab, (n,), dtype=np.int32)
        # deterministic rid-derived priority spread (only the priority
        # scheduler reads it; the field's presence cannot perturb FCFS)
        prio = (rid * 5) % 3 if mix == "priority_mix" else 0
        reqs.append(Request(rid=rid, prompt=prompt, max_new_tokens=max_new,
                            priority=prio))
    return reqs


def _metrics_row(wall, toks, ttfts, stats, streams) -> dict:
    row = {
        "requests": len(streams),
        "tokens": toks,
        "wall_s": wall,
        "tok_per_s": toks / wall if wall else 0.0,
        "ttft_ms": float(np.mean(ttfts)) * 1e3 if ttfts else None,
        "steps": stats.get("steps"),
        # one batched host readback per dispatched step — the property
        # the sharded engine must preserve (exact-gated; the host-driven
        # reference engine predates the counter and reports 0)
        "readbacks": stats.get("readbacks", 0),
        "prefill_compiles": stats.get("prefill_compiles"),
        "paged": stats.get("paged", False),
        "preemptions": stats.get("preemptions", 0),
        "streams": streams,
    }
    if "scheduler" in stats:
        row["scheduler"] = stats["scheduler"]
        row["sched_reorders"] = stats["sched_reorders"]
    # request-lifecycle counters (deterministic; exact-gated): nonzero
    # only under the chaos_mix injector or client aborts/deadlines
    for key in ("aborted", "rejected", "failed", "deadline_expired",
                "recoveries"):
        row[key] = stats.get(key, 0)
    # speculative-decoding counters, always present (zero when spec is
    # off or inert) — deterministic under greedy self-draft, so the
    # regression gate compares them exactly like the lifecycle counters
    row["spec_on"] = stats.get("spec_on", False)
    row["accepted_per_step"] = round(stats.get("accepted_per_step", 0.0), 4)
    row["accepted_tokens"] = stats.get("accepted_tokens", 0)
    row["draft_tokens"] = stats.get("draft_tokens", 0)
    row["accept_rate"] = round(stats.get("accept_rate", 0.0), 4)
    # always present (zero when caching is off/unsupported) so the
    # regression gate can compare them uniformly across engines
    row["prefix_cache"] = stats.get("prefix_cache", False)
    row["prefix_hit_tokens"] = stats.get("prefix_hit_tokens", 0)
    row["cow_copies"] = stats.get("cow_copies", 0)
    if row["prefix_cache"]:
        row["prefix_hit_rate"] = round(stats.get("prefix_hit_rate", 0.0), 4)
        row["tree_evictions"] = stats.get("tree_evictions", 0)
    if stats.get("paged"):
        row.update({
            "page_size": stats["page_size"],
            "num_pages": stats["num_pages"],
            "peak_pages_in_use": stats["peak_pages_in_use"],
            "page_util_mean": round(stats["page_util_mean"], 4),
            "page_frag_mean": round(stats["page_frag_mean"], 4),
        })
    return row


def run_engine(engine, requests) -> dict:
    """Drive one raw engine over pre-built Requests (the reference path)."""
    t0 = time.perf_counter()
    for r in requests:
        engine.submit(r)
    done = engine.run()
    wall = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    ttfts = [r.t_first - r.t_submit for r in done
             if getattr(r, "t_first", 0) and getattr(r, "t_submit", 0)]
    stats = engine.stats() if hasattr(engine, "stats") else {}
    return _metrics_row(wall, toks, ttfts, stats,
                        {r.rid: list(r.out_tokens) for r in done})


def run_llm(llm, requests) -> dict:
    """Drive the LLMEngine facade over the same request list (device
    path): prompts + per-request knobs in, RequestOutputs out — no
    submit/run/out_tokens scraping."""
    t0 = time.perf_counter()
    outs = llm.generate(
        [r.prompt for r in requests],
        max_new_tokens=[r.max_new_tokens for r in requests],
        priorities=[r.priority for r in requests])
    wall = time.perf_counter() - t0
    toks = sum(len(o.tokens) for o in outs)
    ttfts = [o.ttft_s for o in outs if o.ttft_s is not None]
    row = _metrics_row(wall, toks, ttfts, llm.stats(),
                       {o.rid: list(o.tokens) for o in outs})
    # per-request detail for the warm-TTFT comparison; popped by
    # bench_arch before rows leave the process
    row["_ttfts"] = {o.rid: o.ttft_s for o in outs}
    row["_hits"] = {o.rid: o.prefix_hit_tokens for o in outs}
    row["_reasons"] = {o.rid: o.finish_reason for o in outs}
    return row


def reference_rows(arch: str, mixes=MIXES, *, seed: int = SEED) -> list[dict]:
    """Measure the host-driven reference engine (run this in a FRESH
    process: in-process ordering would hand one engine the other's warm
    XLA op caches and skew the comparison either way)."""
    import jax
    from repro import configs
    from repro.models import registry
    from repro.serving.reference import ReferenceEngine

    cfg = configs.smoke(arch)
    params, _ = registry.init(cfg, jax.random.PRNGKey(seed))
    rows = []
    for mix in mixes:
        if mix in MIX_NO_REFERENCE:
            continue
        reqs = build_requests(cfg, mix, seed=seed)
        max_seq = MIX_ENGINE_KW.get(mix, {}).get("max_seq", MAX_SEQ)
        row = {"arch": arch, "mix": mix, "engine": "reference",
               **run_engine(ReferenceEngine(params, cfg, slots=SLOTS,
                                            max_seq=max_seq), reqs)}
        row["prefill_compiles"] = len({len(r.prompt) for r in reqs})
        rows.append(row)
    return rows


def _reference_rows_subprocess(arch: str, mixes, seed: int) -> list[dict]:
    """Cold, isolated reference measurement via a child interpreter."""
    import subprocess
    import sys
    import tempfile
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
        out = f.name
    t0 = time.perf_counter()
    try:
        proc = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--reference-only",
             "--out", out, "--arch", arch, "--mixes", ",".join(mixes)],
            env=env, capture_output=True, text=True)
        if proc.returncode != 0:
            raise RuntimeError(
                f"reference-engine subprocess failed (rc={proc.returncode})"
                f":\n{proc.stderr[-2000:]}")
        with open(out) as f:
            return json.load(f)
    finally:
        _DURATIONS.append((f"reference_subprocess/{arch}",
                           time.perf_counter() - t0))
        os.unlink(out)


def bench_arch(arch: str, mixes=MIXES, *, compare: bool = False,
               check: bool = False, seed: int = SEED) -> list[dict]:
    """All mixes for one arch; fresh engines share one param set."""
    import jax
    from repro import configs
    from repro.models import registry
    from repro.serving import LLMEngine

    cfg = configs.smoke(arch)
    params, _ = registry.init(cfg, jax.random.PRNGKey(seed))
    # per-request streams have an oracle (the FCFS reference, or the
    # chaos row's undisturbed twin) only when decode is slot-independent
    # (PAGED_OK): aborts/recoveries/reordering change pool composition,
    # which slot-coupled families (MoE capacity routing) observe
    slot_independent = bool(getattr(registry.module_for(cfg),
                                    "PAGED_OK", False))
    # tensor-parallel twin: the same engine sharded over a (2, 2)
    # (data, model) mesh — dense family only, and only when the forced
    # host platform actually yielded >= 4 devices. Streams must be
    # bit-identical to the single-device row (streams_match_sharded).
    sharded_mesh = None
    if cfg.family == "dense" and len(jax.devices()) >= 4:
        from jax.sharding import Mesh
        sharded_mesh = Mesh(
            np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
    rows = []
    for mix in mixes:
        kw = dict(slots=SLOTS, max_seq=MAX_SEQ)
        kw.update(MIX_ENGINE_KW.get(mix, {}))
        chaos = None
        if mix == "chaos_mix":
            from repro.serving import ChaosInjector
            chaos = ChaosInjector(_chaos_plan())
        spec = None
        if mix == "spec_mix":
            # self-draft: the draft model IS the target, so every greedy
            # proposal matches and acceptance is deterministic (near
            # k+1 tokens/step) — the strongest golden-stable setting for
            # exact-gating the verify program. Inert (zero counters) for
            # non-paged families; the row still runs target-equivalent.
            from repro.serving import SpecConfig
            spec = SpecConfig(drafter="draft_model", k=SPEC_K,
                              draft_params=params, draft_cfg=cfg)
        llm = LLMEngine(params, cfg, chaos=chaos, spec=spec, **kw)
        reqs = build_requests(cfg, mix, seed=seed)
        row = {"arch": arch, "mix": mix, "engine": "device",
               **run_llm(llm, reqs)}
        rows.append(row)
        if mix == "spec_mix":
            # the spec row's oracle: the identical engine target-only —
            # greedy spec streams must be bitwise identical, just
            # reached in fewer (exact-gated) steps
            llm0 = LLMEngine(params, cfg, **kw)
            row0 = {"arch": arch, "mix": mix, "engine": "device-nospec",
                    **run_llm(llm0, reqs)}
            row["streams_match_nospec"] = row["streams"] == row0["streams"]
            rows.append(row0)
        if mix == "chaos_mix":
            assert chaos.exhausted, "chaos plan failed to fire fully"
            # the chaos row's oracle: the same engine, same requests, no
            # injector — surviving streams must be bit-identical and
            # aborted streams exact prefixes of the undisturbed run
            llm0 = LLMEngine(params, cfg, **kw)
            row0 = {"arch": arch, "mix": mix, "engine": "device-nochaos",
                    **run_llm(llm0, reqs)}
            if slot_independent:
                match = True
                for rid, stream in row["streams"].items():
                    want = row0["streams"].get(rid, [])
                    reason = row["_reasons"][rid]
                    if reason == "done":
                        match &= stream == want
                    elif reason == "aborted":
                        match &= stream == want[:len(stream)]
                row["survivors_match_nochaos"] = match
            else:
                row["survivors_match_nochaos"] = None   # no oracle
            rows.append(row0)
        if mix == "shared_prefix":
            # the prefix cache's own oracle: the identical engine with the
            # radix tree disabled — streams must match bit-for-bit, and
            # warm requests (those with tree hits) show the TTFT win
            llm0 = LLMEngine(params, cfg, prefix_cache=False, **kw)
            row0 = {"arch": arch, "mix": mix, "engine": "device-nocache",
                    **run_llm(llm0, reqs)}
            row["streams_match_nocache"] = \
                row["streams"] == row0["streams"]
            warm = sorted(r for r, h in row["_hits"].items() if h > 0)
            if warm:
                for r_ in (row, row0):
                    ts = [r_["_ttfts"][w] for w in warm
                          if r_["_ttfts"][w] is not None]
                    r_["warm_ttft_ms"] = float(np.mean(ts)) * 1e3 \
                        if ts else None
            rows.append(row0)
        if sharded_mesh is not None:
            # fresh injector for the chaos twin: the plan is stateful
            chaos_s = None
            if mix == "chaos_mix":
                from repro.serving import ChaosInjector
                chaos_s = ChaosInjector(_chaos_plan())
            llm_s = LLMEngine(params, cfg, chaos=chaos_s, spec=spec,
                              mesh=sharded_mesh, **kw)
            row_s = {"arch": arch, "mix": mix, "engine": "device-sharded",
                     **run_llm(llm_s, reqs)}
            row_s["streams_match_sharded"] = \
                row_s["streams"] == row["streams"]
            rows.append(row_s)
    for row in rows:
        row.pop("_ttfts", None)
        row.pop("_hits", None)
        row.pop("_reasons", None)
    if compare or check:
        ref_mixes = [m for m in mixes if m not in MIX_NO_REFERENCE]
        refs = {r["mix"]: r for r in
                _reference_rows_subprocess(arch, ref_mixes, seed)} \
            if ref_mixes else {}
        for row in list(rows):
            if row["engine"] != "device":
                continue
            ref = refs.get(row["mix"])
            if ref is None:            # no host oracle (chaos_mix)
                continue
            row["speedup_vs_reference"] = (ref["wall_s"] / row["wall_s"]
                                           if row["wall_s"] else None)
            sched = MIX_ENGINE_KW.get(row["mix"], {}).get("scheduler",
                                                          "fcfs")
            if sched != "fcfs" and not slot_independent:
                row["streams_match_reference"] = None   # no oracle
            else:
                row["streams_match_reference"] = (
                    {str(k): v for k, v in row["streams"].items()}
                    == {str(k): v for k, v in ref["streams"].items()})
            rows.append(ref)
    return rows


def _golden_path(arch: str, mix: str) -> str:
    return os.path.join(GOLDEN_DIR, f"serve_{arch}_{mix}.json")


def check_golden(rows, *, record: bool = False) -> bool:
    """Compare device-engine streams against the recorded goldens."""
    ok = True
    for row in rows:
        if row["engine"] != "device":
            continue
        path = _golden_path(row["arch"], row["mix"])
        streams = {str(k): v for k, v in row["streams"].items()}
        if record:
            os.makedirs(GOLDEN_DIR, exist_ok=True)
            with open(path, "w") as f:
                json.dump({"arch": row["arch"], "mix": row["mix"],
                           "seed": SEED, "slots": SLOTS, "max_seq": MAX_SEQ,
                           "max_new": MIX_MAX_NEW.get(row["mix"], MAX_NEW),
                           "engine_kw": MIX_ENGINE_KW.get(row["mix"], {}),
                           "streams": streams}, f,
                          indent=1, sort_keys=True)
            print(f"# golden recorded -> {path}")
            continue
        if not os.path.exists(path):
            # a missing golden must FAIL the check, not silently pass —
            # otherwise a renamed arch/mix (or uncommitted goldens) turns
            # the CI gate into a no-op
            ok = False
            print(f"# GOLDEN MISSING for {row['arch']}/{row['mix']}: {path} "
                  f"(run with --record-golden and commit it)")
            continue
        want = json.load(open(path))["streams"]
        if want != streams:
            ok = False
            bad = sorted(k for k in want if want[k] != streams.get(k))
            print(f"# GOLDEN MISMATCH {row['arch']}/{row['mix']}: "
                  f"rids {bad[:5]} diverge ({path})")
    return ok


def print_rows(rows):
    print("# Serving — continuous batching throughput "
          "(name,us_per_token,derived)")
    for r in rows:
        us = r["wall_s"] / max(r["tokens"], 1) * 1e6
        extra = ""
        if r.get("speedup_vs_reference") is not None:
            extra = (f",speedup={r['speedup_vs_reference']:.2f}x,"
                     f"match={r['streams_match_reference']}")
        ttft = f"{r['ttft_ms']:.0f}" if r.get("ttft_ms") is not None else "na"
        paged = ""
        if r.get("paged"):
            paged = (f",preempt={r['preemptions']},"
                     f"pages={r['peak_pages_in_use']}/{r['num_pages']},"
                     f"frag={r['page_frag_mean']:.2f}")
        sched = ""
        if r.get("scheduler") and r["scheduler"] != "fcfs":
            sched = (f",sched={r['scheduler']},"
                     f"reorders={r['sched_reorders']}")
        pfx = ""
        if r.get("prefix_cache"):
            pfx = (f",hit_rate={r['prefix_hit_rate']:.2f},"
                   f"hit_tokens={r['prefix_hit_tokens']},"
                   f"cow={r['cow_copies']}")
        if r.get("warm_ttft_ms") is not None:
            pfx += f",warm_ttft_ms={r['warm_ttft_ms']:.0f}"
        if r.get("streams_match_nocache") is not None:
            pfx += f",match_nocache={r['streams_match_nocache']}"
        if r.get("streams_match_sharded") is not None:
            pfx += f",match_sharded={r['streams_match_sharded']}"
        if r.get("spec_on"):
            pfx += (f",accepted_per_step={r['accepted_per_step']:.2f},"
                    f"accept_rate={r['accept_rate']:.2f},"
                    f"draft_tokens={r['draft_tokens']}")
        if r.get("streams_match_nospec") is not None:
            pfx += f",match_nospec={r['streams_match_nospec']}"
        if any(r.get(k) for k in ("aborted", "rejected", "failed",
                                  "deadline_expired", "recoveries")):
            pfx += (f",aborted={r['aborted']},rejected={r['rejected']},"
                    f"failed={r['failed']},recoveries={r['recoveries']}")
        if "survivors_match_nochaos" in r:
            pfx += f",survivors_match={r['survivors_match_nochaos']}"
        print(f"serving/{r['arch']}/{r['mix']}/{r['engine']},{us:.0f},"
              f"tok_s={r['tok_per_s']:.1f},ttft_ms={ttft},"
              f"steps={r['steps']},"
              f"prefill_compiles={r['prefill_compiles']}{sched}{pfx}"
              f"{paged}{extra}")


def bench(archs=DEFAULT_ARCHS, mixes=MIXES, *, compare: bool = False,
          check: bool = False, seed: int = SEED) -> list[dict]:
    rows = []
    for arch in archs:
        t0 = time.perf_counter()
        rows.extend(bench_arch(arch, mixes, compare=compare, check=check,
                               seed=seed))
        _DURATIONS.append((f"bench_arch/{arch}",
                           time.perf_counter() - t0))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", action="append", dest="archs", default=None)
    ap.add_argument("--mixes", default=",".join(MIXES),
                    help="comma-separated subset of " + ",".join(MIXES))
    ap.add_argument("--compare", action="store_true",
                    help="also run the host-driven reference engine")
    ap.add_argument("--check", action="store_true",
                    help="fail unless device streams are bit-identical to "
                         "the reference engine")
    ap.add_argument("--check-golden", action="store_true",
                    help="fail unless device streams match the recorded "
                         "goldens in benchmarks/golden/")
    ap.add_argument("--record-golden", action="store_true")
    ap.add_argument("--skip-reference", action="store_true",
                    help="skip the host-reference subprocess (fast local "
                         "runs; disables --compare rows and --check's "
                         "stream comparison, golden checks still run)")
    ap.add_argument("--durations", action="store_true",
                    help="print per-phase wall timings (device rows per "
                         "arch, reference subprocesses) — shows what "
                         "--skip-reference and the twin-only mixes save")
    ap.add_argument("--json", action="store_true",
                    help=f"write rows (sans streams) to {SERVE_JSON}")
    ap.add_argument("--reference-only", action="store_true",
                    help=argparse.SUPPRESS)   # internal: cold child process
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    mixes = tuple(m for m in args.mixes.split(",") if m)
    if args.reference_only:
        rows = []
        for arch in tuple(args.archs or DEFAULT_ARCHS):
            rows.extend(reference_rows(arch, mixes))
        with open(args.out, "w") as f:
            json.dump(rows, f)
        return 0
    compare = (args.compare or args.check) and not args.skip_reference
    rows = bench(tuple(args.archs or DEFAULT_ARCHS), mixes,
                 compare=compare, check=args.check and not
                 args.skip_reference)
    print_rows(rows)
    if args.durations:
        print("# durations (phase,wall_s)")
        for label, secs in _DURATIONS:
            print(f"# {label},{secs:.2f}")
        if not any(lbl.startswith("reference_subprocess")
                   for lbl, _ in _DURATIONS):
            print("# (no reference subprocess was started)")
    rc = 0
    if args.check:
        # None = no FCFS oracle (reordering scheduler on a slot-coupled
        # family) — skipped, not failed
        bad = [r for r in rows if r["engine"] == "device"
               and r.get("streams_match_reference") is False]
        for r in bad:
            print(f"# STREAM MISMATCH vs reference: "
                  f"{r['arch']}/{r['mix']}")
        rc |= bool(bad)
    if args.check or args.check_golden:
        # sharded rows must be bit-identical to the single-device rows
        bad_s = [r for r in rows
                 if r.get("streams_match_sharded") is False]
        for r in bad_s:
            print(f"# STREAM MISMATCH sharded vs single-device: "
                  f"{r['arch']}/{r['mix']}")
        rc |= bool(bad_s)
        # greedy speculative streams must be bitwise identical to the
        # target-only twin — a drafter may be slow, never wrong
        bad_sp = [r for r in rows
                  if r.get("streams_match_nospec") is False]
        for r in bad_sp:
            print(f"# STREAM MISMATCH spec vs target-only: "
                  f"{r['arch']}/{r['mix']}")
        rc |= bool(bad_sp)
    if args.check_golden or args.record_golden:
        rc |= not check_golden(rows, record=args.record_golden)
    if args.json:
        os.makedirs(ART, exist_ok=True)
        slim = [{k: v for k, v in r.items() if k != "streams"}
                for r in rows]
        with open(SERVE_JSON, "w") as f:
            json.dump(slim, f, indent=1)
        print(f"# serving json -> {SERVE_JSON}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
