"""Benchmark harness — one function per paper table/figure.

    Table 2: baseline vs Astra-optimized kernels (latency, speedup, correct)
    Table 3: single-agent vs multi-agent ablation
    Table 4: per-tensor-shape speedups
    Roofline: the dry-run table (reads benchmarks/artifacts/dryrun/*.json)

Prints ``name,us_per_call,derived`` CSV rows; artifacts are written to
benchmarks/artifacts/. Latencies are analytic TPU-v5e cost-model values
(see README.md § "Evaluation pipeline" — this host has no TPU);
correctness is interpret-mode Pallas vs the jnp oracles.

Search evaluations go through the tiered engine with a **persistent**
evaluation cache under ``benchmarks/artifacts/evalcache/``: a second
consecutive run revalidates nothing (hit-rate ~1.0 is printed per search).
Delete that directory to start cold.

Robustness (README § "Robust search"): every search writes a write-ahead
journal under ``benchmarks/artifacts/journal/``; ``--resume`` replays it
after a kill so the run continues from the first unfinished evaluation
with a bit-identical Log. ``--isolation process`` evaluates candidates in
sandboxed spawn workers (deadlines, retries, quarantine); ``--chaos``
drills that path with injected worker kills/hangs/corruption. One
kernel's infra failure marks it ``failed`` in bench.json and the run
continues (keep-going).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

# The serving benchmark's tensor-parallel rows need >= 4 devices; the
# forced host platform split must land in XLA_FLAGS before anything
# imports jax (serve_bench is imported lazily, long after jax is live,
# so it cannot set the flag itself when run through this harness).
# Single-device rows and kernel timings are unaffected — they run on
# device 0, whose computation is identical under the virtual split.
if "jax" not in sys.modules and "--xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=4").strip()

import numpy as np

ART = os.path.join(os.path.dirname(__file__), "artifacts")
BENCH_JSON = os.path.join(ART, "bench.json")
EVALCACHE = os.path.join(ART, "evalcache", "cache.jsonl")
JOURNAL_DIR = os.path.join(ART, "journal")

# Hoisted hi-fi measurement rig: one ProfilingAgent (reps=10**6) and one
# memoized suite per kernel, shared by table2/table3/table4/bench_json —
# historically every _eval call built a fresh agent and regenerated T.
_HIFI = None
_TESTER = None


def _hifi():
    global _HIFI
    if _HIFI is None:
        from repro.core import ProfilingAgent
        _HIFI = ProfilingAgent(reps=10**6)
    return _HIFI


def _tester():
    global _TESTER
    if _TESTER is None:
        from repro.core import TestingAgent
        _TESTER = TestingAgent()
    return _TESTER


def _suite(space):
    """Memoized per-kernel test suite (registry suite memo)."""
    from repro.kernels.registry import suite_tests
    return suite_tests(space, _tester())


def _eval(space, variant, tests):
    return _hifi().profile(space, variant, tests).geomean_latency_us


def table2_main(results=None, csv=True):
    """Paper Table 2: per-kernel baseline vs optimized (R=5 rounds)."""
    from repro.core import SPACES, optimize_all
    results = results or optimize_all(rounds=5)
    tester = _tester()
    rows = []
    for i, (name, log) in enumerate(results.items(), 1):
        space = SPACES[name]
        tests = _suite(space)
        base = _eval(space, space.baseline, tests)
        best = log.best()
        opt_lat = _eval(space, best.code, tests)
        ok, err = tester.validate(space, best.code, tests)
        rows.append({
            "kernel": name, "paper_kernel": f"K{i}",
            "knobs_base": space.baseline.describe(),
            "knobs_opt": best.code.describe(),
            "time_base_us": base, "time_opt_us": opt_lat,
            "speedup": base / opt_lat, "correct": bool(ok),
            "max_err": err, "rounds": len(log.entries) - 1,
            "trajectory": [e.row() for e in log.entries],
        })
    if csv:
        print("# Table 2 — baseline vs Astra-optimized "
              "(paper: K1 1.26x K2 1.25x K3 1.46x, avg 1.32x)")
        for r in rows:
            print(f"table2/{r['kernel']},{r['time_opt_us']:.3f},"
                  f"speedup={r['speedup']:.2f}x,correct={r['correct']}")
        g = np.exp(np.mean([np.log(r["speedup"]) for r in rows]))
        print(f"table2/geomean,,speedup={g:.2f}x")
    return rows


def table3_ablation(results=None, csv=True):
    """Paper Table 3: single-agent vs multi-agent."""
    from repro.core import SPACES, optimize_all, optimize_single_agent
    results = results or optimize_all(rounds=5)
    tester = _tester()
    rows = []
    for name, log in results.items():
        space = SPACES[name]
        tests = _suite(space)
        base = _eval(space, space.baseline, tests)
        ma = _eval(space, log.best().code, tests)
        sa_log = optimize_single_agent(name, rounds=5)
        sa = _eval(space, sa_log.final_variant, tests)
        sa_ok, _ = tester.validate(space, sa_log.final_variant, tests)
        rows.append({"kernel": name, "time_base_us": base,
                     "speedup_sa": base / sa, "speedup_ma": base / ma,
                     "correct_sa": bool(sa_ok), "correct_ma": True})
    if csv:
        print("# Table 3 — single-agent vs multi-agent "
              "(paper: SA 0.73/1.18/1.48 avg 1.08; MA 1.26/1.25/1.46 avg 1.32)")
        for r in rows:
            print(f"table3/{r['kernel']},{r['time_base_us']:.3f},"
                  f"SA={r['speedup_sa']:.2f}x,MA={r['speedup_ma']:.2f}x")
        gs = np.exp(np.mean([np.log(r["speedup_sa"]) for r in rows]))
        gm = np.exp(np.mean([np.log(r["speedup_ma"]) for r in rows]))
        print(f"table3/geomean,,SA={gs:.2f}x,MA={gm:.2f}x")
    return rows


def table4_shapes(results=None, csv=True):
    """Paper Table 4: per-shape baseline/optimized latencies."""
    from repro.core import SPACES, make_inputs, optimize_all
    results = results or optimize_all(rounds=5)
    rows = []
    for name, log in results.items():
        space = SPACES[name]
        best = log.best().code
        for shape in space.suite_shapes:
            t = make_inputs(name, shape, seed=1)
            try:
                base_c = space.cost(space.baseline, **t.shape_info)
                opt_c = space.cost(best, **t.shape_info)
            except Exception:
                continue
            rows.append({"kernel": name, "shape": t.name,
                         "time_base_us": base_c.latency_s * 1e6,
                         "time_opt_us": opt_c.latency_s * 1e6,
                         "speedup": base_c.latency_s / opt_c.latency_s})
    if csv:
        print("# Table 4 — impact of tensor shapes")
        for r in rows:
            print(f"table4/{r['kernel']}{r['shape']},"
                  f"{r['time_opt_us']:.3f},speedup={r['speedup']:.2f}x")
    return rows


def roofline_table(csv=True):
    """§Roofline: aggregate the dry-run artifacts (prefers the post-
    optimization `dryrun_final` sweep; `dryrun` holds the baselines)."""
    src = "dryrun_final" if glob.glob(os.path.join(ART, "dryrun_final",
                                                   "*.json")) else "dryrun"
    rows = []
    for f in sorted(glob.glob(os.path.join(ART, src, "*.json"))):
        rows.append(json.load(open(f)))
    ok = [r for r in rows if r.get("status") == "ok"]
    if csv and ok:
        print("# Roofline — dry-run cells (per-chip roofline step time, us)")
        for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
            print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
                  f"{r['step_ms']*1e3:.0f},dom={r['dominant']},"
                  f"useful={r['useful_flops_ratio']:.2f}")
    elif csv:
        print("# Roofline — no dry-run artifacts yet "
              "(run python -m repro.launch.dryrun --all)")
    return rows


def serving_bench(csv=True, archs=None, mixes=None):
    """End-to-end serving throughput through the device-resident engine
    (CPU wall time — a functional benchmark, not a TPU number) across the
    request mixes and both default model families. Reference comparison /
    golden gating: ``benchmarks/serve_bench.py`` (the CI serving smoke).
    """
    import sys
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import serve_bench as sb
    rows = sb.bench(archs or sb.DEFAULT_ARCHS, mixes or sb.MIXES)
    if csv:
        sb.print_rows(rows)
    return [{k: v for k, v in r.items() if k != "streams"} for r in rows]


def autotune_spec(csv=True, ks=(1, 2, 4),
                  path=os.path.join(ART, "spec_autotune.json")):
    """Sweep the speculative-decoding (drafter, k) search space against
    end-to-end serving tokens/s (``repro.serving.spec.space``): a
    half-depth sibling drafts for the qwen2-0.5b smoke target over a
    fixed ragged prompt mix, every variant is validated bit-identical to
    the target-only baseline, and the best valid variant wins. Opt-in
    via ``--autotune-spec`` (CPU serving walls are noisy, so this stays
    out of the default CI artifact)."""
    import dataclasses

    import jax
    from repro import configs
    from repro.models import registry
    from repro.serving.spec import space as spec_space

    cfg = configs.smoke("qwen2-0.5b")
    params, _ = registry.init(cfg, jax.random.PRNGKey(0))
    dcfg = dataclasses.replace(cfg, name=cfg.name + "-draft",
                               n_layers=max(1, cfg.n_layers // 2))
    draft_params, _ = registry.init(dcfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (int(n),), dtype=np.int32)
               for n in rng.integers(6, 25, 8)]
    out = spec_space.autotune(params, cfg, prompts,
                              draft_params=draft_params, draft_cfg=dcfg,
                              ks=ks)
    if csv:
        print("# Spec autotune — (drafter, k) vs serve tokens/s "
              "(valid = streams bit-identical to target-only)")
        for r in out["rows"]:
            print(f"spec_autotune/{r['drafter']}/k{r['k']},"
                  f"{r['wall_s']*1e6:.0f},tok_s={r['tok_per_s']:.1f},"
                  f"accepted_per_step={r['accepted_per_step']:.2f},"
                  f"accept_rate={r['accept_rate']:.2f},"
                  f"valid={r['valid']}")
        best = out["best"]
        if best is None:
            print("spec_autotune/best,,NONE (every variant diverged — "
                  "that is a bug, not a tuning result)")
        else:
            print(f"spec_autotune/best,,drafter={best['drafter']},"
                  f"k={best['k']},tok_s={best['tok_per_s']:.1f} "
                  f"(target-only {best['base_tok_per_s']:.1f})")
    os.makedirs(ART, exist_ok=True)
    with open(path, "w") as f:
        json.dump(out, f, indent=1, default=str)
    print(f"# spec autotune json -> {path}")
    return out


def bench_json(results=None, *, strategy="greedy", rounds: int = 5,
               path: str = BENCH_JSON, serving=None) -> dict:
    """Machine-readable perf snapshot for cross-PR trajectory tracking:
    per-kernel baseline/optimized latency, speedup, per-search wall-clock,
    evaluation-cache hit-rate, the tiered engine's stage counters
    (oracle computations, validation runs, cascade skips) — all from
    ``Log.meta`` — plus the serving-engine throughput rows (tokens/s,
    TTFT, steps, prefill retraces per request mix)."""
    from repro.core import SPACES, registered_kernels
    from repro.search import EvalCache, optimize_all
    if results is None:
        results = optimize_all(rounds=rounds, strategy=strategy,
                               kernels=registered_kernels(),
                               cache=EvalCache())
    kernels = []
    failed = []
    for name, log in results.items():
        if isinstance(log, Exception):    # keep-going: SearchFailure
            failed.append({"kernel": name, "failed": True,
                           "error": getattr(log, "detail", repr(log))})
            continue
        space = SPACES[name]
        tests = _suite(space)
        base = _eval(space, space.baseline, tests)
        best = log.best()
        opt = _eval(space, best.code, tests)
        cache = log.meta.get("cache", {})
        total = cache.get("hits", 0) + cache.get("misses", 0)
        kernels.append({
            "kernel": name,
            "strategy": log.meta.get("strategy", "greedy"),
            "baseline_us": base,
            "optimized_us": opt,
            "speedup": base / opt,
            "correct": bool(best.correct),
            "cache_hits": cache.get("hits", 0),
            "cache_misses": cache.get("misses", 0),
            "cache_hit_rate": cache.get("hits", 0) / total if total else 0.0,
            "wall_s": log.meta.get("wall_s"),
            "stages": log.meta.get("stages", {}),
            "variant": best.code.describe(),
        })
    geo = float(np.exp(np.mean([np.log(k["speedup"]) for k in kernels]))) \
        if kernels else 0.0
    stage_totals = {}
    for k in kernels:
        for key, v in k["stages"].items():
            stage_totals[key] = stage_totals.get(key, 0) + v
    if serving is None:   # standalone bench_json: representative cells
        serving = serving_bench(csv=False, archs=("qwen2-0.5b",),
                                mixes=("ragged_burst", "oversubscribed"))
    payload = {"kernels": kernels + failed, "geomean_speedup": geo,
               "stage_totals": stage_totals, "serving": serving}
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=str)
    print(f"# bench json -> {path} (geomean {geo:.2f}x)")
    return payload


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", action="store_true",
                        help="also write benchmarks/artifacts/bench.json "
                             "(per-kernel latency, speedup, cache hit-rate)")
    parser.add_argument("--strategy", default="greedy",
                        choices=("greedy", "beam", "population"),
                        help="search strategy for the optimization runs")
    parser.add_argument("--rounds", type=int, default=5)
    parser.add_argument("--workers", type=int, default=4,
                        help="concurrent candidate evaluations per round "
                             "(beam/population batches)")
    parser.add_argument("--no-evalcache", action="store_true",
                        help="skip the persistent evaluation cache under "
                             "benchmarks/artifacts/evalcache/")
    parser.add_argument("--kernels", default=None,
                        help="comma-separated kernel names, or 'all' for "
                             "every registered kernel (default: the paper's "
                             "three; flash_decode's interpret-mode "
                             "validation adds minutes per genome)")
    parser.add_argument("--isolation", default="thread",
                        choices=("thread", "process"),
                        help="run candidate evaluations in-process (thread) "
                             "or in sandboxed spawn workers (process): "
                             "crashes/hangs cost a worker, never the run")
    parser.add_argument("--resume", action="store_true",
                        help="replay the write-ahead search journals under "
                             "benchmarks/artifacts/journal/ and continue "
                             "from the first unfinished evaluation")
    parser.add_argument("--chaos", action="store_true",
                        help="drill the isolation layer: inject a worker "
                             "kill, an over-deadline hang, and a corrupted "
                             "result (implies --isolation process and "
                             "--no-evalcache)")
    parser.add_argument("--search-only", action="store_true",
                        help="run only the kernel searches (skip paper "
                             "tables, roofline, and serving benches)")
    parser.add_argument("--autotune-spec", action="store_true",
                        help="sweep the speculative-decoding (drafter, k) "
                             "search space against serve_bench tokens/s "
                             "and exit (writes artifacts/"
                             "spec_autotune.json; skips kernel searches)")
    args = parser.parse_args(argv)

    os.makedirs(ART, exist_ok=True)
    if args.autotune_spec:
        autotune_spec()
        return
    from repro.core import optimize_all, registered_kernels
    from repro.search import EvalCache, SearchJournal
    paper = ("merge_attn_states_lse", "fused_add_rmsnorm", "silu_and_mul")
    if args.kernels == "all":
        kernels = registered_kernels()
    elif args.kernels:
        kernels = tuple(args.kernels.split(","))
    else:
        kernels = paper

    pool_config = None
    if args.chaos:
        # chaos quarantines genomes on purpose; never leak those verdicts
        # into the shared persistent evalcache
        args.isolation, args.no_evalcache = "process", True
        from repro.reliability import Fault, SearchChaosInjector
        pool_config = {
            "deadline_s": 10.0,
            "chaos": SearchChaosInjector([Fault("kill_worker", step=1),
                                          Fault("hang_eval", step=3,
                                                seconds=30.0),
                                          Fault("corrupt_result", step=5)]),
        }
    cache = EvalCache(persist_path=None if args.no_evalcache else EVALCACHE)
    if cache.preloaded:
        print(f"# evalcache: preloaded {cache.preloaded} proven evaluations "
              f"from {EVALCACHE}")

    journals = {}
    for k in kernels:
        jpath = os.path.join(JOURNAL_DIR,
                             f"{k}-{args.strategy}-r{args.rounds}.jsonl")
        if not args.resume and os.path.exists(jpath):
            os.remove(jpath)        # fresh run: yesterday's journal is stale
        journals[k] = SearchJournal(jpath)

    results = optimize_all(rounds=args.rounds, strategy=args.strategy,
                           kernels=kernels, cache=cache,
                           workers=args.workers, isolation=args.isolation,
                           pool_config=pool_config, journals=journals,
                           keep_going=True)
    ok_results = {k: v for k, v in results.items()
                  if not isinstance(v, Exception)}
    print("# Search engine — per-search wall-clock, cache, cascade skips")
    for name, log in results.items():
        if isinstance(log, Exception):
            print(f"search/{name},,FAILED="
                  f"{getattr(log, 'detail', repr(log))!r}")
            continue
        c, s = log.meta.get("cache", {}), log.meta.get("stages", {})
        total = c.get("hits", 0) + c.get("misses", 0)
        rate = c.get("hits", 0) / total if total else 0.0
        j = log.meta.get("journal", {})
        print(f"search/{name},{log.meta.get('wall_s', 0.0)*1e6:.0f},"
              f"hit_rate={rate:.2f},"
              f"screened={s.get('screened_infeasible', 0) + s.get('screened_dominated', 0)},"
              f"smoke_fails={s.get('validations_smoke_failed', 0)},"
              f"oracle_computations={s.get('oracle_computations', 0)},"
              f"validation_test_runs={s.get('validation_test_runs', 0)},"
              f"quarantined={s.get('quarantined', 0)},"
              f"recoveries={s.get('recoveries', 0)},"
              f"resumed={j.get('resumed', False)}")
    if args.search_only:
        if args.json:
            bench_json(results, serving=[])
        return
    paper_three = {k: v for k, v in ok_results.items() if k in paper}
    # guard the falsy-empty-dict case: tableX(None-or-empty) would silently
    # re-run three fresh 5-round optimizations, ignoring the CLI flags
    t2 = table2_main(paper_three) if paper_three else []
    t3 = table3_ablation(paper_three) if paper_three else []
    t4 = table4_shapes(paper_three) if paper_three else []
    roofline_table()
    sv = serving_bench()
    with open(os.path.join(ART, "paper_tables.json"), "w") as f:
        json.dump({"table2": t2, "table3": t3, "table4": t4,
                   "serving": sv}, f, indent=2, default=str)
    print(f"# artifacts -> {ART}/paper_tables.json")
    if args.json:
        bench_json(results, serving=sv)


if __name__ == "__main__":
    main()
