"""Benchmark harness — one function per paper table/figure.

    Table 2: baseline vs Astra-optimized kernels (latency, speedup, correct)
    Table 3: single-agent vs multi-agent ablation
    Table 4: per-tensor-shape speedups
    Roofline: the dry-run table (reads benchmarks/artifacts/dryrun/*.json)

Prints ``name,us_per_call,derived`` CSV rows; artifacts are written to
benchmarks/artifacts/. Latencies are analytic TPU-v5e cost-model values
(see DESIGN.md §5 — this host has no TPU); correctness is interpret-mode
Pallas vs the jnp oracles.
"""

from __future__ import annotations

import glob
import json
import os

import numpy as np

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def _hifi():
    from repro.core import ProfilingAgent
    return ProfilingAgent(reps=10**6)


def _eval(space, variant, tests):
    return _hifi().profile(space, variant, tests).geomean_latency_us


def table2_main(results=None, csv=True):
    """Paper Table 2: per-kernel baseline vs optimized (R=5 rounds)."""
    from repro.core import SPACES, TestingAgent, optimize_all
    results = results or optimize_all(rounds=5)
    tester = TestingAgent()
    rows = []
    for i, (name, log) in enumerate(results.items(), 1):
        space = SPACES[name]
        tests = tester.generate_tests(space)
        base = _eval(space, space.baseline, tests)
        best = log.best()
        opt_lat = _eval(space, best.code, tests)
        ok, err = tester.validate(space, best.code, tests)
        rows.append({
            "kernel": name, "paper_kernel": f"K{i}",
            "knobs_base": space.baseline.describe(),
            "knobs_opt": best.code.describe(),
            "time_base_us": base, "time_opt_us": opt_lat,
            "speedup": base / opt_lat, "correct": bool(ok),
            "max_err": err, "rounds": len(log.entries) - 1,
            "trajectory": [e.row() for e in log.entries],
        })
    if csv:
        print("# Table 2 — baseline vs Astra-optimized "
              "(paper: K1 1.26x K2 1.25x K3 1.46x, avg 1.32x)")
        for r in rows:
            print(f"table2/{r['kernel']},{r['time_opt_us']:.3f},"
                  f"speedup={r['speedup']:.2f}x,correct={r['correct']}")
        g = np.exp(np.mean([np.log(r["speedup"]) for r in rows]))
        print(f"table2/geomean,,speedup={g:.2f}x")
    return rows


def table3_ablation(results=None, csv=True):
    """Paper Table 3: single-agent vs multi-agent."""
    from repro.core import (SPACES, TestingAgent, optimize_all,
                            optimize_single_agent)
    results = results or optimize_all(rounds=5)
    tester = TestingAgent()
    rows = []
    for name, log in results.items():
        space = SPACES[name]
        tests = tester.generate_tests(space)
        base = _eval(space, space.baseline, tests)
        ma = _eval(space, log.best().code, tests)
        sa_log = optimize_single_agent(name, rounds=5)
        sa = _eval(space, sa_log.final_variant, tests)
        sa_ok, _ = tester.validate(space, sa_log.final_variant, tests)
        rows.append({"kernel": name, "time_base_us": base,
                     "speedup_sa": base / sa, "speedup_ma": base / ma,
                     "correct_sa": bool(sa_ok), "correct_ma": True})
    if csv:
        print("# Table 3 — single-agent vs multi-agent "
              "(paper: SA 0.73/1.18/1.48 avg 1.08; MA 1.26/1.25/1.46 avg 1.32)")
        for r in rows:
            print(f"table3/{r['kernel']},{r['time_base_us']:.3f},"
                  f"SA={r['speedup_sa']:.2f}x,MA={r['speedup_ma']:.2f}x")
        gs = np.exp(np.mean([np.log(r["speedup_sa"]) for r in rows]))
        gm = np.exp(np.mean([np.log(r["speedup_ma"]) for r in rows]))
        print(f"table3/geomean,,SA={gs:.2f}x,MA={gm:.2f}x")
    return rows


def table4_shapes(results=None, csv=True):
    """Paper Table 4: per-shape baseline/optimized latencies."""
    from repro.core import SPACES, make_inputs, optimize_all
    results = results or optimize_all(rounds=5)
    rows = []
    for name, log in results.items():
        space = SPACES[name]
        best = log.best().code
        for shape in space.suite_shapes:
            t = make_inputs(name, shape, seed=1)
            try:
                base_c = space.cost(space.baseline, **t.shape_info)
                opt_c = space.cost(best, **t.shape_info)
            except Exception:
                continue
            rows.append({"kernel": name, "shape": t.name,
                         "time_base_us": base_c.latency_s * 1e6,
                         "time_opt_us": opt_c.latency_s * 1e6,
                         "speedup": base_c.latency_s / opt_c.latency_s})
    if csv:
        print("# Table 4 — impact of tensor shapes")
        for r in rows:
            print(f"table4/{r['kernel']}{r['shape']},"
                  f"{r['time_opt_us']:.3f},speedup={r['speedup']:.2f}x")
    return rows


def roofline_table(csv=True):
    """§Roofline: aggregate the dry-run artifacts (prefers the post-
    optimization `dryrun_final` sweep; `dryrun` holds the baselines)."""
    src = "dryrun_final" if glob.glob(os.path.join(ART, "dryrun_final",
                                                   "*.json")) else "dryrun"
    rows = []
    for f in sorted(glob.glob(os.path.join(ART, src, "*.json"))):
        rows.append(json.load(open(f)))
    ok = [r for r in rows if r.get("status") == "ok"]
    if csv and ok:
        print("# Roofline — dry-run cells (per-chip roofline step time, us)")
        for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
            print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
                  f"{r['step_ms']*1e3:.0f},dom={r['dominant']},"
                  f"useful={r['useful_flops_ratio']:.2f}")
    elif csv:
        print("# Roofline — no dry-run artifacts yet "
              "(run python -m repro.launch.dryrun --all)")
    return rows


def serving_bench(csv=True):
    """End-to-end serving throughput on the smoke config (CPU wall time —
    a functional benchmark, not a TPU number)."""
    import time
    from repro.launch.serve import run
    t0 = time.perf_counter()
    done = run(requests=4, slots=2, max_new=4, verbose=False)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    if csv:
        print("# Serving — continuous batching functional bench")
        print(f"serving/engine,{dt/max(toks,1)*1e6:.0f},"
              f"tokens={toks},wall_s={dt:.1f}")
    return {"tokens": toks, "seconds": dt}


def main() -> None:
    os.makedirs(ART, exist_ok=True)
    from repro.core import optimize_all
    results = optimize_all(rounds=5)
    t2 = table2_main(results)
    t3 = table3_ablation(results)
    t4 = table4_shapes(results)
    roofline_table()
    sv = serving_bench()
    with open(os.path.join(ART, "paper_tables.json"), "w") as f:
        json.dump({"table2": t2, "table3": t3, "table4": t4,
                   "serving": sv}, f, indent=2, default=str)
    print(f"# artifacts -> {ART}/paper_tables.json")


if __name__ == "__main__":
    main()
