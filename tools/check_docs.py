"""Docs-consistency CI gate: fail on references to files that don't exist.

Scans the repo's prose surfaces —

* ``README.md`` and every ``docs/*.md``
* the module docstrings of ``src/repro/sharding/*.py``,
  ``src/repro/serving/*.py``, and ``src/repro/serving/spec/*.py`` (the
  packages whose docstrings carry cross-references, enforced by the ruff
  ``D`` rules)

— and checks two kinds of reference:

1. **Relative markdown links** ``[text](target)``: the target (anchor
   stripped) must exist relative to the referencing document. External
   schemes (http/https/mailto) and pure-anchor links are skipped.
2. **Backticked path tokens**: a backticked token that looks like a file
   path (path charset, contains ``/`` or ends in ``.md``, and ends with a
   known source extension or a trailing ``/`` for directories) must
   resolve against one of the candidate roots: the document's own
   directory, the repo root, ``src/``, ``src/repro/``, ``docs/``, or
   ``benchmarks/``. Tokens with spaces, globs, or placeholder characters
   (``<arch>``, ``{mix}``) are ignored — this is a linter for *stale*
   references, not a parser.

Known generated paths (``benchmarks/artifacts/...``) are allowed even
when absent, since they only exist after a bench run.

Usage:
    python tools/check_docs.py [--root /path/to/repo] [-v]

Exit status: 0 when every reference resolves, 1 otherwise (one line per
broken reference), 2 on usage errors.
"""

from __future__ import annotations

import argparse
import ast
import os
import re
import sys

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
BACKTICK = re.compile(r"`([^`\n]+)`")
PATH_CHARSET = re.compile(r"^[A-Za-z0-9_.\-/]+$")
SKIP_SCHEMES = ("http://", "https://", "mailto:", "#")
SOURCE_EXTS = (".py", ".md", ".json", ".yml", ".yaml", ".toml", ".txt",
               ".jsonl", ".sh")
# paths produced by running the benchmarks, not committed
GENERATED_PREFIXES = ("benchmarks/artifacts",)

DOCSTRING_GLOBS = ("src/repro/sharding", "src/repro/serving",
                   "src/repro/serving/spec")


def _is_pathlike(token: str) -> bool:
    if not PATH_CHARSET.match(token):
        return False
    if "/" not in token and not token.endswith(".md"):
        return False
    if token.startswith(("-", "/")):        # CLI flags, absolute paths
        return False
    if token.endswith("/"):
        return True
    return token.endswith(SOURCE_EXTS)


def _resolve(token: str, roots: list[str]) -> bool:
    if any(token.startswith(p) for p in GENERATED_PREFIXES):
        return True
    want_dir = token.endswith("/")
    for root in roots:
        cand = os.path.join(root, token)
        if want_dir and os.path.isdir(cand):
            return True
        if not want_dir and os.path.isfile(cand):
            return True
    return False


def _check_text(text: str, *, where: str, own_dir: str,
                repo: str) -> list[str]:
    roots = [own_dir, repo,
             os.path.join(repo, "src"),
             os.path.join(repo, "src", "repro"),
             os.path.join(repo, "docs"),
             os.path.join(repo, "benchmarks")]
    bad = []
    for m in MD_LINK.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP_SCHEMES):
            continue
        target = target.split("#", 1)[0]
        if not target:
            continue
        # markdown links resolve relative to the document only
        if not os.path.exists(os.path.normpath(os.path.join(own_dir,
                                                            target))):
            bad.append(f"{where}: broken link target `{target}`")
    for m in BACKTICK.finditer(text):
        token = m.group(1).strip()
        if not _is_pathlike(token):
            continue
        if not _resolve(token, roots):
            bad.append(f"{where}: backticked path `{token}` "
                       "does not exist")
    return bad


def check(repo: str, verbose: bool = False) -> list[str]:
    """Return a list of broken-reference messages (empty = pass)."""
    bad = []
    docs = [os.path.join(repo, "README.md")]
    docs_dir = os.path.join(repo, "docs")
    if os.path.isdir(docs_dir):
        docs += sorted(os.path.join(docs_dir, f)
                       for f in os.listdir(docs_dir) if f.endswith(".md"))
    n_scanned = 0
    for path in docs:
        if not os.path.isfile(path):
            continue
        n_scanned += 1
        rel = os.path.relpath(path, repo)
        with open(path, encoding="utf-8") as f:
            bad += _check_text(f.read(), where=rel,
                               own_dir=os.path.dirname(path), repo=repo)
    for pkg in DOCSTRING_GLOBS:
        pkg_dir = os.path.join(repo, pkg)
        if not os.path.isdir(pkg_dir):
            continue
        for fname in sorted(os.listdir(pkg_dir)):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(pkg_dir, fname)
            rel = os.path.relpath(path, repo)
            with open(path, encoding="utf-8") as f:
                try:
                    doc = ast.get_docstring(ast.parse(f.read()))
                except SyntaxError as e:
                    bad.append(f"{rel}: unparseable ({e})")
                    continue
            if doc:
                n_scanned += 1
                bad += _check_text(doc, where=f"{rel} (docstring)",
                                   own_dir=os.path.dirname(path),
                                   repo=repo)
    if verbose:
        print(f"# scanned {n_scanned} documents/docstrings under {repo}")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))),
        help="repo root to scan (default: this file's parent repo)")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)
    if not os.path.isdir(args.root):
        print(f"# no such root: {args.root}", file=sys.stderr)
        return 2
    bad = check(args.root, verbose=args.verbose)
    if bad:
        print(f"# DOCS CHECK FAILED ({len(bad)} broken references):")
        for msg in bad:
            print(f"#   {msg}")
        return 1
    print("# docs check: all intra-repo references resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
