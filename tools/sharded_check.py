"""Bit-identity harness: sharded serving vs the single-device engine.

Runs the same request waves through two engines in one process — one
single-device (``mesh=None``), one sharded over a forced-host CPU mesh —
and asserts the token streams are **bitwise identical** and the
deterministic counters (steps, preemptions, prefix hits, CoW copies,
recoveries) agree. This is the executable proof behind the sharded
serving design in ``docs/ARCHITECTURE.md``: every cross-device exchange
is an all-gather, so sharding must not change a single token.

Scenarios:
    greedy     argmax decoding, continuous batching
    sampling   seeded temperature/top-k/top-p sampling
    preempt    oversubscribed paged pool forcing swap preemption
    prefix     radix prefix-cache hits across two request waves
    chaos      injected device fault + swap-restore recovery

Usage (the XLA flag is self-applied when the module is imported first):
    python tools/sharded_check.py --arch qwen3-8b --mesh 2,2 --json
    python tools/sharded_check.py --arch qwen2-0.5b --mesh 1,4
"""

from __future__ import annotations

import os
import sys


def _want_devices(default: int = 4) -> int:
    for i, a in enumerate(sys.argv):
        if a == "--devices" and i + 1 < len(sys.argv):
            return int(sys.argv[i + 1])
        if a.startswith("--devices="):
            return int(a.split("=", 1)[1])
    return default


# Must run before jax is imported anywhere in this process.
if "jax" not in sys.modules and "--xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_want_devices()}"
    ).strip()

import argparse  # noqa: E402
import json  # noqa: E402
from pathlib import Path  # noqa: E402

_SRC = Path(__file__).resolve().parents[1] / "src"
if str(_SRC) not in sys.path:
    sys.path.insert(0, str(_SRC))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import configs  # noqa: E402
from repro.models import registry  # noqa: E402
from repro.reliability import Fault  # noqa: E402
from repro.serving import (ChaosInjector, LLMEngine,  # noqa: E402
                           SamplingParams)

SCENARIOS = ("greedy", "sampling", "preempt", "prefix", "chaos")

# deterministic counters that must agree between the two engines
COMPARE = ("steps", "readbacks", "prefill_compiles", "preemptions",
           "sched_reorders", "prefix_hit_tokens", "cow_copies",
           "recoveries", "aborted", "failed")


def _prompts(cfg, rng, n, lo=4, hi=16):
    return [rng.integers(0, cfg.vocab, (int(rng.integers(lo, hi + 1)),),
                         dtype=np.int32) for _ in range(n)]


def _streams(outs):
    return [(o.rid, o.finish_reason, list(map(int, o.tokens)))
            for o in outs]


def run_scenario(name: str, cfg, params, mesh):
    """One engine, one scenario; returns (streams, stats)."""
    kw = dict(slots=4, max_seq=128)
    chaos = None
    if name == "chaos":
        chaos = ChaosInjector([Fault(kind="device_fault", step=7, slot=1)])
    if name == "preempt":
        kw.update(max_seq=96, num_pages=10)
    llm = LLMEngine(params, cfg, mesh=mesh, chaos=chaos, **kw)
    rng = np.random.default_rng(0)
    sp = None
    if name == "sampling":
        sp = SamplingParams(temperature=0.8, top_k=5, top_p=0.9)
    if name == "prefix":
        # wave 1 caches the base prompt's pages in the radix tree; wave 2
        # shares a 32-token (2-page) prefix and must hit it
        base = rng.integers(0, cfg.vocab, (48,), dtype=np.int32)
        streams = _streams(llm.generate([base], sp, max_new_tokens=8))
        tails = [rng.integers(0, cfg.vocab, (6,), dtype=np.int32)
                 for _ in range(3)]
        wave2 = [np.concatenate([base[:32], t]) for t in tails]
        streams += _streams(llm.generate(wave2, sp, max_new_tokens=8))
        return streams, llm.stats()
    if name == "preempt":
        prompts = _prompts(cfg, rng, 6, lo=24, hi=40)
        outs = llm.generate(prompts, sp, max_new_tokens=16)
    else:
        prompts = _prompts(cfg, rng, 6)
        outs = llm.generate(prompts, sp, max_new_tokens=8)
    return _streams(outs), llm.stats()


def check(arch: str, mesh_shape, scenarios=SCENARIOS) -> dict:
    """Run every scenario twice (single-device, sharded) and compare."""
    cfg = configs.smoke(arch)
    params, _ = registry.init(cfg, jax.random.PRNGKey(0))
    mesh = jax.make_mesh(tuple(mesh_shape), ("data", "model"))
    report = {"arch": arch, "mesh": list(mesh_shape), "scenarios": {},
              "ok": True}
    for name in scenarios:
        base_streams, base_stats = run_scenario(name, cfg, params, None)
        sh_streams, sh_stats = run_scenario(name, cfg, params, mesh)
        report.setdefault("plan", sh_stats.get("mesh"))
        notes = []
        if base_streams != sh_streams:
            notes.append("token streams differ")
        for k in COMPARE:
            if base_stats.get(k, 0) != sh_stats.get(k, 0):
                notes.append(f"{k}: single={base_stats.get(k, 0)} "
                             f"sharded={sh_stats.get(k, 0)}")
        for label, s in (("single", base_stats), ("sharded", sh_stats)):
            if s["readbacks"] != s["steps"]:
                notes.append(f"{label}: {s['readbacks']} readbacks != "
                             f"{s['steps']} steps")
        if name == "preempt" and base_stats.get("preemptions", 0) == 0:
            notes.append("scenario forced no preemption")
        if name == "prefix" and base_stats.get("prefix_hit_tokens", 0) == 0:
            notes.append("scenario produced no prefix-cache hit")
        if name == "chaos" and base_stats.get("recoveries", 0) != 1:
            notes.append(f"expected 1 recovery, got "
                         f"{base_stats.get('recoveries', 0)}")
        ok = not notes
        report["scenarios"][name] = {
            "ok": ok, "streams_match": base_streams == sh_streams,
            "steps": base_stats["steps"],
            "counters": {k: base_stats.get(k, 0) for k in COMPARE},
            "notes": notes}
        report["ok"] = report["ok"] and ok
    return report


def main():
    ap = argparse.ArgumentParser(
        description="sharded-vs-single-device bit-identity check")
    ap.add_argument("--arch", default="qwen3-8b",
                    choices=list(configs.ARCH_IDS))
    ap.add_argument("--mesh", default="2,2",
                    help="data,model axis sizes (e.g. 2,2 or 1,4)")
    ap.add_argument("--devices", type=int, default=4,
                    help="forced host device count (set before jax init)")
    ap.add_argument("--scenarios", default=None,
                    help=f"comma list from {','.join(SCENARIOS)}")
    ap.add_argument("--json", action="store_true", dest="as_json")
    args = ap.parse_args()
    mesh_shape = tuple(int(x) for x in args.mesh.split(","))
    scenarios = tuple(args.scenarios.split(",")) if args.scenarios \
        else SCENARIOS
    report = check(args.arch, mesh_shape, scenarios)
    if args.as_json:
        print(json.dumps(report, indent=2))
    else:
        print(f"{args.arch} on mesh {mesh_shape} plan={report.get('plan')}")
        for name, r in report["scenarios"].items():
            mark = "ok" if r["ok"] else "FAIL " + "; ".join(r["notes"])
            print(f"  {name:<10} streams_match={r['streams_match']} "
                  f"steps={r['steps']} -> {mark}")
        print("bit-identical" if report["ok"] else "MISMATCH")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
