"""Decorator-based kernel registry — each kernel module declares its own
optimization space.

Historically ``repro.core.variants`` hand-maintained one ``SPACES`` dict
that knew every kernel's run/oracle/cost wiring.  That made adding a kernel
a two-file edit and coupled the agent core to every kernel module.  Now the
space definition lives next to the kernel it describes::

    from repro.kernels.registry import (KernelSpace, Knob,
                                        register_kernel_space)

    @register_kernel_space
    def _space() -> KernelSpace:
        return KernelSpace(name="my_kernel", baseline=BASELINE, ...)

``repro.kernels.__init__`` imports every kernel module, so importing the
package populates the registry; lookups (``get_space`` / the ``SPACES``
mapping view) trigger that import lazily so standalone consumers never see
an empty registry.  ``repro.core.variants`` re-exports everything here as a
back-compat shim.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Iterator, Mapping

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Knob:
    """One legal move in the optimization space."""
    name: str
    kind: str                       # "pow2" | "bool"
    lo: int = 8                     # pow2 bounds
    hi: int = 1024
    # which roofline terms this knob attacks; the planning agent matches
    # knobs against the dominant term of the profile. A knob that removes a
    # whole pass attacks both memory (traffic) and overhead (launch).
    attacks: tuple = ("memory",)    # of "memory" | "compute" | "overhead"
    # For bool knobs: the catalog-optimized direction (paper §5.3). The
    # planning agent only ever moves TOWARD the target; knobs whose baseline
    # already sits at the target (e.g. fuse_s_out) are ablation-only.
    target: Any = None
    note: str = ""


@dataclasses.dataclass(frozen=True)
class TestCase:
    """One element of the test suite T (paper §3.1)."""
    name: str
    args: tuple                     # positional args to run_fn / oracle
    shape_info: dict                # kwargs for the cost function


TestCase.__test__ = False           # keep pytest from collecting it


@dataclasses.dataclass(frozen=True)
class KernelSpace:
    name: str
    baseline: Any
    run: Callable[..., Any]         # run(variant, *args, interpret=...)
    oracle: Callable[..., Any]
    cost: Callable[..., Any]        # cost(variant, **shape_info)
    knobs: tuple[Knob, ...]
    # shapes the TESTING agent draws the suite from (LLaMA-family dims per
    # paper §4); values are generator kwargs, see agents.TestingAgent.
    suite_shapes: tuple[dict, ...]
    # materializes one TestCase: make_inputs(shape, *, dtype, seed)
    make_inputs: Callable[..., TestCase] | None = None
    # the shipped tuned variant (``ops`` dispatch default); falls back to
    # ``baseline`` when a kernel has no pre-tuned genome.
    default: Any = None

    def mutate(self, variant, knob: Knob, value) -> Any:
        new = dataclasses.replace(variant, **{knob.name: value})
        # name = genome digest, not lineage (lineage lives in the Log)
        return dataclasses.replace(new, name=f"{self.name}@{knob.name}={value}")

    @property
    def shipped(self) -> Any:
        return self.default if self.default is not None else self.baseline


_REGISTRY: dict[str, KernelSpace] = {}


def register_kernel_space(obj):
    """Register a ``KernelSpace`` — usable as ``@register_kernel_space`` on
    a zero-arg factory function, or called directly on a space instance.

    Returns the registered ``KernelSpace`` (so a decorated factory's module
    attribute *is* the space). Duplicate names are an error: spaces register
    at module import, so a collision means two modules claim one kernel.
    """
    space = obj if isinstance(obj, KernelSpace) else obj()
    if not isinstance(space, KernelSpace):
        raise TypeError(f"register_kernel_space expected a KernelSpace or a "
                        f"factory returning one, got {type(space).__name__}")
    if space.name in _REGISTRY:
        raise ValueError(f"kernel space {space.name!r} is already registered")
    _REGISTRY[space.name] = space
    return space


def _populate() -> None:
    # Importing the package imports every kernel module, each of which
    # registers its space as a side effect.
    import repro.kernels  # noqa: F401


def get_space(name: str) -> KernelSpace:
    _populate()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"no kernel space named {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def registered_kernels() -> tuple[str, ...]:
    _populate()
    return tuple(sorted(_REGISTRY))


class _SpacesView(Mapping):
    """Read-only dict-compatible view of the registry (legacy ``SPACES``)."""

    def __getitem__(self, name: str) -> KernelSpace:
        return get_space(name)

    def __iter__(self) -> Iterator[str]:
        _populate()
        return iter(_REGISTRY)

    def __len__(self) -> int:
        _populate()
        return len(_REGISTRY)

    def __repr__(self) -> str:
        _populate()
        return f"SPACES({sorted(_REGISTRY)})"


SPACES: Mapping[str, KernelSpace] = _SpacesView()


def make_inputs(kernel: str, shape: dict, *, dtype=jnp.float32,
                seed: int = 0) -> TestCase:
    """Materialize one test case for a registered kernel from a shape spec."""
    space = get_space(kernel)
    if space.make_inputs is None:
        raise NotImplementedError(f"kernel {kernel!r} registered no "
                                  "make_inputs generator")
    return space.make_inputs(shape, dtype=dtype, seed=seed)


# -- suite / oracle memoization ----------------------------------------------
#
# Test suites and oracle outputs depend only on (kernel, suite shapes, data
# seed, dtypes) — never on the genome under evaluation — yet historically
# every search and every benchmark table regenerated both per call (per
# *genome* per test, for the oracle). These module-level memos make them
# once-per-suite; the tiered evaluator and ``benchmarks/run.py`` both read
# through them. ``clear_suite_memos()`` drops the (unbounded) memo arrays.

_SUITE_MEMO: dict[tuple, tuple] = {}
_ORACLE_MEMO: dict[tuple, tuple] = {}
_MEMO_LOCK = threading.Lock()          # guards the memo/lock dicts only
_ORACLE_KEY_LOCKS: dict[tuple, threading.Lock] = {}


def suite_key(space: KernelSpace, testing) -> tuple:
    """Identity of a generated suite: kernel + its shape spec + the testing
    agent's class (a subclass may override ``generate_tests``), data seed,
    and dtype roster (see ``TestingAgent.generate_tests``)."""
    cls = type(testing)
    return (space.name, repr(space.suite_shapes),
            f"{cls.__module__}.{cls.__qualname__}",
            getattr(testing, "seed", None),
            tuple(str(jnp.dtype(d)) for d in getattr(testing, "dtypes", ())))


def suite_tests(space: KernelSpace, testing) -> list[TestCase]:
    """Memoized ``testing.generate_tests(space)``; one generation per
    (kernel, shapes, seed, dtypes) process-wide."""
    key = suite_key(space, testing)
    with _MEMO_LOCK:
        hit = _SUITE_MEMO.get(key)
    if hit is not None:
        return list(hit)
    tests = testing.generate_tests(space)
    with _MEMO_LOCK:
        _SUITE_MEMO.setdefault(key, tuple(tests))
    return list(tests)


def oracle_outputs(space: KernelSpace, tests, *, digest: str) -> tuple[tuple, bool]:
    """Memoized oracle outputs aligned with ``tests``, keyed by (kernel,
    suite digest). Returns ``(outputs, computed)`` where ``computed`` is
    True when this call paid for the oracle run (callers meter oracle work
    with it).

    Locking is per key: racing evaluators of the SAME (kernel, suite)
    still compute the oracle exactly once, but evaluators of different
    kernels no longer serialize on one kernel's oracle run (historically
    the computation held the single global memo lock)."""
    key = (space.name, digest)
    with _MEMO_LOCK:
        hit = _ORACLE_MEMO.get(key)
        if hit is not None:
            return hit, False
        key_lock = _ORACLE_KEY_LOCKS.setdefault(key, threading.Lock())
    with key_lock:
        with _MEMO_LOCK:
            hit = _ORACLE_MEMO.get(key)
        if hit is not None:
            return hit, False
        outs = tuple(space.oracle(*t.args) for t in tests)
        with _MEMO_LOCK:
            _ORACLE_MEMO[key] = outs
        return outs, True


def clear_suite_memos() -> None:
    """Drop all memoized suites and oracle outputs (frees the arrays)."""
    with _MEMO_LOCK:
        _SUITE_MEMO.clear()
        _ORACLE_MEMO.clear()
        _ORACLE_KEY_LOCKS.clear()
