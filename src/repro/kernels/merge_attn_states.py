"""Paper Kernel 1 — ``merge_attn_states_lse`` as a Pallas TPU kernel.

Semantics (paper Table 1):

    V_out = (e^{S_a} V_a + e^{S_b} V_b) / (e^{S_a} + e^{S_b})
    S_out = log(e^{S_a} + e^{S_b})

The CUDA optimization story (paper §5.3, Fig. 2) is **loop-invariant
hoisting**: the baseline recomputes the mixing weights (two exps, one
divide) for every element of the output vector; the optimized version
computes them once per output row. The TPU adaptation (DESIGN.md §2):

  * ``hoist`` — baseline (False) broadcasts the scores across the whole
    ``[rows, head_dim]`` tile and evaluates exp/divide *element-wise on the
    tile* (head_dim× more VPU transcendental work — the exact analogue of
    recomputing in the inner loop). The optimized variant (True) evaluates
    exp/reciprocal on the ``[rows, 1]`` score column only and broadcasts the
    two cheap scalars into the multiply-add.
  * ``use_reciprocal`` — ``inv = rcp(denom)`` then two multiplies, vs two
    divides (fast-math analogue of ``__frcp_rn``).
  * ``block_rows`` — VMEM tile height (grid sizing / occupancy analogue).
  * ``fuse_s_out`` — compute S_out in the same kernel instance (single HBM
    trip) vs a separate elementwise pass (baseline mirrors SGLang's fused
    form, so both default True; kept as an ablation knob).

Layout note: scores are carried as ``[rows, 1]`` fp32 columns. Mosaic pads
the lane dimension internally; the cost model charges that padding waste,
which is how the planning agent "sees" the layout pressure.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import _common, ref
from repro.kernels._common import pad_rows, round_up, sublane_for
from repro.kernels.registry import (KernelSpace, Knob, TestCase,
                                    register_kernel_space)


@dataclasses.dataclass(frozen=True)
class MergeVariant:
    """Genome for merge_attn_states_lse (the space Astra searches)."""
    name: str = "baseline"
    block_rows: int = 16
    hoist: bool = False
    use_reciprocal: bool = False
    fuse_s_out: bool = True

    def describe(self) -> str:
        return (f"{self.name}: rows={self.block_rows} hoist={self.hoist} "
                f"rcp={self.use_reciprocal} fuse_s={self.fuse_s_out}")


# Literal-port baseline: one row-block per grid step (the CUDA kernel's
# one-thread-block-per-row structure) and per-element weight recompute.
BASELINE = MergeVariant()
OPTIMIZED = MergeVariant(
    name="astra_opt", block_rows=32, hoist=True, use_reciprocal=True)


def _weights(sa, sb, *, use_reciprocal):
    """LSE mixing weights + merged score. Shapes follow the inputs."""
    m = jnp.maximum(sa, sb)
    m_safe = jnp.where(jnp.isneginf(m), 0.0, m)
    wa = jnp.exp(sa - m_safe)
    wb = jnp.exp(sb - m_safe)
    denom = wa + wb
    if use_reciprocal:
        inv = jnp.where(denom > 0, _common.reciprocal(denom, approx=False),
                        0.0)
    else:
        inv = jnp.where(denom > 0, 1.0 / denom, 0.0)
    return wa * inv, wb * inv, m + jnp.log(denom)


def _kernel(va_ref, sa_ref, vb_ref, sb_ref, vo_ref, so_ref, *,
            hoist, use_reciprocal, fuse_s_out):
    va = va_ref[...].astype(jnp.float32)
    vb = vb_ref[...].astype(jnp.float32)
    sa = sa_ref[...].astype(jnp.float32)   # [br, 1]
    sb = sb_ref[...].astype(jnp.float32)

    if hoist:
        # Optimized: weights computed once per row ([br, 1]), broadcast into
        # a lightweight multiply-add over the [br, head_dim] tile.
        a, b, s_out = _weights(sa, sb, use_reciprocal=use_reciprocal)
        vo = a * va + b * vb
    else:
        # Baseline: the CUDA inner loop recomputed exp/div per element; the
        # tile analogue evaluates the transcendentals on the broadcast
        # [br, head_dim] score tiles — head_dim× the VPU work.
        d = va.shape[-1]
        sa_t = jnp.broadcast_to(sa, (sa.shape[0], d))
        sb_t = jnp.broadcast_to(sb, (sb.shape[0], d))
        a_t, b_t, s_t = _weights(sa_t, sb_t, use_reciprocal=use_reciprocal)
        vo = a_t * va + b_t * vb
        s_out = s_t[:, :1]
    vo_ref[...] = vo.astype(vo_ref.dtype)
    if fuse_s_out:
        so_ref[...] = s_out.astype(so_ref.dtype)
    else:
        # Unfused ablation: S_out written by a separate pass; this instance
        # writes a placeholder that the second pass overwrites.
        so_ref[...] = jnp.zeros_like(so_ref)


def _s_out_kernel(sa_ref, sb_ref, so_ref):
    sa = sa_ref[...].astype(jnp.float32)
    sb = sb_ref[...].astype(jnp.float32)
    m = jnp.maximum(sa, sb)
    m_safe = jnp.where(jnp.isneginf(m), 0.0, m)
    so = m + jnp.log(jnp.exp(sa - m_safe) + jnp.exp(sb - m_safe))
    so_ref[...] = so.astype(so_ref.dtype)


def merge_attn_states_lse(v_a: jax.Array, s_a: jax.Array,
                          v_b: jax.Array, s_b: jax.Array,
                          variant: MergeVariant = OPTIMIZED, *,
                          interpret: bool = False):
    """Merge two partial attention states. Returns ``(v_out, s_out)``.

    Accepts ``v: [..., head_dim]``, ``s: [...]`` (any leading shape, e.g.
    ``[seq, heads]``); computation runs on the flattened row view.
    """
    lead_shape = s_a.shape
    d = v_a.shape[-1]
    va2 = v_a.reshape(-1, d)
    vb2 = v_b.reshape(-1, d)
    sa2 = s_a.reshape(-1, 1).astype(jnp.float32)
    sb2 = s_b.reshape(-1, 1).astype(jnp.float32)
    n = va2.shape[0]

    sl = sublane_for(v_a.dtype)
    br = max(sl, (min(variant.block_rows, max(n, 1)) // sl) * sl) if n >= sl else max(n, 1)
    va2, n_pad = pad_rows(va2, br)
    vb2, _ = pad_rows(vb2, br)
    sa2, _ = pad_rows(sa2, br)
    sb2, _ = pad_rows(sb2, br)
    grid = (n_pad // br,)

    v_spec = pl.BlockSpec((br, d), lambda i: (i, 0))
    s_spec = pl.BlockSpec((br, 1), lambda i: (i, 0))

    kern = functools.partial(_kernel, hoist=variant.hoist,
                             use_reciprocal=variant.use_reciprocal,
                             fuse_s_out=variant.fuse_s_out)
    v_out, s_out = pl.pallas_call(
        kern, grid=grid,
        in_specs=[v_spec, s_spec, v_spec, s_spec],
        out_specs=[v_spec, s_spec],
        out_shape=[jax.ShapeDtypeStruct((n_pad, d), v_a.dtype),
                   jax.ShapeDtypeStruct((n_pad, 1), jnp.float32)],
        interpret=interpret,
    )(va2, sa2, vb2, sb2)

    if not variant.fuse_s_out:
        s_out = pl.pallas_call(
            _s_out_kernel, grid=grid,
            in_specs=[s_spec, s_spec],
            out_specs=s_spec,
            out_shape=jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
            interpret=interpret,
        )(sa2, sb2)

    v_out = v_out[:n].reshape(*lead_shape, d)
    s_out = s_out[:n, 0].reshape(lead_shape).astype(s_a.dtype)
    return v_out, s_out


def cost(variant: MergeVariant, *, rows: int, d: int, dtype):
    """Analytic v5e cost of this variant on ``v: [rows, d]``, ``s: [rows]``."""
    from repro.core import costmodel as cm

    item = jnp.dtype(dtype).itemsize
    sl = sublane_for(dtype)
    br = max(sl, (min(variant.block_rows, max(rows, 1)) // sl) * sl) \
        if rows >= sl else max(rows, 1)
    n_pad = round_up(rows, br)
    steps = n_pad // br
    ops = cm.OP

    # weight math: max, 2 exps, add, divide-or-rcp, 2 muls, log (s_out)
    weight_ops = (ops["max"] + 2 * ops["exp"] + ops["add"]
                  + (ops["rcp"] if variant.use_reciprocal else ops["div"])
                  + 2 * ops["mul"] + ops["log"] + 2 * ops["cmp"])
    mad_ops = 2 * ops["mul"] + ops["add"]  # a*va + b*vb
    cast = 3 * ops["cast"] if item < 4 else 0

    if variant.hoist:
        vpu = rows * (weight_ops + d * (mad_ops + cast))
    else:
        vpu = rows * d * (weight_ops + mad_ops + cast)

    # traffic: v_a, v_b read; v_out write; scores are narrow [rows,1] fp32
    # columns — charged with DMA-granule padding waste.
    v_bytes = 3 * rows * d * item
    s_logical, s_waste = cm.dma_bytes(3 * rows * 4, 4)
    pad_waste = (n_pad - rows) * d * item * 3

    main = cm.Cost(
        hbm_bytes=v_bytes + s_logical,
        vpu_ops=vpu,
        grid_steps=steps, n_calls=1,
        vmem_bytes=br * d * 3 * 4 + br * 128 * 3 * 4,
        align_waste_bytes=pad_waste + s_waste)
    costs = [main]
    if not variant.fuse_s_out:
        s2_logical, s2_waste = cm.dma_bytes(3 * rows * 4, 4)
        costs.append(cm.Cost(
            hbm_bytes=s2_logical, vpu_ops=rows * weight_ops,
            grid_steps=steps, n_calls=1, vmem_bytes=br * 128 * 3 * 4,
            align_waste_bytes=s2_waste))
    total = cm.combine(costs)
    total.validate()
    return total


reference = ref.merge_attn_states_lse


SUITE_SHAPES = ({"seq": 512, "heads": 32, "head_dim": 256},
                {"seq": 512, "heads": 40, "head_dim": 128},
                {"seq": 768, "heads": 32, "head_dim": 256},
                {"seq": 512, "heads": 64, "head_dim": 128},
                {"seq": 100, "heads": 7, "head_dim": 128})


def make_inputs(shape: dict, *, dtype=jnp.float32, seed: int = 0) -> TestCase:
    s, h, d = shape["seq"], shape["heads"], shape["head_dim"]
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    va = jax.random.normal(ks[0], (s, h, d), dtype=dtype)
    vb = jax.random.normal(ks[1], (s, h, d), dtype=dtype)
    # scores with wide dynamic range + empty partitions (-inf)
    sa = jax.random.normal(ks[2], (s, h)) * 8.0
    sb = jax.random.normal(ks[3], (s, h)) * 8.0
    sb = jnp.where(jax.random.uniform(ks[4], (s, h)) < 0.05, -jnp.inf, sb)
    return TestCase(f"[{s},{h},{d}]", (va, sa, vb, sb),
                    {"rows": s * h, "d": d, "dtype": dtype})


def _run(variant, va, sa, vb, sb, *, interpret=True):
    return merge_attn_states_lse(va, sa, vb, sb, variant, interpret=interpret)


@register_kernel_space
def _space() -> KernelSpace:
    return KernelSpace(
        name="merge_attn_states_lse",
        baseline=BASELINE,
        default=OPTIMIZED,
        run=_run,
        oracle=reference,
        cost=cost,
        knobs=(
            Knob("block_rows", "pow2", 8, 2048, attacks=("overhead",)),
            Knob("hoist", "bool", attacks=("compute",), target=True,
                 note="hoist LSE weights out of the element dimension "
                      "(loop-invariant hoisting, paper Fig. 2)"),
            Knob("use_reciprocal", "bool", attacks=("compute",), target=True),
            Knob("fuse_s_out", "bool", attacks=("memory", "overhead"),
                 target=True,
                 note="compute S_out in the same pass"),
        ),
        suite_shapes=SUITE_SHAPES,
        make_inputs=make_inputs,
    )
