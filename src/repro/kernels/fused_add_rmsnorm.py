"""Paper Kernel 2 — ``fused_add_rmsnorm`` as a Pallas TPU kernel.

The CUDA optimization story (paper §5.3, Fig. 3) is a reduction-strategy
change: shared-memory tree reduction → register-resident warp-shuffle
reduction with a short shared-memory finalize. TPUs have no warps or shared
memory; the idiomatic equivalent (DESIGN.md §2) is the *reduction layout*:

  * ``two_pass``   — baseline: pass 1 reduces each row block to a partial
    sum-of-squares written back to HBM scratch; pass 2 re-reads the rows and
    normalizes. Mirrors the extra round-trips of the tree reduction.
  * one-pass (``two_pass=False``) — the whole row lives in VMEM; the
    sum-of-squares is a single lane-axis ``jnp.sum`` that Mosaic lowers to
    the VPU reduction tree (the register-resident shuffle analogue), and the
    normalize happens in the same kernel instance — one HBM round trip.
  * ``use_rsqrt``  — ``rsqrt`` intrinsic vs ``1/sqrt`` (div + sqrt), the
    fast-math analogue.
  * ``accum_fp32`` — fp32 accumulation of the squares (safe default).
  * ``block_rows`` — rows per grid step (VMEM tile height).

Contract (SGLang): ``r' = x + r``; ``y = r' * rsqrt(mean(r'^2) + eps) * w``;
returns ``(y, r')``.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref
from repro.kernels._common import pad_rows, round_up, sublane_for
from repro.kernels.registry import (KernelSpace, Knob, TestCase,
                                    register_kernel_space)


@dataclasses.dataclass(frozen=True)
class RmsNormVariant:
    name: str = "baseline"
    block_rows: int = 16
    two_pass: bool = True
    use_rsqrt: bool = False
    accum_fp32: bool = True

    def describe(self) -> str:
        return (f"{self.name}: rows={self.block_rows} two_pass={self.two_pass} "
                f"rsqrt={self.use_rsqrt} fp32={self.accum_fp32}")


# Literal-port baseline: one row-block per grid step + the two-pass
# reduction structure of the CUDA shared-memory tree (extra HBM round trip).
BASELINE = RmsNormVariant()
OPTIMIZED = RmsNormVariant(
    name="astra_opt", block_rows=16, two_pass=False, use_rsqrt=True)


def _norm_from_rows(r, w, eps, *, use_rsqrt, accum_fp32, out_dtype):
    rf = r.astype(jnp.float32) if accum_fp32 else r
    var = jnp.mean(jnp.square(rf), axis=-1, keepdims=True)
    if use_rsqrt:
        scale = jax.lax.rsqrt(var + eps)
    else:
        scale = 1.0 / jnp.sqrt(var + eps)
    y = rf * scale * w.astype(rf.dtype)
    return y.astype(out_dtype)


def _one_pass_kernel(x_ref, res_ref, w_ref, y_ref, res_out_ref, *,
                     eps, use_rsqrt, accum_fp32):
    x = x_ref[...]
    res = res_ref[...]
    r = (x.astype(jnp.float32) + res.astype(jnp.float32)) if accum_fp32 \
        else (x + res)
    res_out_ref[...] = r.astype(res_out_ref.dtype)
    y_ref[...] = _norm_from_rows(r, w_ref[...], eps, use_rsqrt=use_rsqrt,
                                 accum_fp32=accum_fp32, out_dtype=y_ref.dtype)


def _pass1_kernel(x_ref, res_ref, sumsq_ref, res_out_ref, *, accum_fp32):
    x = x_ref[...]
    res = res_ref[...]
    r = (x.astype(jnp.float32) + res.astype(jnp.float32)) if accum_fp32 \
        else (x + res)
    res_out_ref[...] = r.astype(res_out_ref.dtype)
    ss = jnp.sum(jnp.square(r.astype(jnp.float32)), axis=-1, keepdims=True)
    sumsq_ref[...] = jnp.broadcast_to(ss, sumsq_ref.shape)


def _pass2_kernel(r_ref, sumsq_ref, w_ref, y_ref, *, eps, d, use_rsqrt):
    r = r_ref[...].astype(jnp.float32)
    var = sumsq_ref[...][:, :1] / d
    if use_rsqrt:
        scale = jax.lax.rsqrt(var + eps)
    else:
        scale = 1.0 / jnp.sqrt(var + eps)
    y = r * scale * w_ref[...].astype(jnp.float32)
    y_ref[...] = y.astype(y_ref.dtype)


def fused_add_rmsnorm(x: jax.Array, residual: jax.Array, weight: jax.Array,
                      eps: float = 1e-6,
                      variant: RmsNormVariant = OPTIMIZED, *,
                      interpret: bool = False):
    """Fused residual-add + RMSNorm. Returns ``(y, new_residual)``."""
    orig_shape = x.shape
    d = orig_shape[-1]
    x2 = x.reshape(-1, d)
    r2 = residual.reshape(-1, d)
    n = x2.shape[0]

    sl = sublane_for(x.dtype)
    br = max(sl, (min(variant.block_rows, max(n, 1)) // sl) * sl) if n >= sl else max(n, 1)
    x2, n_pad = pad_rows(x2, br)
    r2, _ = pad_rows(r2, br)
    grid = (n_pad // br,)
    w2 = weight.reshape(1, d)

    row_spec = pl.BlockSpec((br, d), lambda i: (i, 0))
    w_spec = pl.BlockSpec((1, d), lambda i: (0, 0))

    if not variant.two_pass:
        kern = functools.partial(_one_pass_kernel, eps=eps,
                                 use_rsqrt=variant.use_rsqrt,
                                 accum_fp32=variant.accum_fp32)
        y, res_out = pl.pallas_call(
            kern, grid=grid,
            in_specs=[row_spec, row_spec, w_spec],
            out_specs=[row_spec, row_spec],
            out_shape=[jax.ShapeDtypeStruct((n_pad, d), x.dtype),
                       jax.ShapeDtypeStruct((n_pad, d), x.dtype)],
            interpret=interpret,
        )(x2, r2, w2)
    else:
        # Baseline: two HBM round trips (reduce, then normalize).
        sum_spec = pl.BlockSpec((br, 128), lambda i: (i, 0))
        kern1 = functools.partial(_pass1_kernel, accum_fp32=variant.accum_fp32)
        sumsq, res_out = pl.pallas_call(
            kern1, grid=grid,
            in_specs=[row_spec, row_spec],
            out_specs=[sum_spec, row_spec],
            out_shape=[jax.ShapeDtypeStruct((n_pad, 128), jnp.float32),
                       jax.ShapeDtypeStruct((n_pad, d), x.dtype)],
            interpret=interpret,
        )(x2, r2)
        kern2 = functools.partial(_pass2_kernel, eps=eps, d=float(d),
                                  use_rsqrt=variant.use_rsqrt)
        y = pl.pallas_call(
            kern2, grid=grid,
            in_specs=[row_spec, sum_spec, w_spec],
            out_specs=row_spec,
            out_shape=jax.ShapeDtypeStruct((n_pad, d), x.dtype),
            interpret=interpret,
        )(res_out, sumsq, w2)

    y = y[:n].reshape(orig_shape)
    res_out = res_out[:n].reshape(orig_shape)
    return y, res_out


def cost(variant: RmsNormVariant, *, rows: int, d: int, dtype):
    """Analytic v5e cost of this variant on ``[rows, d]`` inputs."""
    from repro.core import costmodel as cm

    item = jnp.dtype(dtype).itemsize
    sl = sublane_for(dtype)
    br = max(sl, (min(variant.block_rows, max(rows, 1)) // sl) * sl) \
        if rows >= sl else max(rows, 1)
    n_pad = round_up(rows, br)
    steps = n_pad // br
    ops = cm.OP

    # shared per-element work: add residual, square+accumulate, scale*w
    el_add = ops["add"] + (2 * ops["cast"] if variant.accum_fp32 and item < 4 else 0)
    el_sq = ops["fma"]
    el_scale = 2 * ops["mul"] + (ops["cast"] if item < 4 else 0)
    per_row_scalar = (ops["rsqrt"] if variant.use_rsqrt
                      else ops["sqrt"] + ops["div"]) + ops["add"]
    pad_waste = (n_pad - rows) * d * item * 4

    if not variant.two_pass:
        c = cm.Cost(
            hbm_bytes=(2 * rows * d + d) * item + 2 * rows * d * item,
            vpu_ops=rows * d * (el_add + el_sq + el_scale) + rows * per_row_scalar,
            grid_steps=steps, n_calls=1,
            vmem_bytes=br * d * 4 * 4,  # x, res, y, res_out blocks (fp32 compute)
            align_waste_bytes=pad_waste)
        c.validate()
        return c

    # two-pass: pass 1 reads x+res, writes res'+sumsq; pass 2 re-reads res',
    # reads sumsq, writes y — the CUDA tree-reduction's extra traffic analogue.
    p1 = cm.Cost(
        hbm_bytes=(2 * rows * d + rows * 128) * item + rows * d * item,
        vpu_ops=rows * d * (el_add + el_sq),
        grid_steps=steps, n_calls=1, vmem_bytes=br * d * 3 * 4,
        align_waste_bytes=pad_waste / 2 + rows * 127 * 4)  # sumsq lane pad
    p2 = cm.Cost(
        hbm_bytes=(rows * d + rows * 128 + d) * item + rows * d * item,
        vpu_ops=rows * d * el_scale + rows * per_row_scalar,
        grid_steps=steps, n_calls=1, vmem_bytes=br * d * 2 * 4,
        align_waste_bytes=pad_waste / 2)
    total = cm.combine([p1, p2])
    total.validate()
    return total


reference = ref.fused_add_rmsnorm


SUITE_SHAPES = ({"batch": 256, "hidden": 4096},
                {"batch": 1024, "hidden": 4096},
                {"batch": 128, "hidden": 11008},
                {"batch": 512, "hidden": 14336},
                {"batch": 33, "hidden": 5120})


def make_inputs(shape: dict, *, dtype=jnp.float32, seed: int = 0) -> TestCase:
    b, h = shape["batch"], shape["hidden"]
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (b, h), dtype=dtype)
    r = jax.random.normal(ks[1], (b, h), dtype=dtype)
    w = (1.0 + 0.1 * jax.random.normal(ks[2], (h,))).astype(dtype)
    return TestCase(f"[{b},{h}]", (x, r, w),
                    {"rows": b, "d": h, "dtype": dtype})


def _run(variant, x, res, w, *, interpret=True):
    return fused_add_rmsnorm(x, res, w, variant=variant, interpret=interpret)


@register_kernel_space
def _space() -> KernelSpace:
    return KernelSpace(
        name="fused_add_rmsnorm",
        baseline=BASELINE,
        default=OPTIMIZED,
        run=_run,
        oracle=reference,
        cost=cost,
        knobs=(
            Knob("two_pass", "bool", attacks=("memory", "overhead"),
                 target=False,
                 note="False = one-pass VPU-tree reduction in VMEM "
                      "(register-resident shuffle analogue)"),
            Knob("block_rows", "pow2", 8, 1024, attacks=("overhead",)),
            Knob("use_rsqrt", "bool", attacks=("compute",), target=True,
                 note="rsqrt intrinsic instead of sqrt+div"),
        ),
        suite_shapes=SUITE_SHAPES,
        make_inputs=make_inputs,
    )
