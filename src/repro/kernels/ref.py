"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the *contracts*: each Pallas kernel (any variant) must match its
oracle to tolerance on every test input. They mirror the SGLang kernel
semantics described in the paper (Table 1):

  Kernel 1  merge_attn_states_lse:
      V_out = (e^{S_a} V_a + e^{S_b} V_b) / (e^{S_a} + e^{S_b})
      S_out = log(e^{S_a} + e^{S_b})
  Kernel 2  fused_add_rmsnorm:
      r' = x + r ;  y = r' / sqrt(mean(r'^2) + eps) * w
  Kernel 3  silu_and_mul:
      out = SiLU(gate) * up,  SiLU(z) = z / (1 + e^{-z})

All oracles are numerically-stable fp32 formulations (computation in fp32,
cast back to the input dtype), matching SGLang's accumulate-in-fp32 policy.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def merge_attn_states_lse(
    v_a: jax.Array,
    s_a: jax.Array,
    v_b: jax.Array,
    s_b: jax.Array,
):
    """Merge two partial attention states with log-sum-exp weights.

    Args:
      v_a, v_b: partial attention outputs ``[..., head_dim]``.
      s_a, s_b: log-sum-exp of the corresponding softmax partitions,
        shape ``[...]`` (i.e. ``v.shape[:-1]``). ``-inf`` marks an empty
        partition and is handled exactly (the other side wins).

    Returns:
      (v_out, s_out) with the same shapes/dtypes as the inputs.
    """
    out_dtype = v_a.dtype
    sa = s_a.astype(jnp.float32)
    sb = s_b.astype(jnp.float32)
    va = v_a.astype(jnp.float32)
    vb = v_b.astype(jnp.float32)

    m = jnp.maximum(sa, sb)
    # Guard the fully-empty case (both -inf): weights become 0, s_out -inf.
    m_safe = jnp.where(jnp.isneginf(m), 0.0, m)
    wa = jnp.exp(sa - m_safe)
    wb = jnp.exp(sb - m_safe)
    denom = wa + wb
    inv = jnp.where(denom > 0, 1.0 / denom, 0.0)
    a = (wa * inv)[..., None]
    b = (wb * inv)[..., None]
    v_out = a * va + b * vb
    s_out = m + jnp.log(denom)
    return v_out.astype(out_dtype), s_out.astype(s_a.dtype)


def fused_add_rmsnorm(
    x: jax.Array,
    residual: jax.Array,
    weight: jax.Array,
    eps: float = 1e-6,
):
    """Fused residual-add + RMSNorm (SGLang contract).

    Args:
      x:        ``[..., d]`` block output to be added into the residual.
      residual: ``[..., d]`` running residual stream.
      weight:   ``[d]`` scale.

    Returns:
      (y, new_residual): the normalized output and the updated residual
      (``x + residual``), both in the input dtype.
    """
    out_dtype = x.dtype
    r = x.astype(jnp.float32) + residual.astype(jnp.float32)
    var = jnp.mean(jnp.square(r), axis=-1, keepdims=True)
    y = r * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return y.astype(out_dtype), r.astype(out_dtype)


def silu_and_mul(x: jax.Array) -> jax.Array:
    """SwiGLU gate: ``silu(x[..., :d]) * x[..., d:]`` with ``d = x.shape[-1]//2``."""
    d = x.shape[-1] // 2
    gate = x[..., :d].astype(jnp.float32)
    up = x[..., d:].astype(jnp.float32)
    out = gate * jax.nn.sigmoid(gate) * up
    return out.astype(x.dtype)


def flash_decode_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    kv_len: jax.Array | None = None,
    sm_scale: float | None = None,
):
    """Oracle for single-token GQA decode attention.

    Args:
      q: ``[batch, q_heads, head_dim]`` query for ONE new token.
      k: ``[batch, seq, kv_heads, head_dim]`` key cache.
      v: ``[batch, seq, kv_heads, head_dim]`` value cache.
      kv_len: optional ``[batch]`` int32 valid lengths (entries >= kv_len are
        masked out). Defaults to the full cache.
      sm_scale: softmax scale; defaults to ``1/sqrt(head_dim)``.

    Returns:
      ``[batch, q_heads, head_dim]`` attention output in q's dtype.
    """
    b, hq, dh = q.shape
    _, s, hkv, _ = k.shape
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (dh ** 0.5)

    # NOTE: no whole-cache .astype — XLA would hoist an fp32 copy of the
    # full [b, s, hkv, dh] cache out of the decode loop (2x HBM + traffic).
    # bf16 reads with fp32 accumulation via preferred_element_type instead.
    qf = q.reshape(b, hkv, group, dh)
    scores = jnp.einsum("bhgd,bshd->bhgs", qf, k,
                        preferred_element_type=jnp.float32) * sm_scale
    if kv_len is not None:
        mask = jnp.arange(s)[None, :] < kv_len[:, None]  # [b, s]
        scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, dh).astype(q.dtype)


def paged_flash_decode_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    *,
    kv_len: jax.Array | None = None,
    sm_scale: float | None = None,
):
    """Oracle for paged decode attention: gather the logical cache through
    the page table, then contiguous decode attention.

    Args:
      q: ``[batch, q_heads, head_dim]``.
      k_pages, v_pages: ``[num_pages, page_size, kv_heads, head_dim]``
        global page pool.
      page_table: ``[batch, pages_per_seq]`` int32 physical page indices.
      kv_len: optional ``[batch]`` valid lengths; rows at or past ``kv_len``
        (including whatever trap/garbage pages the tail of the table points
        at) are masked out exactly.

    The gathered array ``k_pages[page_table]`` reshapes to the contiguous
    ``[batch, pages_per_seq * page_size, kv_heads, head_dim]`` cache, so the
    paged path is bit-identical to contiguous attention over the same rows.
    """
    b = q.shape[0]
    _, page, hkv, dh = k_pages.shape
    n_pt = page_table.shape[1]
    k = k_pages[page_table].reshape(b, n_pt * page, hkv, dh)
    v = v_pages[page_table].reshape(b, n_pt * page, hkv, dh)
    return flash_decode_attention(q, k, v, kv_len=kv_len, sm_scale=sm_scale)


def flash_decode_lse(
    q: jax.Array,
    k: jax.Array,
    *,
    kv_len: jax.Array | None = None,
    sm_scale: float | None = None,
) -> jax.Array:
    """LSE of the decode-attention softmax: ``[batch, q_heads]`` fp32.

    This is the ``S`` half of the partial state ``(V, S)`` consumed by
    ``merge_attn_states_lse`` in the distributed split-KV decode path.
    """
    b, hq, dh = q.shape
    _, s, hkv, _ = k.shape
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (dh ** 0.5)
    qf = q.reshape(b, hkv, group, dh)
    scores = jnp.einsum("bhgd,bshd->bhgs", qf, k,
                        preferred_element_type=jnp.float32) * sm_scale
    if kv_len is not None:
        mask = jnp.arange(s)[None, :] < kv_len[:, None]
        scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    lse = jax.nn.logsumexp(scores, axis=-1)  # [b, hkv, group]
    return lse.reshape(b, hq)
