"""Pallas TPU kernels for the compute hot-spots the paper optimizes.

Each kernel module ships a ``*Variant`` dataclass (the genome the Astra
loop tunes), ``BASELINE`` / ``OPTIMIZED`` instances, the ``pl.pallas_call``
implementation, and a ``reference`` alias to the pure-jnp oracle in
``ref.py``. ``ops.py`` is the jit'd public wrapper the models call.
"""

from repro.kernels import flash_decode  # noqa: F401
from repro.kernels import fused_add_rmsnorm  # noqa: F401
from repro.kernels import merge_attn_states  # noqa: F401
from repro.kernels import ops  # noqa: F401
from repro.kernels import ref  # noqa: F401
from repro.kernels import silu_and_mul  # noqa: F401
