"""Shared helpers for the Pallas kernels: padding, tiling, alignment,
and small compatibility shims across jax/pallas versions."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# ``pltpu.CompilerParams`` was ``TPUCompilerParams`` before jax 0.5.
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def reciprocal(x: jax.Array, *, approx: bool = False) -> jax.Array:
    """``pl.reciprocal`` where available (jax >= 0.5), else plain divide —
    the exact semantics of the non-approximate path."""
    fn = getattr(pl, "reciprocal", None)
    if fn is not None:
        return fn(x, approx=approx)
    return 1.0 / x

# TPU register-tile geometry: the VPU operates on (sublane, lane) = (8, 128)
# fp32 tiles ((16, 128) for bf16). Block shapes should be multiples of these
# or Mosaic pads them internally (wasting lanes); the cost model charges for
# that waste, and the Astra planning agent learns to avoid it.
SUBLANE = 8
LANE = 128


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(x: int, m: int) -> int:
    return cdiv(x, m) * m


def sublane_for(dtype) -> int:
    """Minimum sublane multiple for a dtype (fp32: 8, bf16: 16, int8/fp8: 32)."""
    itemsize = jnp.dtype(dtype).itemsize
    return {4: 8, 2: 16, 1: 32}.get(itemsize, 8)


def pad_rows(x: jax.Array, block_rows: int) -> tuple[jax.Array, int]:
    """Pad the leading dim of a 2-D array up to a multiple of block_rows."""
    n = x.shape[0]
    n_pad = round_up(n, block_rows)
    if n_pad != n:
        x = jnp.pad(x, ((0, n_pad - n),) + ((0, 0),) * (x.ndim - 1))
    return x, n_pad


def pick_block_rows(n_rows: int, row_bytes: int, *, vmem_budget: int = 8 * 2**20,
                    max_rows: int = 256, dtype=jnp.float32) -> int:
    """Pick a row-block size: as many rows as fit the VMEM budget, aligned."""
    sl = sublane_for(dtype)
    rows = max(sl, min(max_rows, vmem_budget // max(row_bytes, 1)))
    rows = max(sl, (rows // sl) * sl)
    return min(rows, round_up(n_rows, sl))
