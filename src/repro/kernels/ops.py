"""Public jit'd wrappers for the kernel package — the "reintegration" layer.

The paper's post-processing step drops optimized kernels back into SGLang as
transparent replacements. Here the model layers (``repro.models``) call
*these* functions, never a Pallas kernel directly, so an Astra-tuned variant
is a drop-in replacement for the whole framework.

Dispatch policy (``impl``):
  * ``"auto"``   — Pallas on TPU backends; pure-jnp reference elsewhere
    (CPU dry-run / tests / training backward pass all lower the reference).
  * ``"pallas"`` — force the Pallas kernel (``interpret=True`` off-TPU).
  * ``"ref"``    — force the pure-jnp oracle.

Training uses the reference formulations (differentiable jnp); serving's
hot decode path uses the Pallas kernels on TPU. ``set_variants`` installs
Astra-tuned variants process-wide (what the paper calls reintegration).
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import flash_decode as _fd
from repro.kernels import fused_add_rmsnorm as _rms
from repro.kernels import merge_attn_states as _merge
from repro.kernels import ref
from repro.kernels import silu_and_mul as _silu
from repro.kernels import registry as _registry

Impl = Literal["auto", "pallas", "ref"]

# Process-wide tuned-variant overrides (Astra writes these via
# ``set_variants``). Dispatch reads the kernel registry: a kernel with no
# override runs its registered space's shipped ``default`` variant, so a
# newly-registered kernel is servable with zero edits here.
_OVERRIDES: dict[str, object] = {}


def set_variants(**kwargs) -> None:
    """Reintegrate tuned kernel variants (paper §3.2 post-processing)."""
    for name, variant in kwargs.items():
        _registry.get_space(name)       # raises KeyError on unknown kernels
        _OVERRIDES[name] = variant


def get_variant(name: str):
    try:
        return _OVERRIDES[name]
    except KeyError:
        return _registry.get_space(name).shipped


def _use_pallas(impl: Impl) -> tuple[bool, bool]:
    """Returns (use_pallas, interpret)."""
    if impl == "ref":
        return False, False
    on_tpu = jax.default_backend() == "tpu"
    if impl == "pallas":
        return True, not on_tpu
    return on_tpu, False  # auto


def silu_and_mul(x: jax.Array, *, impl: Impl = "auto") -> jax.Array:
    """SwiGLU gate: ``silu(x[..., :d]) * x[..., d:]``."""
    use, interp = _use_pallas(impl)
    if use:
        return _silu.silu_and_mul(x, get_variant("silu_and_mul"),
                                  interpret=interp)
    return ref.silu_and_mul(x)


def fused_add_rmsnorm(x: jax.Array, residual: jax.Array, weight: jax.Array,
                      eps: float = 1e-6, *, impl: Impl = "auto"):
    """Residual-add + RMSNorm. Returns ``(y, new_residual)``."""
    use, interp = _use_pallas(impl)
    if use:
        return _rms.fused_add_rmsnorm(x, residual, weight, eps,
                                      get_variant("fused_add_rmsnorm"),
                                      interpret=interp)
    return ref.fused_add_rmsnorm(x, residual, weight, eps)


def merge_attn_states_lse(v_a, s_a, v_b, s_b, *, impl: Impl = "auto"):
    """LSE-merge of two partial attention states. Returns ``(v, s)``."""
    use, interp = _use_pallas(impl)
    if use:
        return _merge.merge_attn_states_lse(
            v_a, s_a, v_b, s_b, get_variant("merge_attn_states_lse"),
            interpret=interp)
    return ref.merge_attn_states_lse(v_a, s_a, v_b, s_b)


def flash_decode_attention(q, k, v, *, kv_len=None, sm_scale=None,
                           return_lse: bool = False, impl: Impl = "auto"):
    """Single-token GQA decode attention over the KV cache."""
    use, interp = _use_pallas(impl)
    if use:
        return _fd.flash_decode_attention(
            q, k, v, kv_len=kv_len, sm_scale=sm_scale,
            variant=get_variant("flash_decode"), interpret=interp,
            return_lse=return_lse)
    out = ref.flash_decode_attention(q, k, v, kv_len=kv_len,
                                     sm_scale=sm_scale)
    if not return_lse:
        return out
    lse = ref.flash_decode_lse(q, k, kv_len=kv_len, sm_scale=sm_scale)
    return out, lse


def paged_flash_decode_attention(q, k_pages, v_pages, page_table, *,
                                 kv_len=None, sm_scale=None,
                                 impl: Impl = "auto"):
    """Single-token GQA decode attention over a paged KV pool: K/V blocks
    are gathered through ``page_table`` (``[batch, pages_per_seq]`` physical
    page indices into the ``[num_pages, page_size, kv_heads, head_dim]``
    pool). The paged serving engine's decode hot path lands here."""
    use, interp = _use_pallas(impl)
    if use:
        return _fd.paged_flash_decode_attention(
            q, k_pages, v_pages, page_table, kv_len=kv_len,
            sm_scale=sm_scale, variant=get_variant("paged_flash_decode"),
            interpret=interp)
    return ref.paged_flash_decode_attention(q, k_pages, v_pages, page_table,
                                            kv_len=kv_len, sm_scale=sm_scale)
