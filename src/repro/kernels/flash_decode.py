"""Split-KV (FlashDecoding-style) GQA decode attention — Pallas TPU kernel.

This is the production consumer of paper Kernel 1: the KV cache is split
into sequence chunks; each chunk produces a partial attention state
``(V_partial, LSE)``; partials are merged with the ``merge_attn_states_lse``
math (running online-softmax merge in VMEM scratch across grid steps).

The same merge runs at TWO levels:
  1. on-chip: across KV chunks inside this kernel (this file), and
  2. cross-device: sequence-parallel decode shards the KV cache along the
     sequence axis and merges per-shard partials with collectives — the
     distributed form of Kernel 1 (``sharding/rules.py`` maps ``kv_seq``
     to the ``model`` axis for it). The paged serving engine does NOT use
     this path: its tensor-parallel plan (``repro.sharding.tp``) shards
     heads instead, because the cross-device LSE merge is not bitwise
     identical to single-device execution while head-sharded all-gathers
     are.

Grid: ``(batch * kv_heads, num_chunks)`` with the chunk axis sequential
("arbitrary"), carrying ``(acc, m, l)`` in VMEM scratch — the classic
online-softmax carry. Block shapes: q ``[group_pad, head_dim]``, k/v
``[chunk, head_dim]``.

Variant knobs (the space Astra searches):
  * ``chunk``        — KV rows per grid step (VMEM working set).
  * ``use_reciprocal`` — final normalize via rcp+mul vs divide.
  * ``mask_oob``     — predicate chunks entirely past ``kv_len`` (skip work)
    vs masking every score (baseline reads + masks everything).

**Paged form** (``paged_flash_decode_attention``): the production layout of
this kernel in a paged-KV serving engine. K/V live in a global page pool
``[num_pages, page_size, kv_heads, head_dim]`` shared by all requests; a
per-request page table maps logical page ``j`` to its physical page.  The
grid's sequential axis walks *logical* pages and the K/V BlockSpec
index_maps read the scalar-prefetched page table to DMA the right physical
block — the same online-softmax carry, with the gather folded into the
block fetch.  ``page_size`` is a search knob of its own registered space
(``paged_flash_decode``): it sets both the pool granule the serving engine
allocates in and this kernel's per-step working set.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _common, ref
from repro.kernels._common import round_up
from repro.kernels.registry import (KernelSpace, Knob, TestCase,
                                    register_kernel_space)

NEG_INF = -1e30  # finite -inf stand-in: keeps exp() well-defined on padding


@dataclasses.dataclass(frozen=True)
class FlashDecodeVariant:
    name: str = "baseline"
    chunk: int = 512
    use_reciprocal: bool = False
    mask_oob: bool = False

    def describe(self) -> str:
        return (f"{self.name}: chunk={self.chunk} rcp={self.use_reciprocal} "
                f"mask_oob={self.mask_oob}")


BASELINE = FlashDecodeVariant()
OPTIMIZED = FlashDecodeVariant(name="astra_opt", chunk=1024,
                               use_reciprocal=True, mask_oob=True)


def _init_carry(acc_ref, m_ref, l_ref):
    acc_ref[...] = jnp.zeros_like(acc_ref)
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)


def _online_softmax_step(q, k, v, acc_ref, m_ref, l_ref, *,
                         pos0, kv_len, sm_scale):
    """One chunk of the running online-softmax merge. ``pos0`` is the
    absolute KV position of this chunk's first row (rows >= kv_len are
    masked)."""
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * sm_scale   # [G, C]
    # mask positions >= kv_len within this chunk
    pos = pos0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < kv_len, s, NEG_INF)

    m_prev = m_ref[...][:, :1]                    # [G, 1]
    l_prev = l_ref[...][:, :1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)    # [G, 1]
    m_new = jnp.maximum(m_prev, m_cur)
    # merge_attn_states_lse math: rescale old accumulator, add new chunk
    alpha = jnp.exp(m_prev - m_new)               # e^{S_a - m}
    p = jnp.exp(s - m_new)                        # [G, C]
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)


def _finalize_output(o_ref, acc_ref, l_ref, *, use_reciprocal):
    l = l_ref[...][:, :1]
    if use_reciprocal:
        inv = jnp.where(l > 0, _common.reciprocal(l, approx=False), 0.0)
        o_ref[0] = (acc_ref[...] * inv).astype(o_ref.dtype)
    else:
        safe_l = jnp.where(l > 0, l, 1.0)
        o_ref[0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *,
            chunk, sm_scale, use_reciprocal, mask_oob):
    j = pl.program_id(1)
    n_chunks = pl.num_programs(1)
    kv_len = len_ref[0, 0]

    @pl.when(j == 0)
    def _init():
        _init_carry(acc_ref, m_ref, l_ref)

    def _step():
        _online_softmax_step(
            q_ref[0].astype(jnp.float32),             # [G, D]
            k_ref[0].astype(jnp.float32),             # [C, D]
            v_ref[0].astype(jnp.float32),             # [C, D]
            acc_ref, m_ref, l_ref,
            pos0=j * chunk, kv_len=kv_len, sm_scale=sm_scale)

    if mask_oob:
        # Optimized: skip chunks entirely past kv_len (saves the matmul+exp).
        pl.when(j * chunk < kv_len)(_step)
    else:
        _step()

    @pl.when(j == n_chunks - 1)
    def _finalize():
        _finalize_output(o_ref, acc_ref, l_ref, use_reciprocal=use_reciprocal)


def flash_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           kv_len: jax.Array | None = None,
                           sm_scale: float | None = None,
                           variant: FlashDecodeVariant = OPTIMIZED,
                           interpret: bool = False,
                           return_lse: bool = False):
    """Single-token GQA decode attention over a (chunked) KV cache.

    Args:
      q: ``[batch, q_heads, head_dim]``.
      k, v: ``[batch, seq, kv_heads, head_dim]``.
      kv_len: ``[batch]`` int32 valid lengths (default: full cache).

    Returns:
      ``[batch, q_heads, head_dim]`` (and ``[batch, q_heads]`` LSE when
      ``return_lse`` — the partial state consumed by the distributed merge).
    """
    b, hq, dh = q.shape
    _, s, hkv, _ = k.shape
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (dh ** 0.5)
    if kv_len is None:
        kv_len = jnp.full((b,), s, jnp.int32)

    chunk = min(variant.chunk, s)
    s_pad = round_up(s, chunk)
    if s_pad != s:
        pad = ((0, 0), (0, s_pad - s), (0, 0), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    n_chunks = s_pad // chunk

    g_pad = round_up(group, 8)  # sublane-align the query group
    # [b, hkv, G, D] with padded group rows
    q4 = q.reshape(b, hkv, group, dh)
    if g_pad != group:
        q4 = jnp.pad(q4, ((0, 0), (0, 0), (0, g_pad - group), (0, 0)))
    q3 = q4.reshape(b * hkv, g_pad, dh)
    # [b*hkv, s_pad, dh]
    k3 = jnp.swapaxes(k, 1, 2).reshape(b * hkv, s_pad, dh)
    v3 = jnp.swapaxes(v, 1, 2).reshape(b * hkv, s_pad, dh)
    len2 = jnp.repeat(kv_len.astype(jnp.int32), hkv).reshape(b * hkv, 1)

    grid = (b * hkv, n_chunks)
    kern = functools.partial(
        _kernel, chunk=chunk, sm_scale=sm_scale,
        use_reciprocal=variant.use_reciprocal, mask_oob=variant.mask_oob)

    out = pl.pallas_call(
        kern, grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (i, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, g_pad, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, chunk, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, dh), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, g_pad, dh), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, g_pad, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g_pad, dh), jnp.float32),
            pltpu.VMEM((g_pad, 128), jnp.float32),
            pltpu.VMEM((g_pad, 128), jnp.float32),
        ],
        compiler_params=_common.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(len2, q3, k3, v3)

    out = out.reshape(b, hkv, g_pad, dh)[:, :, :group].reshape(b, hq, dh)
    if not return_lse:
        return out
    # LSE is recomputed cheaply host-side for the distributed merge path.
    lse = ref.flash_decode_lse(q, k[:, :s], kv_len=kv_len, sm_scale=sm_scale)
    return out, lse


def cost(variant: FlashDecodeVariant, *, batch: int, q_heads: int,
         kv_heads: int, head_dim: int, seq: int, dtype,
         mean_kv_len: float | None = None):
    """Analytic v5e cost of decode attention over a ``[b, s, hkv, d]`` cache."""
    from repro.core import costmodel as cm

    import jax.numpy as jnp
    item = jnp.dtype(dtype).itemsize
    group = q_heads // kv_heads
    g_pad = round_up(group, 8)
    chunk = min(variant.chunk, seq)
    s_pad = round_up(seq, chunk)
    n_chunks = s_pad // chunk
    ops = cm.OP

    # fraction of chunks actually touched when predication is on
    frac = 1.0
    if variant.mask_oob and mean_kv_len is not None:
        frac = min(1.0, (mean_kv_len / chunk + 1) / n_chunks)

    kv_bytes = 2 * batch * kv_heads * s_pad * head_dim * item * frac
    q_bytes = batch * q_heads * head_dim * item
    o_bytes = batch * kv_heads * g_pad * head_dim * item

    mxu = 2 * 2 * batch * kv_heads * g_pad * head_dim * s_pad * frac  # qk + pv
    # per-score VPU: mask cmp+sel, exp, running max/sum, rescale
    vpu = batch * kv_heads * g_pad * s_pad * frac * (
        ops["exp"] + 2 * ops["cmp"] + ops["max"] + 2 * ops["fma"])
    vpu += batch * kv_heads * g_pad * head_dim * n_chunks * frac * 2 * ops["fma"]
    vpu += batch * kv_heads * g_pad * head_dim * (
        (ops["rcp"] + ops["mul"]) if variant.use_reciprocal else ops["div"])

    c = cm.Cost(
        hbm_bytes=kv_bytes + q_bytes + o_bytes,
        vpu_ops=vpu,
        mxu_flops=mxu,
        mxu_dtype="bf16" if item == 2 else "fp32",
        grid_steps=batch * kv_heads * n_chunks,
        n_calls=1,
        vmem_bytes=(2 * chunk * head_dim * item          # k, v blocks
                    + 2 * g_pad * head_dim * 4           # q, acc
                    + 2 * g_pad * 128 * 4),              # m, l
        align_waste_bytes=kv_bytes * (s_pad / seq - 1.0)
        + (g_pad - group) / max(group, 1) * q_bytes,
    )
    c.validate()
    return c


reference = ref.flash_decode_attention


SUITE_SHAPES = ({"batch": 8, "q_heads": 32, "kv_heads": 8, "head_dim": 128,
                 "seq": 4096},
                {"batch": 32, "q_heads": 14, "kv_heads": 2, "head_dim": 64,
                 "seq": 2048},
                {"batch": 4, "q_heads": 16, "kv_heads": 16, "head_dim": 128,
                 "seq": 8192})


def make_inputs(shape: dict, *, dtype=jnp.float32, seed: int = 0) -> TestCase:
    b, hq, hkv = shape["batch"], shape["q_heads"], shape["kv_heads"]
    dh, s = shape["head_dim"], shape["seq"]
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, hq, dh), dtype=dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, dh), dtype=dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, dh), dtype=dtype)
    kv_len = jax.random.randint(ks[3], (b,), 1, s + 1)
    info = dict(shape)
    info.update(dtype=dtype, mean_kv_len=float(jnp.mean(kv_len)))
    return TestCase(f"[{b},{hq}/{hkv},{dh},s{s}]", (q, k, v, kv_len), info)


def _run(variant, q, k, v, kv_len, *, interpret=True):
    return flash_decode_attention(q, k, v, kv_len=kv_len, variant=variant,
                                  interpret=interpret)


def _oracle(q, k, v, kv_len):
    return ref.flash_decode_attention(q, k, v, kv_len=kv_len)


@register_kernel_space
def _space() -> KernelSpace:
    return KernelSpace(
        name="flash_decode",
        baseline=BASELINE,
        default=OPTIMIZED,
        run=_run,
        oracle=_oracle,
        cost=cost,
        knobs=(
            Knob("mask_oob", "bool", attacks=("memory", "compute"),
                 target=True,
                 note="predicate chunks past kv_len (skip DMA + compute)"),
            Knob("chunk", "pow2", 128, 4096, attacks=("overhead",),
                 note="KV rows per grid step"),
            Knob("use_reciprocal", "bool", attacks=("compute",), target=True),
        ),
        suite_shapes=SUITE_SHAPES,
        make_inputs=make_inputs,
    )


# ==========================================================================
# Paged variant — K/V gathered through a page table (paged-KV serving form)
# ==========================================================================

@dataclasses.dataclass(frozen=True)
class PagedFlashDecodeVariant:
    """Knobs of the paged kernel. ``page_size`` is the pool granule: the
    serving engine allocates KV in ``page_size``-row pages and this kernel
    processes one page per sequential grid step (the paged analogue of
    ``chunk``). At apply time the kernel reads the page size off the pool's
    shape; the knob steers the *search*, whose verdict sizes the pool."""
    name: str = "baseline"
    page_size: int = 16
    use_reciprocal: bool = False
    mask_oob: bool = False

    def describe(self) -> str:
        return (f"{self.name}: page_size={self.page_size} "
                f"rcp={self.use_reciprocal} mask_oob={self.mask_oob}")


PAGED_BASELINE = PagedFlashDecodeVariant()
PAGED_OPTIMIZED = PagedFlashDecodeVariant(name="astra_opt", page_size=64,
                                          use_reciprocal=True, mask_oob=True)


def _paged_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *,
                  page, hkv, sm_scale, use_reciprocal, mask_oob):
    i = pl.program_id(0)                  # batch * kv_head
    j = pl.program_id(1)                  # LOGICAL page index
    n_pages = pl.num_programs(1)
    kv_len = len_ref[i // hkv]

    @pl.when(j == 0)
    def _init():
        _init_carry(acc_ref, m_ref, l_ref)

    def _step():
        _online_softmax_step(
            q_ref[0].astype(jnp.float32),
            k_ref[0, 0].astype(jnp.float32),          # [page, D]
            v_ref[0, 0].astype(jnp.float32),
            acc_ref, m_ref, l_ref,
            pos0=j * page, kv_len=kv_len, sm_scale=sm_scale)

    if mask_oob:
        # skip logical pages entirely past kv_len (their physical blocks
        # may belong to other requests — never read, never computed)
        pl.when(j * page < kv_len)(_step)
    else:
        _step()

    @pl.when(j == n_pages - 1)
    def _finalize():
        _finalize_output(o_ref, acc_ref, l_ref, use_reciprocal=use_reciprocal)


def paged_flash_decode_attention(q: jax.Array, k_pages: jax.Array,
                                 v_pages: jax.Array, page_table: jax.Array, *,
                                 kv_len: jax.Array | None = None,
                                 sm_scale: float | None = None,
                                 variant: PagedFlashDecodeVariant
                                 = PAGED_OPTIMIZED,
                                 interpret: bool = False):
    """Single-token GQA decode attention over a paged KV pool.

    Args:
      q: ``[batch, q_heads, head_dim]``.
      k_pages, v_pages: ``[num_pages, page_size, kv_heads, head_dim]``
        global block pool (shared by every request).
      page_table: ``[batch, pages_per_seq]`` int32 — logical page ``j`` of
        request ``b`` lives in physical page ``page_table[b, j]``.
      kv_len: ``[batch]`` int32 valid lengths (default: the full table).

    Returns ``[batch, q_heads, head_dim]``; bitwise it computes attention
    over the gathered cache ``k_pages[page_table]`` — table entries at or
    past ``kv_len`` may point anywhere valid (the engine points them at a
    trap page) and are fully masked.
    """
    b, hq, dh = q.shape
    _, page, hkv, _ = k_pages.shape
    n_pt = page_table.shape[1]
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (dh ** 0.5)
    if kv_len is None:
        kv_len = jnp.full((b,), n_pt * page, jnp.int32)

    g_pad = round_up(group, 8)
    q4 = q.reshape(b, hkv, group, dh)
    if g_pad != group:
        q4 = jnp.pad(q4, ((0, 0), (0, 0), (0, g_pad - group), (0, 0)))
    q3 = q4.reshape(b * hkv, g_pad, dh)
    # [P, hkv, page, dh]: one (physical page, head) pair per block fetch
    k4 = jnp.swapaxes(k_pages, 1, 2)
    v4 = jnp.swapaxes(v_pages, 1, 2)
    flat_pt = page_table.reshape(-1).astype(jnp.int32)   # [b * n_pt]

    kern = functools.partial(
        _paged_kernel, page=page, hkv=hkv, sm_scale=sm_scale,
        use_reciprocal=variant.use_reciprocal, mask_oob=variant.mask_oob)

    def kv_map(i, j, pt_ref, len_ref):
        # gather through the scalar-prefetched table: logical page j of
        # request i // hkv -> physical block index
        return (pt_ref[(i // hkv) * n_pt + j], i % hkv, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,            # page table + kv_len
        grid=(b * hkv, n_pt),
        in_specs=[
            pl.BlockSpec((1, g_pad, dh), lambda i, j, pt, ln: (i, 0, 0)),
            pl.BlockSpec((1, 1, page, dh), kv_map),
            pl.BlockSpec((1, 1, page, dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, g_pad, dh), lambda i, j, pt, ln: (i, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g_pad, dh), jnp.float32),
            pltpu.VMEM((g_pad, 128), jnp.float32),
            pltpu.VMEM((g_pad, 128), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hkv, g_pad, dh), q.dtype),
        compiler_params=_common.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(flat_pt, kv_len.astype(jnp.int32), q3, k4, v4)

    return out.reshape(b, hkv, g_pad, dh)[:, :, :group].reshape(b, hq, dh)


def paged_cost(variant: PagedFlashDecodeVariant, *, batch: int, q_heads: int,
               kv_heads: int, head_dim: int, seq: int, dtype,
               mean_kv_len: float | None = None):
    """Analytic cost: the split-KV cost at chunk=page_size plus the page
    table reads (SMEM-prefetched, but they still cross HBM once)."""
    proxy = FlashDecodeVariant(chunk=variant.page_size,
                               use_reciprocal=variant.use_reciprocal,
                               mask_oob=variant.mask_oob)
    c = cost(proxy, batch=batch, q_heads=q_heads, kv_heads=kv_heads,
             head_dim=head_dim, seq=seq, dtype=dtype,
             mean_kv_len=mean_kv_len)
    n_pt = round_up(seq, variant.page_size) // variant.page_size
    c = dataclasses.replace(c, hbm_bytes=c.hbm_bytes + batch * n_pt * 4)
    c.validate()
    return c


def _page_kv(k, v, page: int):
    """Pack a contiguous ``[b, s, hkv, d]`` cache into a shuffled physical
    pool + page table (the search harness's stand-in for the engine's
    allocator — a fixed permutation so the gather path is really exercised).
    """
    import numpy as np
    b, s, hkv, dh = k.shape
    s_pad = round_up(s, page)
    if s_pad != s:
        padw = ((0, 0), (0, s_pad - s), (0, 0), (0, 0))
        k, v = jnp.pad(k, padw), jnp.pad(v, padw)
    n_pt = s_pad // page
    perm = jnp.asarray(np.random.default_rng(17).permutation(b * n_pt),
                       jnp.int32)
    k_flat = k.reshape(b * n_pt, page, hkv, dh)
    v_flat = v.reshape(b * n_pt, page, hkv, dh)
    k_pages = jnp.zeros_like(k_flat).at[perm].set(k_flat)
    v_pages = jnp.zeros_like(v_flat).at[perm].set(v_flat)
    return k_pages, v_pages, perm.reshape(b, n_pt)


def _paged_run(variant, q, k, v, kv_len, *, interpret=True):
    page = min(variant.page_size, round_up(k.shape[1], 8))
    k_pages, v_pages, table = _page_kv(k, v, page)
    return paged_flash_decode_attention(q, k_pages, v_pages, table,
                                        kv_len=kv_len, variant=variant,
                                        interpret=interpret)


PAGED_SUITE_SHAPES = (
    {"batch": 2, "q_heads": 8, "kv_heads": 2, "head_dim": 64, "seq": 256},
    {"batch": 4, "q_heads": 4, "kv_heads": 4, "head_dim": 64, "seq": 512},
)


@register_kernel_space
def _paged_space() -> KernelSpace:
    return KernelSpace(
        name="paged_flash_decode",
        baseline=PAGED_BASELINE,
        default=PAGED_OPTIMIZED,
        run=_paged_run,
        oracle=_oracle,       # paging + gather must reproduce contiguous
        cost=paged_cost,
        knobs=(
            Knob("page_size", "pow2", 8, 256,
                 attacks=("overhead", "memory"),
                 note="KV pool granule = rows per grid step; small pages "
                      "cut allocator fragmentation, large pages cut "
                      "grid/DMA overhead"),
            Knob("mask_oob", "bool", attacks=("memory", "compute"),
                 target=True,
                 note="predicate logical pages past kv_len"),
            Knob("use_reciprocal", "bool", attacks=("compute",), target=True),
        ),
        suite_shapes=PAGED_SUITE_SHAPES,
        make_inputs=make_inputs,
    )
