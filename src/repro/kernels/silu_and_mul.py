"""Paper Kernel 3 — ``silu_and_mul`` (SwiGLU gate) as a Pallas TPU kernel.

The CUDA baseline does scalar ``__half`` loads and library-math SiLU with a
division; the Astra-optimized CUDA version uses ``half2`` vectorized loads
and ``__expf``/``__frcp_rn`` fast math (paper §5.3, Figs. 4–5). The TPU
adaptation of that optimization space (DESIGN.md §2):

  * ``fused_split``   — baseline materializes ``gate``/``up`` slices in HBM
    (the extra-memory-transaction analogue of scalar loads); the optimized
    variant indexes both halves of the *original* array via two BlockSpecs
    over the same buffer, so no slice copies are ever written to HBM.
  * ``use_reciprocal`` — division-free SiLU: ``z * rcp(1 + e^{-z})``
    (reciprocal-multiply, the ``__frcp_rn`` analogue; the cost model charges
    div at a lower rate than rcp+mul on the VPU).
  * ``compute_fp32``  — accumulate in fp32 (safe) vs bf16 fast-math.
  * ``block_rows`` / ``block_cols`` — VMEM tile geometry; lane-aligned
    (multiples of (8/16, 128)) tiles are the ``half2`` analogue: full-width
    VREG transfers with zero padding waste.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref
from repro.kernels._common import LANE, cdiv, pad_rows, round_up, sublane_for
from repro.kernels.registry import (KernelSpace, Knob, TestCase,
                                    register_kernel_space)


@dataclasses.dataclass(frozen=True)
class SiluMulVariant:
    """Genome for the silu_and_mul kernel (the space Astra searches)."""
    name: str = "baseline"
    block_rows: int = 16
    block_cols: int = 256
    compute_fp32: bool = True
    use_reciprocal: bool = False
    fast_exp: bool = False
    fused_split: bool = False

    def describe(self) -> str:
        return (f"{self.name}: tile=({self.block_rows},{self.block_cols}) "
                f"fp32={self.compute_fp32} rcp={self.use_reciprocal} "
                f"exp2={self.fast_exp} fused_split={self.fused_split}")


# "Production port" baseline: a reasonable but untuned direct translation of
# the SGLang kernel structure (modest tile, library math, materialized
# gate/up slices — the scalar-load analogue).
BASELINE = SiluMulVariant()
# Found by the Astra loop (see EXPERIMENTS.md §Perf / benchmarks table 2).
OPTIMIZED = SiluMulVariant(
    name="astra_opt", block_rows=32, block_cols=256,
    compute_fp32=True, use_reciprocal=False, fast_exp=False, fused_split=True,
)

_LOG2E = 1.4426950408889634


def _pick_block_cols(d: int, want: int) -> int:
    """Largest divisor of d that is <= want, preferring lane multiples.

    The fused-split path offsets the `up` BlockSpec by whole blocks, so the
    block width must divide d exactly.
    """
    want = max(1, min(want, d))
    lane_divs = [bc for bc in range(LANE, want + 1, LANE) if d % bc == 0]
    if lane_divs:
        return lane_divs[-1]
    return d  # no aligned divisor: use the whole row as one block


def _kernel(gate_ref, up_ref, o_ref, *, compute_fp32: bool,
            use_reciprocal: bool, fast_exp: bool):
    gate = gate_ref[...]
    up = up_ref[...]
    if compute_fp32:
        gate = gate.astype(jnp.float32)
        up = up.astype(jnp.float32)
    if fast_exp:
        # exp(-z) = exp2(-z * log2(e)): exp2 is the native VPU transcendental
        # (no base-e range reduction) — the __expf analogue.
        e = jnp.exp2(-gate * _LOG2E)
    else:
        e = jnp.exp(-gate)
    if use_reciprocal:
        # Fast-math: z * rcp(1 + exp(-z)) — reciprocal-multiply, no divide.
        sig = 1.0 / (1.0 + e)  # lowered to rcp on the VPU
        out = gate * sig * up
    else:
        # Library-math formulation with an explicit divide (paper baseline).
        out = (gate / (1.0 + e)) * up
    o_ref[...] = out.astype(o_ref.dtype)


def silu_and_mul(x: jax.Array, variant: SiluMulVariant = OPTIMIZED, *,
                 interpret: bool = False) -> jax.Array:
    """``silu(x[..., :d]) * x[..., d:]`` — Pallas TPU implementation.

    Accepts any leading batch shape; the kernel runs on the flattened
    ``[rows, 2d]`` view.
    """
    orig_shape = x.shape
    d = orig_shape[-1] // 2
    x2 = x.reshape(-1, orig_shape[-1])
    n = x2.shape[0]

    bc = _pick_block_cols(d, variant.block_cols)
    sl = sublane_for(x.dtype)
    br = max(sl, (min(variant.block_rows, n) // sl) * sl) if n >= sl else n
    x2, n_pad = pad_rows(x2, br)

    grid = (n_pad // br, cdiv(d, bc))
    kern = functools.partial(
        _kernel, compute_fp32=variant.compute_fp32,
        use_reciprocal=variant.use_reciprocal, fast_exp=variant.fast_exp)

    if variant.fused_split:
        # Two BlockSpecs over the SAME buffer: gate blocks from columns
        # [0, d), up blocks from columns [d, 2d). No slice copies in HBM.
        n_cb = cdiv(d, bc)
        in_specs = [
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j, n_cb=n_cb: (i, j + n_cb)),
        ]
        operands = (x2, x2)
    else:
        # Baseline: materialized gate/up slices (extra HBM round-trip).
        in_specs = [
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        ]
        operands = (x2[:, :d], x2[:, d:])

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d), x.dtype),
        interpret=interpret,
    )(*operands)
    return out[:n].reshape(*orig_shape[:-1], d)


def cost(variant: SiluMulVariant, *, rows: int, d: int, dtype):
    """Analytic v5e cost of this variant on a ``[rows, 2d]`` input."""
    from repro.core import costmodel as cm

    item = jnp.dtype(dtype).itemsize
    sl = sublane_for(dtype)
    bc = _pick_block_cols(d, variant.block_cols)
    br = max(sl, (min(variant.block_rows, rows) // sl) * sl) if rows >= sl \
        else max(rows, 1)
    n_pad = round_up(rows, br)
    grid_steps = (n_pad // br) * cdiv(d, bc)

    # per-element VPU work (fp32-equivalent weighted ops)
    ops = cm.OP
    per_el = ops["mul"]  # final multiply by `up`
    per_el += (ops["exp_fast"] + ops["mul"]) if variant.fast_exp else ops["exp"]
    per_el += ops["add"]  # 1 + e
    per_el += (ops["rcp"] + ops["mul"]) if variant.use_reciprocal else ops["div"]
    if variant.compute_fp32 and item < 4:
        per_el += 3 * ops["cast"]

    pad_rows_waste = (n_pad - rows) * d * item * 3  # read 2 + write 1
    lane_waste = 0.0
    if bc % LANE:
        lane_waste = rows * d * item * 3 * (round_up(bc, LANE) / bc - 1.0)

    main = cm.Cost(
        hbm_bytes=3 * rows * d * item,
        vpu_ops=rows * d * per_el,
        grid_steps=grid_steps,
        n_calls=1,
        vmem_bytes=br * bc * (2 + 1) * (4 if variant.compute_fp32 else item),
        align_waste_bytes=pad_rows_waste + lane_waste,
    )
    costs = [main]
    if not variant.fused_split:
        # Materialized gate/up slices: one extra HBM round trip of x.
        costs.append(cm.Cost(
            hbm_bytes=4 * rows * d * item,  # read x, write both halves
            vpu_ops=0.0, grid_steps=max(1, grid_steps // 1), n_calls=1,
            vmem_bytes=br * bc * 2 * item))
    total = cm.combine(costs)
    total.validate()
    return total


reference = ref.silu_and_mul


# Paper Table 4 shapes: [batch, hidden] (LLaMA-7B/13B/70B dims) plus
# ragged/odd shapes for robustness.
SUITE_SHAPES = ({"batch": 16, "hidden": 4096}, {"batch": 32, "hidden": 5120},
                {"batch": 64, "hidden": 8192}, {"batch": 16, "hidden": 12288},
                {"batch": 17, "hidden": 11008})


def make_inputs(shape: dict, *, dtype=jnp.float32, seed: int = 0) -> TestCase:
    b, h = shape["batch"], shape["hidden"]
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (b, 2 * h), dtype=dtype) * 2.0
    return TestCase(f"[{b},{h}]", (x,), {"rows": b, "d": h, "dtype": dtype})


def _run(variant, x, *, interpret=True):
    return silu_and_mul(x, variant, interpret=interpret)


@register_kernel_space
def _space() -> KernelSpace:
    return KernelSpace(
        name="silu_and_mul",
        baseline=BASELINE,
        default=OPTIMIZED,
        run=_run,
        oracle=reference,
        cost=cost,
        knobs=(
            Knob("fused_split", "bool", attacks=("memory", "overhead"),
                 target=True,
                 note="index gate/up in-place; kills the slice-copy pass "
                      "(round trip + launch)"),
            Knob("block_rows", "pow2", 8, 1024, attacks=("overhead",),
                 note="rows per grid step; bigger tiles amortize step issue"),
            Knob("block_cols", "pow2", 128, 2048, attacks=("overhead",),
                 note="lane-tile width; lane-aligned widths avoid padding"),
            Knob("use_reciprocal", "bool", attacks=("compute",), target=True,
                 note="rcp+mul instead of divide (__frcp_rn analogue)"),
            Knob("fast_exp", "bool", attacks=("compute",), target=True,
                 note="exp2-based sigmoid (__expf analogue)"),
        ),
        suite_shapes=SUITE_SHAPES,
        make_inputs=make_inputs,
    )
