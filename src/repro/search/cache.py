"""Content-addressed evaluation cache.

Interpret-mode Pallas validation dominates a search's wall-clock; the
sequential Algorithm-1 loop happily re-validates a genome it already saw
(every revert does). The cache keys each evaluation by
``(kernel, genome-digest, suite-digest)`` so a repeated variant is a dict
hit — validation and profiling each run **at most once per unique genome**
per suite, an invariant the cache itself enforces and exposes via
``stats()`` / ``max_evals_per_genome``.

Entries may be *unvalidated* (baseline genomes are correct by construction,
so strategies profile them without paying for validation). A later request
that needs a verdict upgrades the entry in place, reusing the stored
profile.
"""

from __future__ import annotations

import dataclasses
from collections import Counter

from repro.search.types import EvalResult, genome_digest, suite_digest


class EvalCache:
    """Memoizes (validate, profile) per unique (kernel, genome, suite)."""

    def __init__(self) -> None:
        self._store: dict[tuple, EvalResult] = {}
        self.hits = 0
        self.misses = 0
        self._validate_runs: Counter = Counter()
        self._profile_runs: Counter = Counter()

    def key(self, kernel: str, variant, tests=None, *,
            tests_digest: str | None = None) -> tuple:
        sd = tests_digest if tests_digest is not None else suite_digest(tests)
        return (kernel, genome_digest(variant), sd)

    def evaluate(self, space, variant, tests, *, testing, profiling,
                 validate: bool = True,
                 tests_digest: str | None = None) -> EvalResult:
        """Return the (possibly cached) evaluation of ``variant``.

        ``validate=False`` skips the correctness run and records the entry
        as unvalidated with ``passed=True`` (callers use this only for
        genomes correct by construction, e.g. the shipped baseline).
        """
        k = self.key(space.name, variant, tests, tests_digest=tests_digest)
        entry = self._store.get(k)
        if entry is not None and (entry.validated or not validate):
            self.hits += 1
            return dataclasses.replace(entry, cached=True)
        self.misses += 1
        if entry is not None:
            # Upgrade an unvalidated entry: run validation once, keep the
            # stored profile (profiling already ran for this genome).
            passed, max_err = testing.validate(space, variant, tests)
            self._validate_runs[k] += 1
            result = EvalResult(passed, max_err, entry.profile,
                                validated=True)
        else:
            if validate:
                passed, max_err = testing.validate(space, variant, tests)
                self._validate_runs[k] += 1
            else:
                passed, max_err = True, 0.0
            profile = profiling.profile(space, variant, tests)
            self._profile_runs[k] += 1
            result = EvalResult(passed, max_err, profile, validated=validate)
        self._store[k] = result
        return result

    # -- introspection ------------------------------------------------------

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, key: tuple) -> bool:
        return key in self._store

    def max_evals_per_genome(self) -> int:
        """Worst-case number of validation/profiling runs for any genome —
        the memoization invariant says this never exceeds 1."""
        counts = list(self._validate_runs.values()) \
            + list(self._profile_runs.values())
        return max(counts, default=0)

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "entries": len(self._store),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "max_evals_per_genome": self.max_evals_per_genome(),
        }
