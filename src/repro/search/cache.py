"""Content-addressed evaluation cache (thread-safe, optionally persistent).

Interpret-mode Pallas validation dominates a search's wall-clock; the
sequential Algorithm-1 loop happily re-validates a genome it already saw
(every revert does). The cache keys each evaluation by
``(kernel, genome-digest, suite-digest)`` so a repeated variant is a dict
hit — validation and profiling each run **at most once per unique genome**
per suite, an invariant the cache itself enforces and exposes via
``stats()`` / ``max_evals_per_genome``.

The invariant holds under concurrency: ``evaluate`` (and the tiered
evaluator, which shares the same primitives) serializes work per key
through ``key_lock``, so racing threads asking for the same genome get one
computation and N-1 hits.

Entries may be *unvalidated* (baseline genomes are correct by construction,
so strategies profile them without paying for validation). A later request
that needs a verdict upgrades the entry in place, reusing the stored
profile.

With ``persist_path`` the cache is also durable: every entry is appended to
a JSON-lines file keyed by the same digests plus a **code-version salt**
(a hash of the kernel sources, cost model, and agents), so repeated
``benchmarks/run.py`` / CI invocations skip re-validating genomes an
earlier process already proved — and a source change invalidates the whole
file rather than serving stale verdicts. Screened entries are never
persisted (they carry no correctness verdict and cost almost nothing to
recompute).
"""

from __future__ import annotations

import dataclasses
import glob
import hashlib
import json
import os
import threading
from collections import Counter

from repro.search.types import EvalResult, genome_digest, suite_digest

_SALT_LOCK = threading.Lock()
_SALT: str | None = None
_PERSIST_FORMAT = "v1"


def code_version_salt() -> str:
    """Hash of the sources an evaluation's outcome depends on (kernel
    modules, cost model, agents). Folded into every persistent-cache entry:
    editing any of those files invalidates prior entries wholesale."""
    global _SALT
    with _SALT_LOCK:
        if _SALT is None:
            # repro may be a namespace package (__file__ is None): anchor on
            # a concrete submodule instead.
            from repro.core import costmodel
            root = os.path.dirname(os.path.dirname(costmodel.__file__))
            files = sorted(glob.glob(os.path.join(root, "kernels", "*.py")))
            files += [os.path.join(root, "core", "costmodel.py"),
                      os.path.join(root, "core", "agents.py")]
            h = hashlib.sha256(_PERSIST_FORMAT.encode())
            for f in files:
                with open(f, "rb") as fh:
                    h.update(fh.read())
            _SALT = h.hexdigest()[:12]
        return _SALT


def _jsonable(obj):
    """JSON fallback for numpy scalars inside Profile rows."""
    try:
        return float(obj)
    except (TypeError, ValueError):
        return str(obj)


class EvalCache:
    """Memoizes (validate, profile) per unique (kernel, genome, suite)."""

    def __init__(self, *, persist_path: str | None = None) -> None:
        self._store: dict[tuple, EvalResult] = {}
        self._lock = threading.Lock()
        self._persist_lock = threading.Lock()
        self._key_locks: dict[tuple, threading.Lock] = {}
        self.hits = 0
        self.misses = 0
        self.preloaded = 0              # entries restored from persist_path
        self._validate_runs: Counter = Counter()
        self._profile_runs: Counter = Counter()
        self.persist_path = persist_path
        if persist_path:
            self._load_persistent()

    def key(self, kernel: str, variant, tests=None, *,
            tests_digest: str | None = None) -> tuple:
        sd = tests_digest if tests_digest is not None else suite_digest(tests)
        return (kernel, genome_digest(variant), sd)

    # -- concurrency primitives (shared with the tiered evaluator) ----------

    def key_lock(self, key: tuple) -> threading.Lock:
        """Per-key lock: whoever holds it owns computing that entry."""
        with self._lock:
            lk = self._key_locks.get(key)
            if lk is None:
                lk = self._key_locks.setdefault(key, threading.Lock())
            return lk

    def get(self, key: tuple) -> EvalResult | None:
        with self._lock:
            return self._store.get(key)

    def try_hit(self, key: tuple, *, validate: bool = True) -> EvalResult | None:
        """THE hit condition, single-sourced for the legacy and tiered
        paths: a validated entry always hits; screened entries are this
        process's final verdict so they hit too; unvalidated entries hit
        only when the caller doesn't need a verdict. Counts the hit and
        returns the entry marked ``cached``, else None (caller computes
        under the key lock)."""
        entry = self.get(key)
        if entry is not None and (entry.validated or entry.screened
                                  or not validate):
            self.count_hit()
            return dataclasses.replace(entry, cached=True)
        return None

    def put(self, key: tuple, result: EvalResult, *,
            persist: bool = True) -> None:
        with self._lock:
            self._store[key] = result
        # disk append outside the store lock: readers never stall on I/O
        if self.persist_path and persist and not result.screened:
            with self._persist_lock:
                self._append_persistent(key, result)

    def count_hit(self) -> None:
        with self._lock:
            self.hits += 1

    def count_miss(self) -> None:
        with self._lock:
            self.misses += 1

    def note_validate_run(self, key: tuple) -> None:
        with self._lock:
            self._validate_runs[key] += 1

    def note_profile_run(self, key: tuple) -> None:
        with self._lock:
            self._profile_runs[key] += 1

    # -- the memoized evaluation --------------------------------------------

    def evaluate(self, space, variant, tests, *, testing, profiling,
                 validate: bool = True,
                 tests_digest: str | None = None) -> EvalResult:
        """Return the (possibly cached) evaluation of ``variant``.

        ``validate=False`` skips the correctness run and records the entry
        as unvalidated with ``passed=True`` (callers use this only for
        genomes correct by construction, e.g. the shipped baseline).

        Thread-safe: concurrent calls for the same genome serialize on the
        per-key lock, so validation/profiling still run at most once.

        This is the *legacy* sequential pipeline: unlike the tiered
        evaluator it calls ``testing.validate`` once with the whole suite
        (a contract test doubles rely on) and recomputes the oracle per
        genome. Cache semantics are shared with ``TieredEvaluator.evaluate``
        through ``try_hit``.
        """
        k = self.key(space.name, variant, tests, tests_digest=tests_digest)
        with self.key_lock(k):
            hit = self.try_hit(k, validate=validate)
            if hit is not None:
                return hit
            self.count_miss()
            entry = self.get(k)
            if entry is not None:
                # Upgrade an unvalidated entry: run validation once, keep the
                # stored profile (profiling already ran for this genome).
                passed, max_err = testing.validate(space, variant, tests)
                self.note_validate_run(k)
                result = EvalResult(passed, max_err, entry.profile,
                                    validated=True)
            else:
                if validate:
                    passed, max_err = testing.validate(space, variant, tests)
                    self.note_validate_run(k)
                else:
                    passed, max_err = True, 0.0
                profile = profiling.profile(space, variant, tests)
                self.note_profile_run(k)
                result = EvalResult(passed, max_err, profile,
                                    validated=validate)
            self.put(k, result)
            return result

    # -- persistence ---------------------------------------------------------

    def _append_persistent(self, key: tuple, result: EvalResult) -> None:
        # caller holds self._persist_lock; one write() call per entry keeps
        # lines whole even when several processes append to the same file
        rec = {
            "salt": code_version_salt(),
            "key": list(key),
            "passed": bool(result.passed),
            "max_err": float(result.max_err),
            "validated": bool(result.validated),
            "profile": dataclasses.asdict(result.profile),
        }
        os.makedirs(os.path.dirname(self.persist_path) or ".", exist_ok=True)
        with open(self.persist_path, "a") as f:
            f.write(json.dumps(rec, default=_jsonable) + "\n")

    def _load_persistent(self) -> None:
        if not os.path.exists(self.persist_path):
            return
        from repro.core.agents import Profile
        salt = code_version_salt()
        with open(self.persist_path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                    if rec.get("salt") != salt:
                        continue        # stale code version
                    result = EvalResult(
                        bool(rec["passed"]), float(rec["max_err"]),
                        Profile(**rec["profile"]),
                        validated=bool(rec["validated"]))
                except (KeyError, TypeError, ValueError):
                    continue            # torn/foreign line: ignore
                # later lines win (an upgrade appends a second record)
                key = tuple(rec["key"])
                if key not in self._store:
                    self.preloaded += 1
                self._store[key] = result

    # -- introspection ------------------------------------------------------

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)

    def __contains__(self, key: tuple) -> bool:
        with self._lock:
            return key in self._store

    def max_evals_per_genome(self) -> int:
        """Worst-case number of validation/profiling runs for any genome —
        the memoization invariant says this never exceeds 1."""
        with self._lock:
            counts = list(self._validate_runs.values()) \
                + list(self._profile_runs.values())
        return max(counts, default=0)

    def stats(self) -> dict:
        with self._lock:
            entries, hits, misses = len(self._store), self.hits, self.misses
            preloaded = self.preloaded
        total = hits + misses
        return {
            "entries": entries,
            "hits": hits,
            "misses": misses,
            "hit_rate": hits / total if total else 0.0,
            "preloaded": preloaded,
            "max_evals_per_genome": self.max_evals_per_genome(),
        }
