"""Content-addressed evaluation cache (thread-safe, optionally persistent).

Interpret-mode Pallas validation dominates a search's wall-clock; the
sequential Algorithm-1 loop happily re-validates a genome it already saw
(every revert does). The cache keys each evaluation by
``(kernel, genome-digest, suite-digest)`` so a repeated variant is a dict
hit — validation and profiling each run **at most once per unique genome**
per suite, an invariant the cache itself enforces and exposes via
``stats()`` / ``max_evals_per_genome``.

The invariant holds under concurrency: ``evaluate`` (and the tiered
evaluator, which shares the same primitives) serializes work per key
through ``key_lock``, so racing threads asking for the same genome get one
computation and N-1 hits.

Entries may be *unvalidated* (baseline genomes are correct by construction,
so strategies profile them without paying for validation). A later request
that needs a verdict upgrades the entry in place, reusing the stored
profile.

With ``persist_path`` the cache is also durable: every entry is appended to
a JSON-lines file keyed by the same digests plus a **code-version salt**
(a hash of the kernel sources, cost model, and agents), so repeated
``benchmarks/run.py`` / CI invocations skip re-validating genomes an
earlier process already proved — and a source change invalidates the whole
file rather than serving stale verdicts. Screened entries are never
persisted (they carry no correctness verdict and cost almost nothing to
recompute); quarantined (``finish_reason="crashed"``) entries ARE — a
genome that repeatedly killed its worker must never be re-run, not even by
a later process.

A process killed mid-append (``kill -9``, OOM) leaves a torn final line.
The loader tolerates it: the valid prefix is kept, the torn tail is
reported via ``warnings.warn`` and physically truncated on the next flush,
and ``benchmarks/run.py`` proceeds instead of crashing.
"""

from __future__ import annotations

import dataclasses
import glob
import hashlib
import json
import os
import threading
import warnings
from collections import Counter

from repro.search.types import EvalResult, genome_digest, suite_digest

_SALT_LOCK = threading.Lock()
_SALT: str | None = None
_PERSIST_FORMAT = "v1"


def code_version_salt() -> str:
    """Hash of the sources an evaluation's outcome depends on (kernel
    modules, cost model, agents). Folded into every persistent-cache entry:
    editing any of those files invalidates prior entries wholesale."""
    global _SALT
    with _SALT_LOCK:
        if _SALT is None:
            # repro may be a namespace package (__file__ is None): anchor on
            # a concrete submodule instead.
            from repro.core import costmodel
            root = os.path.dirname(os.path.dirname(costmodel.__file__))
            files = sorted(glob.glob(os.path.join(root, "kernels", "*.py")))
            files += [os.path.join(root, "core", "costmodel.py"),
                      os.path.join(root, "core", "agents.py")]
            h = hashlib.sha256(_PERSIST_FORMAT.encode())
            for f in files:
                with open(f, "rb") as fh:
                    h.update(fh.read())
            _SALT = h.hexdigest()[:12]
        return _SALT


def _jsonable(obj):
    """JSON fallback for numpy scalars inside Profile rows."""
    try:
        return float(obj)
    except (TypeError, ValueError):
        return str(obj)


def encode_result(result: EvalResult) -> dict:
    """JSON-able payload for one evaluation outcome — shared between the
    persistent cache and the search journal so both round-trip the same
    fields. ``cached``/``replayed`` are delivery-time flags, not outcomes,
    and are never persisted."""
    return {
        "passed": bool(result.passed),
        "max_err": float(result.max_err),
        "validated": bool(result.validated),
        "screened": bool(result.screened),
        "finish_reason": result.finish_reason,
        "error": result.error,
        "failed_test": int(result.failed_test),
        "profile": dataclasses.asdict(result.profile),
    }


def decode_result(rec: dict, *, replayed: bool = False) -> EvalResult:
    """Inverse of ``encode_result`` (tolerates records from older formats
    that predate the lifecycle fields)."""
    from repro.core.agents import Profile
    return EvalResult(
        bool(rec["passed"]), float(rec["max_err"]),
        Profile(**rec["profile"]),
        validated=bool(rec["validated"]),
        screened=bool(rec.get("screened", False)),
        finish_reason=rec.get("finish_reason", "ok"),
        error=rec.get("error"),
        failed_test=int(rec.get("failed_test", -1)),
        replayed=replayed)


class EvalCache:
    """Memoizes (validate, profile) per unique (kernel, genome, suite)."""

    def __init__(self, *, persist_path: str | None = None) -> None:
        self._store: dict[tuple, EvalResult] = {}
        self._lock = threading.Lock()
        self._persist_lock = threading.Lock()
        self._key_locks: dict[tuple, threading.Lock] = {}
        self.hits = 0
        self.misses = 0
        self.preloaded = 0              # entries restored from persist_path
        self._validate_runs: Counter = Counter()
        self._profile_runs: Counter = Counter()
        self.persist_path = persist_path
        # byte offset to truncate the persistent file to before the next
        # append — set when the loader finds a torn trailing line (the
        # artifact of a killed writer)
        self._truncate_at: int | None = None
        if persist_path:
            self._load_persistent()

    def key(self, kernel: str, variant, tests=None, *,
            tests_digest: str | None = None) -> tuple:
        sd = tests_digest if tests_digest is not None else suite_digest(tests)
        return (kernel, genome_digest(variant), sd)

    # -- concurrency primitives (shared with the tiered evaluator) ----------

    def key_lock(self, key: tuple) -> threading.Lock:
        """Per-key lock: whoever holds it owns computing that entry."""
        with self._lock:
            lk = self._key_locks.get(key)
            if lk is None:
                lk = self._key_locks.setdefault(key, threading.Lock())
            return lk

    def get(self, key: tuple) -> EvalResult | None:
        with self._lock:
            return self._store.get(key)

    def try_hit(self, key: tuple, *, validate: bool = True) -> EvalResult | None:
        """THE hit condition, single-sourced for the legacy and tiered
        paths: a validated entry always hits; screened entries are this
        process's final verdict so they hit too; unvalidated entries hit
        only when the caller doesn't need a verdict. Counts the hit and
        returns the entry marked ``cached``, else None (caller computes
        under the key lock)."""
        entry = self.get(key)
        if entry is not None and (entry.validated or entry.screened
                                  or entry.failed_infra or not validate):
            self.count_hit()
            return dataclasses.replace(entry, cached=True)
        return None

    def put(self, key: tuple, result: EvalResult, *,
            persist: bool = True) -> None:
        with self._lock:
            self._store[key] = result
        # disk append outside the store lock: readers never stall on I/O
        if self.persist_path and persist and not result.screened:
            with self._persist_lock:
                self._append_persistent(key, result)

    def count_hit(self) -> None:
        with self._lock:
            self.hits += 1

    def count_miss(self) -> None:
        with self._lock:
            self.misses += 1

    def note_validate_run(self, key: tuple) -> None:
        with self._lock:
            self._validate_runs[key] += 1

    def note_profile_run(self, key: tuple) -> None:
        with self._lock:
            self._profile_runs[key] += 1

    def clear_replayed(self, key: tuple) -> None:
        """Drop the journal-replay marker after its one-time delivery so a
        later search hitting the same entry doesn't re-apply its failure
        statistics."""
        with self._lock:
            entry = self._store.get(key)
            if entry is not None and entry.replayed:
                self._store[key] = dataclasses.replace(entry, replayed=False)

    # -- the memoized evaluation --------------------------------------------

    def evaluate(self, space, variant, tests, *, testing, profiling,
                 validate: bool = True,
                 tests_digest: str | None = None) -> EvalResult:
        """Return the (possibly cached) evaluation of ``variant``.

        ``validate=False`` skips the correctness run and records the entry
        as unvalidated with ``passed=True`` (callers use this only for
        genomes correct by construction, e.g. the shipped baseline).

        Thread-safe: concurrent calls for the same genome serialize on the
        per-key lock, so validation/profiling still run at most once.

        This is the *legacy* sequential pipeline: unlike the tiered
        evaluator it calls ``testing.validate`` once with the whole suite
        (a contract test doubles rely on) and recomputes the oracle per
        genome. Cache semantics are shared with ``TieredEvaluator.evaluate``
        through ``try_hit``.
        """
        k = self.key(space.name, variant, tests, tests_digest=tests_digest)
        with self.key_lock(k):
            hit = self.try_hit(k, validate=validate)
            if hit is not None:
                return hit
            self.count_miss()
            entry = self.get(k)
            if entry is not None:
                # Upgrade an unvalidated entry: run validation once, keep the
                # stored profile (profiling already ran for this genome).
                passed, max_err = testing.validate(space, variant, tests)
                self.note_validate_run(k)
                result = EvalResult(passed, max_err, entry.profile,
                                    validated=True)
            else:
                if validate:
                    passed, max_err = testing.validate(space, variant, tests)
                    self.note_validate_run(k)
                else:
                    passed, max_err = True, 0.0
                profile = profiling.profile(space, variant, tests)
                self.note_profile_run(k)
                result = EvalResult(passed, max_err, profile,
                                    validated=validate)
            self.put(k, result)
            return result

    # -- persistence ---------------------------------------------------------

    def _append_persistent(self, key: tuple, result: EvalResult) -> None:
        # caller holds self._persist_lock; one write() call per entry keeps
        # lines whole even when several processes append to the same file
        rec = dict(salt=code_version_salt(), key=list(key),
                   **encode_result(result))
        os.makedirs(os.path.dirname(self.persist_path) or ".", exist_ok=True)
        if self._truncate_at is not None:
            # first flush after loading a torn file: cut the file back to
            # its valid prefix so the garbage tail never accumulates
            with open(self.persist_path, "r+") as f:
                f.truncate(self._truncate_at)
            self._truncate_at = None
        with open(self.persist_path, "a") as f:
            f.write(json.dumps(rec, default=_jsonable) + "\n")

    def _load_persistent(self) -> None:
        if not os.path.exists(self.persist_path):
            return
        salt = code_version_salt()
        with open(self.persist_path, "rb") as f:
            raw = f.read()
        lines = raw.split(b"\n")
        # a well-formed file ends with "\n": the final split element is
        # empty. Anything else is a torn trailing write.
        offset = 0
        for i, bline in enumerate(lines):
            is_last = i == len(lines) - 1
            if is_last and bline == b"":
                break                   # clean EOF
            line = bline.decode("utf-8", errors="replace").strip()
            if not line and not is_last:
                offset += len(bline) + 1
                continue
            try:
                rec = json.loads(line)
                result = decode_result(rec)
                key = tuple(rec["key"])
            except (KeyError, TypeError, ValueError):
                if is_last:
                    # the kill -9 artifact: keep the valid prefix, schedule
                    # a physical truncation for the next flush
                    warnings.warn(
                        f"evalcache {self.persist_path}: truncated/corrupt "
                        f"trailing line ({len(bline)} bytes) skipped; file "
                        "will be truncated on next flush")
                    self._truncate_at = offset
                else:
                    warnings.warn(
                        f"evalcache {self.persist_path}: skipping corrupt "
                        f"line {i + 1}")
                    offset += len(bline) + 1
                continue
            offset += len(bline) + 1
            if rec.get("salt") != salt:
                continue                # stale code version
            # later lines win (an upgrade appends a second record)
            if key not in self._store:
                self.preloaded += 1
            self._store[key] = result

    # -- introspection ------------------------------------------------------

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)

    def __contains__(self, key: tuple) -> bool:
        with self._lock:
            return key in self._store

    def max_evals_per_genome(self) -> int:
        """Worst-case number of validation/profiling runs for any genome —
        the memoization invariant says this never exceeds 1."""
        with self._lock:
            counts = list(self._validate_runs.values()) \
                + list(self._profile_runs.values())
        return max(counts, default=0)

    def stats(self) -> dict:
        with self._lock:
            entries, hits, misses = len(self._store), self.hits, self.misses
            preloaded = self.preloaded
        total = hits + misses
        return {
            "entries": entries,
            "hits": hits,
            "misses": misses,
            "hit_rate": hits / total if total else 0.0,
            "preloaded": preloaded,
            "max_evals_per_genome": self.max_evals_per_genome(),
        }
