"""Search strategies over a kernel's knob genome.

``SearchStrategy`` is the pluggable policy for *which* candidates to try;
the four agents (testing / profiling / planning / coding) and the
evaluation cache are shared infrastructure handed in via ``SearchContext``.

  * ``GreedyChain``  — the paper's Algorithm 1, verbatim: one suggestion,
    one variant, one evaluation per round. The default; preserves the
    historical ``optimize()`` behavior exactly.
  * ``BeamSearch``   — keeps the top-``width`` correct candidates as a
    frontier; the planning agent proposes several moves per frontier
    member per round and the cache guarantees no genome is evaluated
    twice. Strictly explores a superset of the greedy chain (the chain's
    move is always proposal #1 from its own lineage).
  * ``Population``   — random-restart + mutation over the knob genome:
    seeded random initial population, elitist selection on cached
    evaluations, random single-knob mutations per generation.

Every strategy returns the same ``Log`` the sequential loop produced, so
``log.best()`` / ``log.speedup()`` / reintegration work unchanged.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Any

from repro.core.agents import Suggestion
from repro.core.oplog import Log, LogEntry
from repro.search.cache import EvalCache
from repro.search.types import EvalResult, genome_digest, suite_digest


@dataclasses.dataclass
class SearchContext:
    """Everything a strategy needs: the space, the four agents, the suite
    T, the shared evaluation cache (plus the tiered evaluator and its
    worker budget), and the round budget."""
    space: Any
    testing: Any
    profiling: Any
    planning: Any
    coding: Any
    tests: list
    cache: EvalCache
    rounds: int = 5
    verbose: bool = False
    tests_digest: str = ""
    evaluator: Any = None           # TieredEvaluator; None = legacy path
    workers: int = 1                # evaluate_many concurrency
    isolation: str = "thread"       # "process": sandboxed eval workers
    pool: Any = None                # workers.EvalWorkerPool (process mode)
    journal: Any = None             # journal.SearchJournal; None = off

    def __post_init__(self) -> None:
        if not self.tests_digest:
            # identical shapes/dtypes can still carry different data (agent
            # class or seed) or measurement fidelity (profiling reps): salt
            # the suite digest so evaluations never leak across rosters.
            salt = repr((type(self.testing).__qualname__,
                         getattr(self.testing, "seed", None),
                         getattr(self.profiling, "reps", None)))
            self.tests_digest = suite_digest(self.tests, salt=salt)

    def evaluate(self, variant, *, validate: bool = True) -> EvalResult:
        if self.evaluator is not None:
            if self.isolation == "process":
                # single-candidate strategies still get sandboxing: route
                # through the batch API, which owns the process path
                return self.evaluate_many([variant], validate=validate)[0]
            result = self.evaluator.evaluate(
                self.space, variant, self.tests,
                testing=self.testing, profiling=self.profiling,
                cache=self.cache, validate=validate,
                tests_digest=self.tests_digest)
        else:
            result = self.cache.evaluate(
                self.space, variant, self.tests,
                testing=self.testing, profiling=self.profiling,
                validate=validate, tests_digest=self.tests_digest)
        self._journal_results([variant], [result])
        return result

    def evaluate_many(self, variants, *,
                      validate: bool = True) -> list[EvalResult]:
        """Evaluate a batch of genomes — concurrently (and still
        deterministically) when an evaluator and ``workers > 1`` are set.
        Results align with ``variants``; duplicates collapse in the cache."""
        if self.evaluator is None:
            return [self.evaluate(v, validate=validate) for v in variants]
        results = self.evaluator.evaluate_many(
            self.space, variants, self.tests,
            testing=self.testing, profiling=self.profiling, cache=self.cache,
            validate=validate, tests_digest=self.tests_digest,
            workers=self.workers, isolation=self.isolation, pool=self.pool)
        self._journal_results(variants, results)
        return results

    def note_round(self, round_: int, variants) -> None:
        """Write-ahead: journal a round's candidate set before its
        evaluations (also the resume determinism self-check)."""
        if self.journal is not None:
            self.journal.record_round(
                round_, [genome_digest(v) for v in variants])

    def _journal_results(self, variants, results) -> None:
        # only freshly computed outcomes: cache hits (including journal
        # replays, which arrive as hits) are already durable
        if self.journal is None:
            return
        for variant, result in zip(variants, results):
            if not result.cached:
                self.journal.record_eval(
                    self.cache.key(self.space.name, variant,
                                   tests_digest=self.tests_digest), result)

    def history_entry(self, variant, result: EvalResult,
                      suggestion=None) -> dict:
        """The planning agents consume history as a list of these dicts."""
        return {"variant": variant, "passed": result.passed,
                "profile": result.profile, "suggestion": suggestion}


class SearchStrategy:
    """Interface: consume a SearchContext, produce an optimization Log."""

    name = "abstract"

    def run(self, ctx: SearchContext) -> Log:
        raise NotImplementedError


class GreedyChain(SearchStrategy):
    """Algorithm 1 (paper §3.2) — the strictly sequential greedy chain."""

    name = "greedy"

    def run(self, ctx: SearchContext) -> Log:
        space = ctx.space
        s_prev = space.baseline
        ctx.note_round(0, [s_prev])
        base = ctx.evaluate(s_prev, validate=False)
        log = Log()
        log.append(LogEntry(0, s_prev, True, base.profile,
                            rationale="baseline"))
        pass_prev, perf_prev = True, base.profile
        history = [ctx.history_entry(s_prev, base)]

        for r in range(1, ctx.rounds + 1):
            sugg = ctx.planning.suggest(space, s_prev, pass_prev, perf_prev,
                                        history)
            s_new = ctx.coding.apply(space, s_prev, sugg)
            ctx.note_round(r, [s_new])
            res = ctx.evaluate(s_new)
            log.append(LogEntry(r, s_new, res.passed, res.profile,
                                rationale=sugg.rationale,
                                max_err=res.max_err))
            history.append(ctx.history_entry(s_new, res, sugg))
            s_prev, pass_prev, perf_prev = s_new, res.passed, res.profile
            if ctx.verbose:
                print(f"[{space.name}] round {r}: {sugg.rationale}")
                print(f"    -> {s_new.describe()}  "
                      f"{'OK' if res.passed else 'FAIL'} "
                      f"{res.profile.geomean_latency_us:.2f}us"
                      + (" (cached)" if res.cached else ""))
        return log


class BeamSearch(SearchStrategy):
    """Top-k frontier search: each round expands every frontier candidate
    with up to ``width`` planner proposals, evaluates the novel genomes
    through the cache, and keeps the ``width`` best (correct-first, then
    by latency)."""

    name = "beam"

    def __init__(self, width: int = 4):
        if width < 1:
            raise ValueError("beam width must be >= 1")
        self.width = width

    def run(self, ctx: SearchContext) -> Log:
        space = ctx.space
        ctx.note_round(0, [space.baseline])
        base = ctx.evaluate(space.baseline, validate=False)
        log = Log()
        log.append(LogEntry(0, space.baseline, True, base.profile,
                            rationale="baseline"))
        seen = {genome_digest(space.baseline)}
        base_hist = [ctx.history_entry(space.baseline, base)]
        # frontier: (variant, result, lineage history for the planner)
        frontier = [(space.baseline, base, base_hist)]

        for r in range(1, ctx.rounds + 1):
            # Phase 1: expand every frontier member into its novel children
            # (planning/coding only — no evaluation yet).
            batch = []                  # (child, suggestion, parent history)
            for var, res, hist in frontier:
                suggs = ctx.planning.suggest_many(
                    space, var, res.passed, res.profile, hist, k=self.width)
                for sugg in suggs:
                    child = ctx.coding.apply(space, var, sugg)
                    dg = genome_digest(child)
                    if dg in seen:
                        continue        # genome already explored this search
                    seen.add(dg)
                    batch.append((child, sugg, hist))
            # Phase 2: evaluate the round's novel genomes as one concurrent
            # batch; results come back in proposal order, so the Log is
            # identical to the old one-at-a-time loop.
            ctx.note_round(r, [c for c, _, _ in batch])
            results = ctx.evaluate_many([c for c, _, _ in batch])
            children = []
            for (child, sugg, hist), cres in zip(batch, results):
                log.append(LogEntry(r, child, cres.passed, cres.profile,
                                    rationale=f"beam: {sugg.rationale}",
                                    max_err=cres.max_err))
                children.append(
                    (child, cres,
                     hist + [ctx.history_entry(child, cres, sugg)]))
            if not children:
                break                   # move space exhausted
            pool = frontier + children
            pool.sort(key=lambda t: (not t[1].passed,
                                     t[1].profile.geomean_latency_us))
            frontier = pool[:self.width]
            if ctx.verbose:
                lead = frontier[0]
                print(f"[{space.name}] beam round {r}: "
                      f"{len(children)} new genomes, frontier lead "
                      f"{lead[1].profile.geomean_latency_us:.2f}us "
                      f"({lead[0].describe()})")
        return log


class Population(SearchStrategy):
    """Random-restart + mutation over the knob genome.

    Seeded and fully deterministic: a random initial population around the
    baseline, elitist survivor selection on cached evaluations, single-knob
    mutations plus a fresh random restart each generation.
    """

    name = "population"

    def __init__(self, size: int = 8, survivors: int = 3, seed: int = 0):
        if size < 2:
            raise ValueError("population size must be >= 2")
        self.size = size
        self.survivors = max(1, min(survivors, size))
        self.seed = seed

    # -- genome samplers ----------------------------------------------------

    def _random_value(self, knob, rng: random.Random):
        if knob.kind == "bool":
            return rng.random() < 0.5
        lo_e = (knob.lo - 1).bit_length()
        hi_e = (knob.hi - 1).bit_length()
        return min(knob.hi, max(knob.lo, 1 << rng.randint(lo_e, hi_e)))

    def _mutate(self, ctx: SearchContext, genome, rng: random.Random):
        knob = rng.choice(ctx.space.knobs)
        sugg = Suggestion(knob.name, self._random_value(knob, rng),
                          f"population: mutate {knob.name}")
        # the coding agent clamps the move to the knob's legal range
        return ctx.coding.apply(ctx.space, genome, sugg)

    def _restart(self, ctx: SearchContext, rng: random.Random):
        genome = ctx.space.baseline
        for _ in range(rng.randint(1, len(ctx.space.knobs))):
            genome = self._mutate(ctx, genome, rng)
        return genome

    # -- the generational loop ----------------------------------------------

    def run(self, ctx: SearchContext) -> Log:
        space = ctx.space
        rng = random.Random(self.seed)
        ctx.note_round(0, [space.baseline])
        base = ctx.evaluate(space.baseline, validate=False)
        log = Log()
        log.append(LogEntry(0, space.baseline, True, base.profile,
                            rationale="baseline"))
        seen = {genome_digest(space.baseline)}
        scored = [(space.baseline, base)]

        population = [self._restart(ctx, rng)
                      for _ in range(self.size - 1)]
        for gen in range(1, ctx.rounds + 1):
            novel = []
            for genome in population:
                dg = genome_digest(genome)
                if dg in seen:
                    continue
                seen.add(dg)
                novel.append(genome)
            # one concurrent batch per generation; results in genome order
            ctx.note_round(gen, novel)
            for genome, res in zip(novel, ctx.evaluate_many(novel)):
                log.append(LogEntry(gen, genome, res.passed, res.profile,
                                    rationale=f"population gen {gen}",
                                    max_err=res.max_err))
                scored.append((genome, res))
            elite = sorted(
                scored, key=lambda t: (not t[1].passed,
                                       t[1].profile.geomean_latency_us)
            )[:self.survivors]
            if ctx.verbose:
                print(f"[{space.name}] population gen {gen}: "
                      f"{len(scored)} genomes scored, best "
                      f"{elite[0][1].profile.geomean_latency_us:.2f}us")
            # next generation: mutated elites + one fresh random restart
            population = [self._mutate(ctx, g, rng) for g, _ in elite]
            while len(population) < self.size - 1:
                population.append(
                    self._mutate(ctx, rng.choice(elite)[0], rng))
            population.append(self._restart(ctx, rng))
        return log


_STRATEGIES: dict[str, type] = {
    GreedyChain.name: GreedyChain,
    BeamSearch.name: BeamSearch,
    Population.name: Population,
}


def resolve_strategy(strategy) -> SearchStrategy:
    """Accepts a strategy name, class, or instance; returns an instance."""
    if isinstance(strategy, SearchStrategy):
        return strategy
    if isinstance(strategy, type) and issubclass(strategy, SearchStrategy):
        return strategy()
    if isinstance(strategy, str):
        try:
            return _STRATEGIES[strategy]()
        except KeyError:
            raise KeyError(f"unknown search strategy {strategy!r}; "
                           f"available: {sorted(_STRATEGIES)}") from None
    raise TypeError(f"cannot resolve a SearchStrategy from {strategy!r}")
