"""Pluggable kernel-optimization search subsystem.

The optimization core extracted from the sequential Algorithm-1 loop:
``Candidate`` / ``EvalResult`` datatypes, a content-addressed evaluation
cache (each unique genome is validated/profiled at most once), and
interchangeable search strategies (greedy chain, beam, population) that
share the four Astra agents.
"""

from repro.search.cache import EvalCache
from repro.search.orchestrator import (SearchOrchestrator, optimize,
                                       optimize_all, reintegrate)
from repro.search.strategies import (BeamSearch, GreedyChain, Population,
                                     SearchContext, SearchStrategy,
                                     resolve_strategy)
from repro.search.types import (Candidate, EvalResult, genome_digest,
                                genome_key, suite_digest)

__all__ = [
    "BeamSearch", "Candidate", "EvalCache", "EvalResult", "GreedyChain",
    "Population", "SearchContext", "SearchOrchestrator", "SearchStrategy",
    "genome_digest", "genome_key", "optimize", "optimize_all",
    "reintegrate", "resolve_strategy", "suite_digest",
]
