"""Pluggable kernel-optimization search subsystem.

The optimization core extracted from the sequential Algorithm-1 loop:
``Candidate`` / ``EvalResult`` datatypes, a content-addressed evaluation
cache (thread-safe, optionally persistent across processes; each unique
genome is validated/profiled at most once), the tiered evaluation engine
(cost-model screen -> smoke test -> full suite, shared-oracle memoization,
concurrent ``evaluate_many``), and interchangeable search strategies
(greedy chain, beam, population) that share the four Astra agents.
"""

from repro.search.cache import EvalCache, code_version_salt
from repro.search.evaluator import EvalStats, TieredEvaluator
from repro.search.orchestrator import (SearchOrchestrator, optimize,
                                       optimize_all, reintegrate)
from repro.search.strategies import (BeamSearch, GreedyChain, Population,
                                     SearchContext, SearchStrategy,
                                     resolve_strategy)
from repro.search.types import (Candidate, EvalResult, genome_digest,
                                genome_key, suite_digest)

__all__ = [
    "BeamSearch", "Candidate", "EvalCache", "EvalResult", "EvalStats",
    "GreedyChain", "Population", "SearchContext", "SearchOrchestrator",
    "SearchStrategy", "TieredEvaluator", "code_version_salt",
    "genome_digest", "genome_key", "optimize", "optimize_all",
    "reintegrate", "resolve_strategy", "suite_digest",
]
