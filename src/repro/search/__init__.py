"""Pluggable kernel-optimization search subsystem.

The optimization core extracted from the sequential Algorithm-1 loop:
``Candidate`` / ``EvalResult`` datatypes, a content-addressed evaluation
cache (thread-safe, optionally persistent across processes; each unique
genome is validated/profiled at most once), the tiered evaluation engine
(cost-model screen -> smoke test -> full suite, shared-oracle memoization,
concurrent ``evaluate_many``), and interchangeable search strategies
(greedy chain, beam, population) that share the four Astra agents.

Robustness layer (README § "Robust search"): ``EvalWorkerPool`` runs
evaluations in crash-isolated spawn workers with deadlines, retries, and
genome quarantine; ``SearchJournal`` makes a search resumable after
``kill -9`` with a bit-identical ``Log``.
"""

from repro.search.cache import (EvalCache, code_version_salt, decode_result,
                                encode_result)
from repro.search.evaluator import EvalStats, TieredEvaluator
from repro.search.journal import JournalMismatch, SearchJournal
from repro.search.orchestrator import (SearchFailure, SearchOrchestrator,
                                       optimize, optimize_all, reintegrate)
from repro.search.strategies import (BeamSearch, GreedyChain, Population,
                                     SearchContext, SearchStrategy,
                                     resolve_strategy)
from repro.search.types import (Candidate, EvalResult, genome_digest,
                                genome_key, suite_digest)
from repro.search.workers import EvalWorkerPool, Outcome

__all__ = [
    "BeamSearch", "Candidate", "EvalCache", "EvalResult", "EvalStats",
    "EvalWorkerPool", "GreedyChain", "JournalMismatch", "Outcome",
    "Population", "SearchContext", "SearchFailure", "SearchJournal",
    "SearchOrchestrator", "SearchStrategy", "TieredEvaluator",
    "code_version_salt", "decode_result", "encode_result", "genome_digest",
    "genome_key", "optimize", "optimize_all", "reintegrate",
    "resolve_strategy", "suite_digest",
]
