"""Core datatypes of the search subsystem.

A *candidate* is one point in a kernel's optimization space: the genome
(the frozen variant dataclass the coding agent edits) plus its lineage.
An *evaluation result* is everything the agents learn about a genome —
correctness verdict, max error, and the profiling agent's ``Profile``.

Genomes are content-addressed: ``genome_digest`` hashes the knob values
and ignores the cosmetic ``name`` field (which records the last move, not
the genome's identity), so two paths that reach the same knob settings
share one evaluation.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Sequence


def genome_key(variant) -> tuple:
    """Identity of a genome: (knob, value) pairs, ``name`` excluded."""
    return tuple((f.name, getattr(variant, f.name))
                 for f in dataclasses.fields(variant) if f.name != "name")


def genome_digest(variant) -> str:
    """Stable content hash of a genome (16 hex chars)."""
    payload = repr((type(variant).__name__,) + genome_key(variant))
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def suite_digest(tests: Sequence, *, salt: str = "") -> str:
    """Stable content hash of a test suite T.

    Keyed on each case's name (which encodes its shape) and dtype. Two
    agents can draw *different data* for identical shapes (different
    ``seed``) and profiling fidelity varies with ``reps``, neither of
    which is visible in the cases themselves — callers sharing a cache
    across agent rosters must fold those into ``salt`` (SearchContext
    does this automatically).
    """
    payload = repr([(t.name, str(t.shape_info.get("dtype")))
                    for t in tests]) + salt
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point in the search: a genome plus where it came from."""
    genome: Any
    round: int = 0
    suggestion: Any = None          # the Suggestion that produced it
    parent_digest: str | None = None

    @property
    def digest(self) -> str:
        return genome_digest(self.genome)


@dataclasses.dataclass(frozen=True)
class EvalResult:
    """What the testing + profiling agents learned about one genome."""
    passed: bool
    max_err: float
    profile: Any                    # agents.Profile
    validated: bool = True          # False: correctness assumed, not run
    cached: bool = False            # True: served from the evaluation cache
    # True: the cascade evaluator rejected this genome from the cost-model
    # profile alone (infeasible tile or clearly dominated) — interpret-mode
    # validation never ran, so ``validated`` is False and ``passed`` is a
    # screening verdict, not a correctness verdict.
    screened: bool = False
    # How the evaluation ended — mirroring the serving layer's per-request
    # lifecycle. "ok": the pipeline ran to a verdict. "screened": rejected
    # by the cost model alone. "crashed": the genome was quarantined after
    # repeatedly crashing/hanging its isolation worker; ``passed`` is False
    # and ``error`` carries the infra detail. Crashed verdicts are final:
    # the cache serves them forever and the genome is never re-run.
    finish_reason: str = "ok"
    error: str | None = None        # infra detail for crashed genomes
    # Suite index of the test that failed validation (-1: none failed).
    # Recorded so a resumed search can reconstruct the evaluator's
    # smoke-ordering failure statistics exactly.
    failed_test: int = -1
    # True: this entry was replayed from a search journal during --resume.
    # Its failure statistics must be re-applied once on delivery (a normal
    # cache hit must not double-count them). Never persisted.
    replayed: bool = False

    @property
    def latency_us(self) -> float:
        return self.profile.geomean_latency_us

    @property
    def failed_infra(self) -> bool:
        """True when the verdict reflects infrastructure failure (worker
        crash/timeout quarantine), not a correctness check."""
        return self.finish_reason == "crashed"
