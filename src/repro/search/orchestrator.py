"""The search orchestrator — wires the four agents, a strategy, and the
tiered evaluation engine into one ``optimize()`` entry point.

``optimize`` / ``optimize_all`` / ``reintegrate`` keep their historical
signatures (``repro.core.loop`` re-exports them), with additions: a
``strategy`` argument selecting ``"greedy"`` (the default — exact
Algorithm-1 semantics), ``"beam"``, ``"population"``, or any
``SearchStrategy`` instance; and ``workers=`` bounding how many candidates
the engine evaluates concurrently. Cache hit counts, per-search wall-clock,
and cascade stage counters are surfaced in the returned ``Log.meta`` and
in the verbose search log.

Robustness additions (see README § "Robust search"):

  * ``isolation="process"`` evaluates candidates in sandboxed spawn
    workers (``search/workers.EvalWorkerPool``, created lazily and closed
    by ``close()``) — a hung or crashing candidate costs a worker, never
    the search, and repeat offenders are quarantined.
  * ``search(..., journal=SearchJournal(path))`` makes the search
    resumable: journaled outcomes are seeded into the cache as replayed
    entries and the (deterministic) strategy fast-forwards through them.
  * ``optimize_all(keep_going=True)`` converts one kernel's infra failure
    into a ``SearchFailure`` record instead of aborting the whole bench.
"""

from __future__ import annotations

import time
import traceback

from repro.core.agents import (CodingAgent, PlanningAgent, ProfilingAgent,
                               TestingAgent)
from repro.core.oplog import Log
from repro.kernels.registry import KernelSpace, get_space, suite_tests
from repro.search.cache import EvalCache, decode_result
from repro.search.evaluator import TieredEvaluator
from repro.search.strategies import SearchContext, resolve_strategy


class SearchFailure(RuntimeError):
    """One kernel's search died of an infrastructure error. Carries the
    kernel name so keep-going callers can mark it failed and move on."""

    def __init__(self, kernel: str, cause: BaseException):
        super().__init__(f"search for {kernel!r} failed: {cause!r}")
        self.kernel = kernel
        self.cause = cause
        self.detail = "".join(traceback.format_exception_only(
            type(cause), cause)).strip()


class SearchOrchestrator:
    """Owns the agent roster, the (shareable) evaluation cache, and the
    tiered evaluator; runs any strategy over any registered kernel space."""

    def __init__(self, *, testing: TestingAgent | None = None,
                 profiling: ProfilingAgent | None = None,
                 planning: PlanningAgent | None = None,
                 coding: CodingAgent | None = None,
                 cache: EvalCache | None = None,
                 evaluator: TieredEvaluator | None = None,
                 workers: int = 4,
                 isolation: str = "thread",
                 pool=None,
                 pool_config: dict | None = None):
        if isolation not in ("thread", "process"):
            raise ValueError(f"unknown isolation mode {isolation!r}")
        self.testing = testing if testing is not None else TestingAgent()
        self.profiling = profiling if profiling is not None \
            else ProfilingAgent(reps=100)
        self.planning = planning if planning is not None else PlanningAgent()
        self.coding = coding if coding is not None else CodingAgent()
        # NOT `cache or ...`: an empty EvalCache has len() == 0 and would
        # be silently replaced, orphaning the caller's cache.
        self.cache = cache if cache is not None else EvalCache()
        self.evaluator = evaluator if evaluator is not None \
            else TieredEvaluator()
        self.workers = max(1, workers)
        self.isolation = isolation
        self._pool = pool               # caller-owned when passed in
        self._owns_pool = pool is None
        self._pool_config = dict(pool_config or {})

    def _ensure_pool(self):
        """Lazily spawn the worker pool on first process-isolated search
        (spawn-mode workers cost ~1s each to start)."""
        if self._pool is None:
            from repro.search.workers import EvalWorkerPool
            cfg = dict(self._pool_config)
            cfg.setdefault("workers", self.workers)
            self._pool = EvalWorkerPool(on_stat=self.evaluator.bump, **cfg)
        return self._pool

    def close(self) -> None:
        """Release the worker pool (no-op for thread isolation or a
        caller-owned pool)."""
        if self._owns_pool and self._pool is not None:
            self._pool.close()
            self._pool = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def search(self, kernel: str | KernelSpace, *, strategy="greedy",
               rounds: int = 5, verbose: bool = False, journal=None) -> Log:
        space = get_space(kernel) if isinstance(kernel, str) else kernel
        strat = resolve_strategy(strategy)
        tests = suite_tests(space, self.testing)
        pool = self._ensure_pool() if self.isolation == "process" else None
        ctx = SearchContext(space=space, testing=self.testing,
                            profiling=self.profiling, planning=self.planning,
                            coding=self.coding, tests=tests,
                            cache=self.cache, rounds=rounds, verbose=verbose,
                            evaluator=self.evaluator, workers=self.workers,
                            isolation=self.isolation, pool=pool,
                            journal=journal)
        resumed, replayed = False, 0
        if journal is not None:
            from repro.search.cache import code_version_salt
            config = {k: v for k, v in vars(strat).items()
                      if isinstance(v, (bool, int, float, str))}
            resumed = journal.open(
                kernel=space.name, strategy=strat.name,
                strategy_config=config, rounds=rounds,
                tests_digest=ctx.tests_digest, salt=code_version_salt())
            # seed journaled outcomes as replayed cache entries; existing
            # entries (e.g. from the persistent evalcache) take precedence
            # so both this run and an uninterrupted one see the same state
            for key, rec in journal.replay.items():
                if self.cache.get(key) is None:
                    self.cache.put(key, decode_result(rec, replayed=True),
                                   persist=False)
                    replayed += 1
        before = self.cache.stats()
        ebefore = self.evaluator.stats_dict()
        t0 = time.perf_counter()
        try:
            log = strat.run(ctx)
            if journal is not None:
                journal.finish(log)
        finally:
            if journal is not None:
                journal.close()
        wall = time.perf_counter() - t0
        after = self.cache.stats()
        eafter = self.evaluator.stats_dict()
        log.meta.update(
            kernel=space.name,
            strategy=strat.name,
            rounds=rounds,
            wall_s=wall,
            cache={
                "hits": after["hits"] - before["hits"],
                "misses": after["misses"] - before["misses"],
                "entries": after["entries"],
                "preloaded": after["preloaded"],
                "max_evals_per_genome": after["max_evals_per_genome"],
            },
            stages={k: eafter[k] - ebefore[k] for k in eafter},
            isolation=self.isolation,
        )
        if journal is not None:
            log.meta.update(journal={"path": journal.path,
                                     "resumed": resumed,
                                     "replayed": replayed})
        if verbose:
            c, s = log.meta["cache"], log.meta["stages"]
            print(f"[{space.name}] {strat.name}: {len(log.entries)} log "
                  f"entries in {wall:.2f}s, cache hits={c['hits']} "
                  f"misses={c['misses']}, screened="
                  f"{s['screened_infeasible'] + s['screened_dominated']} "
                  f"smoke_fails={s['validations_smoke_failed']} "
                  f"oracle_computations={s['oracle_computations']}")
        return log


def optimize(kernel: str | KernelSpace, *, rounds: int = 5,
             strategy="greedy",
             testing: TestingAgent | None = None,
             profiling: ProfilingAgent | None = None,
             planning: PlanningAgent | None = None,
             coding: CodingAgent | None = None,
             cache: EvalCache | None = None,
             evaluator: TieredEvaluator | None = None,
             workers: int = 4,
             isolation: str = "thread",
             pool_config: dict | None = None,
             journal=None,
             verbose: bool = False) -> Log:
    """Run one search on one kernel. Returns the optimization Log.

    With the default ``strategy="greedy"`` this is the paper's Algorithm 1,
    preserving the historical ``optimize()`` behavior (the tiered engine
    changes how evaluations are *scheduled and cached*, not their results).
    """
    orch = SearchOrchestrator(testing=testing, profiling=profiling,
                              planning=planning, coding=coding, cache=cache,
                              evaluator=evaluator, workers=workers,
                              isolation=isolation, pool_config=pool_config)
    with orch:
        return orch.search(kernel, strategy=strategy, rounds=rounds,
                           verbose=verbose, journal=journal)


def optimize_all(*, rounds: int = 5, strategy="greedy",
                 verbose: bool = False,
                 kernels: tuple[str, ...] = ("merge_attn_states_lse",
                                             "fused_add_rmsnorm",
                                             "silu_and_mul"),
                 cache: EvalCache | None = None,
                 workers: int = 4,
                 isolation: str = "thread",
                 pool_config: dict | None = None,
                 journals: dict | None = None,
                 keep_going: bool = False) -> dict[str, Log]:
    """Optimize the paper's kernels; returns {kernel: Log}. One orchestrator
    (one evaluation cache, one tiered evaluator, one worker pool) is shared
    across all searches.

    ``keep_going=True``: a kernel whose search dies of an infrastructure
    error maps to a ``SearchFailure`` instead of a ``Log`` — the remaining
    kernels still run, and the caller decides how to report the casualty
    (``benchmarks/run.py`` marks it ``failed`` in bench.json).
    ``journals`` optionally maps kernel name -> ``SearchJournal``.
    """
    results: dict[str, Log] = {}
    with SearchOrchestrator(cache=cache, workers=workers,
                            isolation=isolation,
                            pool_config=pool_config) as orch:
        for k in kernels:
            journal = (journals or {}).get(k)
            try:
                results[k] = orch.search(k, strategy=strategy, rounds=rounds,
                                         verbose=verbose, journal=journal)
            except Exception as exc:    # noqa: BLE001 — keep-going boundary
                if not keep_going:
                    raise
                results[k] = SearchFailure(k, exc)
    return results


def reintegrate(results: dict[str, Log]) -> None:
    """Post-processing (paper §3.2): install each kernel's best correct
    variant process-wide so the serving/training framework picks it up."""
    from repro.kernels import ops
    ops.set_variants(**{name: log.best().code
                        for name, log in results.items()})
