"""The search orchestrator — wires the four agents, a strategy, and the
tiered evaluation engine into one ``optimize()`` entry point.

``optimize`` / ``optimize_all`` / ``reintegrate`` keep their historical
signatures (``repro.core.loop`` re-exports them), with additions: a
``strategy`` argument selecting ``"greedy"`` (the default — exact
Algorithm-1 semantics), ``"beam"``, ``"population"``, or any
``SearchStrategy`` instance; and ``workers=`` bounding how many candidates
the engine evaluates concurrently. Cache hit counts, per-search wall-clock,
and cascade stage counters are surfaced in the returned ``Log.meta`` and
in the verbose search log.
"""

from __future__ import annotations

import time

from repro.core.agents import (CodingAgent, PlanningAgent, ProfilingAgent,
                               TestingAgent)
from repro.core.oplog import Log
from repro.kernels.registry import KernelSpace, get_space, suite_tests
from repro.search.cache import EvalCache
from repro.search.evaluator import TieredEvaluator
from repro.search.strategies import SearchContext, resolve_strategy


class SearchOrchestrator:
    """Owns the agent roster, the (shareable) evaluation cache, and the
    tiered evaluator; runs any strategy over any registered kernel space."""

    def __init__(self, *, testing: TestingAgent | None = None,
                 profiling: ProfilingAgent | None = None,
                 planning: PlanningAgent | None = None,
                 coding: CodingAgent | None = None,
                 cache: EvalCache | None = None,
                 evaluator: TieredEvaluator | None = None,
                 workers: int = 4):
        self.testing = testing if testing is not None else TestingAgent()
        self.profiling = profiling if profiling is not None \
            else ProfilingAgent(reps=100)
        self.planning = planning if planning is not None else PlanningAgent()
        self.coding = coding if coding is not None else CodingAgent()
        # NOT `cache or ...`: an empty EvalCache has len() == 0 and would
        # be silently replaced, orphaning the caller's cache.
        self.cache = cache if cache is not None else EvalCache()
        self.evaluator = evaluator if evaluator is not None \
            else TieredEvaluator()
        self.workers = max(1, workers)

    def search(self, kernel: str | KernelSpace, *, strategy="greedy",
               rounds: int = 5, verbose: bool = False) -> Log:
        space = get_space(kernel) if isinstance(kernel, str) else kernel
        strat = resolve_strategy(strategy)
        tests = suite_tests(space, self.testing)
        ctx = SearchContext(space=space, testing=self.testing,
                            profiling=self.profiling, planning=self.planning,
                            coding=self.coding, tests=tests,
                            cache=self.cache, rounds=rounds, verbose=verbose,
                            evaluator=self.evaluator, workers=self.workers)
        before = self.cache.stats()
        ebefore = self.evaluator.stats_dict()
        t0 = time.perf_counter()
        log = strat.run(ctx)
        wall = time.perf_counter() - t0
        after = self.cache.stats()
        eafter = self.evaluator.stats_dict()
        log.meta.update(
            kernel=space.name,
            strategy=strat.name,
            rounds=rounds,
            wall_s=wall,
            cache={
                "hits": after["hits"] - before["hits"],
                "misses": after["misses"] - before["misses"],
                "entries": after["entries"],
                "preloaded": after["preloaded"],
                "max_evals_per_genome": after["max_evals_per_genome"],
            },
            stages={k: eafter[k] - ebefore[k] for k in eafter},
        )
        if verbose:
            c, s = log.meta["cache"], log.meta["stages"]
            print(f"[{space.name}] {strat.name}: {len(log.entries)} log "
                  f"entries in {wall:.2f}s, cache hits={c['hits']} "
                  f"misses={c['misses']}, screened="
                  f"{s['screened_infeasible'] + s['screened_dominated']} "
                  f"smoke_fails={s['validations_smoke_failed']} "
                  f"oracle_computations={s['oracle_computations']}")
        return log


def optimize(kernel: str | KernelSpace, *, rounds: int = 5,
             strategy="greedy",
             testing: TestingAgent | None = None,
             profiling: ProfilingAgent | None = None,
             planning: PlanningAgent | None = None,
             coding: CodingAgent | None = None,
             cache: EvalCache | None = None,
             evaluator: TieredEvaluator | None = None,
             workers: int = 4,
             verbose: bool = False) -> Log:
    """Run one search on one kernel. Returns the optimization Log.

    With the default ``strategy="greedy"`` this is the paper's Algorithm 1,
    preserving the historical ``optimize()`` behavior (the tiered engine
    changes how evaluations are *scheduled and cached*, not their results).
    """
    orch = SearchOrchestrator(testing=testing, profiling=profiling,
                              planning=planning, coding=coding, cache=cache,
                              evaluator=evaluator, workers=workers)
    return orch.search(kernel, strategy=strategy, rounds=rounds,
                       verbose=verbose)


def optimize_all(*, rounds: int = 5, strategy="greedy",
                 verbose: bool = False,
                 kernels: tuple[str, ...] = ("merge_attn_states_lse",
                                             "fused_add_rmsnorm",
                                             "silu_and_mul"),
                 cache: EvalCache | None = None,
                 workers: int = 4) -> dict[str, Log]:
    """Optimize the paper's kernels; returns {kernel: Log}. One orchestrator
    (one evaluation cache, one tiered evaluator) is shared across all
    searches."""
    orch = SearchOrchestrator(cache=cache, workers=workers)
    return {k: orch.search(k, strategy=strategy, rounds=rounds,
                           verbose=verbose) for k in kernels}


def reintegrate(results: dict[str, Log]) -> None:
    """Post-processing (paper §3.2): install each kernel's best correct
    variant process-wide so the serving/training framework picks it up."""
    from repro.kernels import ops
    ops.set_variants(**{name: log.best().code
                        for name, log in results.items()})
