"""The search orchestrator — wires the four agents, a strategy, and the
evaluation cache into one ``optimize()`` entry point.

``optimize`` / ``optimize_all`` / ``reintegrate`` keep their historical
signatures (``repro.core.loop`` re-exports them), with one addition: a
``strategy`` argument selecting ``"greedy"`` (the default — exact
Algorithm-1 semantics), ``"beam"``, ``"population"``, or any
``SearchStrategy`` instance. Cache hit counts are surfaced in the returned
``Log.meta`` and in the verbose search log.
"""

from __future__ import annotations

from repro.core.agents import (CodingAgent, PlanningAgent, ProfilingAgent,
                               TestingAgent)
from repro.core.oplog import Log
from repro.kernels.registry import KernelSpace, get_space
from repro.search.cache import EvalCache
from repro.search.strategies import SearchContext, resolve_strategy


class SearchOrchestrator:
    """Owns the agent roster and the (shareable) evaluation cache; runs
    any strategy over any registered kernel space."""

    def __init__(self, *, testing: TestingAgent | None = None,
                 profiling: ProfilingAgent | None = None,
                 planning: PlanningAgent | None = None,
                 coding: CodingAgent | None = None,
                 cache: EvalCache | None = None):
        self.testing = testing if testing is not None else TestingAgent()
        self.profiling = profiling if profiling is not None \
            else ProfilingAgent(reps=100)
        self.planning = planning if planning is not None else PlanningAgent()
        self.coding = coding if coding is not None else CodingAgent()
        # NOT `cache or ...`: an empty EvalCache has len() == 0 and would
        # be silently replaced, orphaning the caller's cache.
        self.cache = cache if cache is not None else EvalCache()

    def search(self, kernel: str | KernelSpace, *, strategy="greedy",
               rounds: int = 5, verbose: bool = False) -> Log:
        space = get_space(kernel) if isinstance(kernel, str) else kernel
        strat = resolve_strategy(strategy)
        tests = self.testing.generate_tests(space)
        ctx = SearchContext(space=space, testing=self.testing,
                            profiling=self.profiling, planning=self.planning,
                            coding=self.coding, tests=tests,
                            cache=self.cache, rounds=rounds, verbose=verbose)
        before = self.cache.stats()
        log = strat.run(ctx)
        after = self.cache.stats()
        log.meta.update(
            kernel=space.name,
            strategy=strat.name,
            rounds=rounds,
            cache={
                "hits": after["hits"] - before["hits"],
                "misses": after["misses"] - before["misses"],
                "entries": after["entries"],
                "max_evals_per_genome": after["max_evals_per_genome"],
            },
        )
        if verbose:
            c = log.meta["cache"]
            print(f"[{space.name}] {strat.name}: {len(log.entries)} log "
                  f"entries, cache hits={c['hits']} misses={c['misses']}")
        return log


def optimize(kernel: str | KernelSpace, *, rounds: int = 5,
             strategy="greedy",
             testing: TestingAgent | None = None,
             profiling: ProfilingAgent | None = None,
             planning: PlanningAgent | None = None,
             coding: CodingAgent | None = None,
             cache: EvalCache | None = None,
             verbose: bool = False) -> Log:
    """Run one search on one kernel. Returns the optimization Log.

    With the default ``strategy="greedy"`` this is the paper's Algorithm 1,
    preserving the historical ``optimize()`` behavior.
    """
    orch = SearchOrchestrator(testing=testing, profiling=profiling,
                              planning=planning, coding=coding, cache=cache)
    return orch.search(kernel, strategy=strategy, rounds=rounds,
                       verbose=verbose)


def optimize_all(*, rounds: int = 5, strategy="greedy",
                 verbose: bool = False,
                 kernels: tuple[str, ...] = ("merge_attn_states_lse",
                                             "fused_add_rmsnorm",
                                             "silu_and_mul"),
                 cache: EvalCache | None = None) -> dict[str, Log]:
    """Optimize the paper's kernels; returns {kernel: Log}. One orchestrator
    (and one evaluation cache) is shared across all searches."""
    orch = SearchOrchestrator(cache=cache)
    return {k: orch.search(k, strategy=strategy, rounds=rounds,
                           verbose=verbose) for k in kernels}


def reintegrate(results: dict[str, Log]) -> None:
    """Post-processing (paper §3.2): install each kernel's best correct
    variant process-wide so the serving/training framework picks it up."""
    from repro.kernels import ops
    ops.set_variants(**{name: log.best().code
                        for name, log in results.items()})
