"""Tiered candidate-evaluation engine — the search subsystem's hot path.

Interpret-mode Pallas validation is what a search actually spends its
wall-clock on; the engine makes candidates cheap in three tiers, spending
the expensive stage only on genomes that survive the cheap ones:

  tier 0  cost-model screen   The analytic profile (microseconds to
                              compute) rejects candidates that can never
                              win: infeasible tiles and genomes whose
                              modeled latency is ``dominate_factor``× worse
                              than the best *validated* latency seen so
                              far. Screened genomes are recorded in the
                              cache and the Log as ``screened`` — never as
                              validated.
  tier 1  smoke test          One validation case first — the historically
                              most discriminative test (by failure count),
                              cheapest first on ties — so a numerically
                              broken genome pays for one interpret-mode run
                              instead of the whole suite.
  tier 2  full suite          Only survivors run the remaining cases, in
                              suite order. Verdicts always match the
                              sequential path; ``max_err`` matches it for
                              every passing genome (max over the whole
                              suite) and reflects the first failing test
                              in *cascade* order — not suite order — for
                              a genome that fails several tests.

The jnp oracle depends only on the test suite, never on the genome, so the
engine computes it **once per (kernel, suite)** via the registry memo and
shares it across every candidate of every search.

``evaluate_many`` evaluates a batch of genomes concurrently on a thread
pool. Results are deterministic regardless of completion order: screening
thresholds and smoke ordering are frozen at batch start, per-key locks in
the shared ``EvalCache`` guarantee each unique genome is validated/profiled
at most once even under races, and best-latency bookkeeping is replayed in
input order after the batch.

``evaluate_many(..., isolation="process", pool=...)`` runs the expensive
tier-1/2 work (profile + oracle + interpret-mode validation) in sandboxed
spawn-mode worker processes (``workers.EvalWorkerPool``): a candidate that
hangs, segfaults, or OOMs kills its *worker*, never the search. Infra
faults are retried with backoff; a genome that faults repeatedly is
**quarantined** — recorded in the cache as ``finish_reason="crashed"``
(``passed=False``) and never re-run. Batch-frozen thresholds are shipped
to the workers, so for well-behaved genomes the results are bit-identical
to the thread path. ``evaluate_many`` never raises on infra faults; the
verdict carries them.

``TieredEvaluator(screen=False, smoke=False, share_oracle=False)`` is the
reference configuration: it reproduces the sequential per-genome pipeline
exactly (same verdicts, same ``max_err``, same oracle cost) while still
metering work through the same counters — which is how the throughput win
is asserted in tests.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import Counter
from concurrent.futures import ThreadPoolExecutor

from repro.search.types import EvalResult, suite_digest

_UNSET = object()                   # "no frozen snapshot": live bookkeeping


@dataclasses.dataclass
class EvalStats:
    """Work counters for one evaluator — the stage accounting that
    ``benchmarks/run.py`` reports and the acceptance tests assert on."""
    oracle_computations: int = 0    # oracle(*args) evaluations (per test)
    validation_test_runs: int = 0   # interpret-mode (genome, test) runs
    validations_full: int = 0       # genomes that went past the smoke test
    validations_smoke_failed: int = 0   # genomes rejected by smoke alone
    screened_infeasible: int = 0    # genomes rejected by the cost model
    screened_dominated: int = 0     # genomes rejected as clearly dominated
    profile_runs: int = 0           # cost-model profiles computed
    # -- process-isolation infra counters (zero on the thread path) --------
    worker_crashes: int = 0         # worker process died mid-task
    eval_timeouts: int = 0          # per-task deadline expired (worker shot)
    corrupt_results: int = 0        # result checksum mismatches
    retries: int = 0                # task re-dispatches after infra faults
    recoveries: int = 0             # tasks that succeeded after >=1 fault
    quarantined: int = 0            # genomes written off as crashed
    workers_recycled: int = 0       # planned worker restarts (task budget)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def total_work(self) -> int:
        """Oracle evaluations + interpret-mode validation runs — the two
        expensive operations a search performs."""
        return self.oracle_computations + self.validation_test_runs


class TieredEvaluator:
    """Cascade screen -> smoke -> full-suite evaluation over a shared
    thread-safe ``EvalCache``. One instance may serve many searches (and
    many threads) concurrently; counters aggregate across all of them."""

    def __init__(self, *, screen: bool = True, smoke: bool = True,
                 share_oracle: bool = True, dominate_factor: float = 3.0):
        if dominate_factor <= 1.0:
            raise ValueError("dominate_factor must be > 1")
        self.screen = screen
        self.smoke = smoke
        self.share_oracle = share_oracle
        self.dominate_factor = dominate_factor
        self.stats = EvalStats()
        self._lock = threading.Lock()
        # per (kernel, suite-digest): best validated-correct latency and
        # per-test-index failure counts (smoke discriminative power)
        self._best_lat: dict[tuple, float] = {}
        self._fail_counts: dict[tuple, Counter] = {}

    # -- public API ----------------------------------------------------------

    def evaluate(self, space, variant, tests, *, testing, profiling, cache,
                 validate: bool = True, tests_digest: str | None = None,
                 _frozen=_UNSET) -> EvalResult:
        """Tiered, cached evaluation of one genome (thread-safe)."""
        sd = tests_digest if tests_digest is not None else suite_digest(tests)
        k = cache.key(space.name, variant, tests, tests_digest=sd)
        with cache.key_lock(k):
            result = cache.try_hit(k, validate=validate)
            if result is None:
                cache.count_miss()
                entry = cache.get(k)
                if entry is not None:       # upgrade: reuse stored profile
                    profile = entry.profile
                else:
                    profile = profiling.profile(space, variant, tests)
                    cache.note_profile_run(k)
                    with self._lock:
                        self.stats.profile_runs += 1
                if validate:
                    result = self._cascade(space, variant, tests, profile,
                                           testing, sd, k, cache,
                                           frozen=_frozen)
                else:
                    result = EvalResult(True, 0.0, profile, validated=False)
                cache.put(k, result)
        if _frozen is _UNSET:
            self._note_delivery((space.name, sd), result, key=k, cache=cache)
        return result

    def evaluate_many(self, space, variants, tests, *, testing, profiling,
                      cache, validate: bool = True,
                      tests_digest: str | None = None,
                      workers: int = 1, isolation: str = "thread",
                      pool=None) -> list[EvalResult]:
        """Evaluate a batch of genomes, concurrently when ``workers > 1``.

        Deterministic: screening thresholds and smoke ordering are frozen
        at batch start (so outcomes cannot depend on thread completion
        order), and the best-latency bookkeeping is replayed in input order
        afterwards. Duplicate genomes in the batch collapse to one
        computation via the cache's per-key locks.

        ``isolation="process"`` dispatches each genome to ``pool`` (an
        ``EvalWorkerPool``) instead of validating in-process; infra faults
        never raise — they surface as ``finish_reason="crashed"`` verdicts.
        """
        if isolation not in ("thread", "process"):
            raise ValueError(f"unknown isolation mode {isolation!r}")
        if isolation == "process" and pool is None:
            raise ValueError("isolation='process' requires an EvalWorkerPool")
        if not variants:
            return []
        sd = tests_digest if tests_digest is not None else suite_digest(tests)
        skey = (space.name, sd)
        with self._lock:
            frozen = (self._best_lat.get(skey),
                      dict(self._fail_counts.get(skey, ())))

        if isolation == "process":
            def one(variant):
                return self._evaluate_process(
                    space, variant, tests, testing=testing,
                    profiling=profiling, cache=cache, validate=validate,
                    sd=sd, frozen=frozen, pool=pool)
        else:
            def one(variant):
                return self.evaluate(space, variant, tests, testing=testing,
                                     profiling=profiling, cache=cache,
                                     validate=validate, tests_digest=sd,
                                     _frozen=frozen)

        if workers > 1 and len(variants) > 1:
            with ThreadPoolExecutor(
                    max_workers=min(workers, len(variants))) as tpool:
                results = list(tpool.map(one, variants))
        else:
            results = [one(v) for v in variants]
        for variant, result in zip(variants, results):  # deterministic order
            k = cache.key(space.name, variant, tests, tests_digest=sd)
            self._note_delivery(skey, result, key=k, cache=cache)
        return results

    # -- process isolation ---------------------------------------------------

    def _evaluate_process(self, space, variant, tests, *, testing, profiling,
                          cache, validate, sd, frozen, pool) -> EvalResult:
        """One genome through the sandboxed worker pool. Cache semantics
        match ``evaluate``; the expensive work happens in a spawned child.
        Never raises on infra faults — repeated faults become a quarantine
        verdict (``finish_reason="crashed"``) recorded in the cache."""
        k = cache.key(space.name, variant, tests, tests_digest=sd)
        with cache.key_lock(k):
            result = cache.try_hit(k, validate=validate)
            if result is None:
                cache.count_miss()
                prior = cache.get(k)
                task = {
                    "kernel": space.name,
                    "suite_shapes": space.suite_shapes,
                    "variant": variant,
                    "testing": testing,
                    "profiling": profiling,
                    "validate": validate,
                    "tests_digest": sd,
                    "frozen": None if frozen is _UNSET else frozen,
                    "config": {"screen": self.screen, "smoke": self.smoke,
                               "share_oracle": self.share_oracle,
                               "dominate_factor": self.dominate_factor},
                }
                outcome = pool.submit(task, digest=k[1])
                if outcome.ok:
                    result, deltas = outcome.result, outcome.stats
                    with self._lock:
                        for name in ("oracle_computations",
                                     "validation_test_runs",
                                     "validations_full",
                                     "validations_smoke_failed",
                                     "screened_infeasible",
                                     "screened_dominated",
                                     "profile_runs"):
                            setattr(self.stats, name,
                                    getattr(self.stats, name)
                                    + int(deltas.get(name, 0)))
                    if prior is None and not result.screened:
                        cache.note_profile_run(k)
                    if result.validated:
                        cache.note_validate_run(k)
                    cache.put(k, result)
                else:
                    # quarantined: the genome repeatedly killed its worker.
                    # The analytic profile is safe to compute in-parent (it
                    # never executes candidate code), so the Log still gets
                    # a latency estimate for the row.
                    profile = prior.profile if prior is not None \
                        else profiling.profile(space, variant, tests)
                    result = EvalResult(False, 0.0, profile, validated=False,
                                        finish_reason="crashed",
                                        error=outcome.error)
                    with self._lock:
                        self.stats.quarantined += 1
                    cache.put(k, result)     # persists: never re-run
        if frozen is _UNSET:
            self._note_delivery((space.name, sd), result, key=k, cache=cache)
        return result

    # -- the cascade ---------------------------------------------------------

    def _cascade(self, space, variant, tests, profile, testing, sd, key,
                 cache, *, frozen) -> EvalResult:
        skey = (space.name, sd)
        if self.screen:
            if profile.signals.get("infeasible"):
                with self._lock:
                    self.stats.screened_infeasible += 1
                return EvalResult(False, 0.0, profile, validated=False,
                                  screened=True, finish_reason="screened")
            if frozen is _UNSET:
                with self._lock:
                    best = self._best_lat.get(skey)
            else:
                best = frozen[0]
            if best is not None and \
                    profile.geomean_latency_us > self.dominate_factor * best:
                with self._lock:
                    self.stats.screened_dominated += 1
                return EvalResult(False, 0.0, profile, validated=False,
                                  screened=True, finish_reason="screened")

        oracle = self._oracle(space, tests, sd)
        order = self._order(skey, profile, len(tests), frozen)
        cache.note_validate_run(key)
        worst, passed, ran, failed_test = 0.0, True, 0, -1
        for i in order:
            ok, err = testing.validate(space, variant, [tests[i]],
                                       oracle=[oracle[i]])
            worst = max(worst, err)
            ran += 1
            with self._lock:
                self.stats.validation_test_runs += 1
            if not ok:
                passed = False
                failed_test = i
                break
        with self._lock:
            if not passed and ran == 1 and self.smoke and len(tests) > 1:
                self.stats.validations_smoke_failed += 1
            else:
                self.stats.validations_full += 1
        # The failure is recorded in the result, not bumped here: the
        # smoke-ordering statistic is applied at *delivery* time
        # (``_note_delivery``), which is what lets a journal replay
        # reconstruct it without re-running the genome.
        return EvalResult(passed, worst, profile, validated=True,
                          failed_test=failed_test)

    def _oracle(self, space, tests, sd):
        """Oracle outputs aligned with ``tests`` — memoized per (kernel,
        suite) when sharing is on, recomputed per genome when off (the
        sequential-reference accounting)."""
        if self.share_oracle:
            from repro.kernels.registry import oracle_outputs
            outs, computed = oracle_outputs(space, tests, digest=sd)
            if computed:
                with self._lock:
                    self.stats.oracle_computations += len(tests)
            return outs
        outs = tuple(space.oracle(*t.args) for t in tests)
        with self._lock:
            self.stats.oracle_computations += len(tests)
        return outs

    def _order(self, skey, profile, n, frozen) -> list[int]:
        """Validation order: the smoke test first (most historical failures,
        then cheapest by the candidate's own modeled per-test latency), the
        rest in suite order — which keeps early-exit and ``max_err``
        semantics identical to the sequential path for all-passing genomes.
        """
        if not self.smoke or n <= 1:
            return list(range(n))
        if frozen is _UNSET:
            with self._lock:
                fails = dict(self._fail_counts.get(skey, ()))
        else:
            fails = frozen[1]
        rows = profile.per_shape
        lat = [rows[i].get("latency_us", float("inf")) if i < len(rows)
               else float("inf") for i in range(n)]
        smoke = min(range(n), key=lambda i: (-fails.get(i, 0), lat[i], i))
        return [smoke] + [i for i in range(n) if i != smoke]

    def _note_delivery(self, skey, result: EvalResult, *, key=None,
                       cache=None) -> None:
        """Apply per-delivery bookkeeping in deterministic order: the
        smoke-ordering failure statistic (once per computed-or-replayed
        result — cache hits must not double-count) and the best-latency
        watermark. Journal-replayed entries count exactly once: the
        ``replayed`` flag is cleared after its first delivery."""
        if result.failed_test >= 0 and (not result.cached or result.replayed):
            with self._lock:
                self._fail_counts.setdefault(
                    skey, Counter())[result.failed_test] += 1
        if result.replayed and cache is not None and key is not None:
            cache.clear_replayed(key)
        self._note_best(skey, result)

    def _note_best(self, skey, result: EvalResult) -> None:
        if not (result.validated and result.passed):
            return
        lat = result.profile.geomean_latency_us
        with self._lock:
            cur = self._best_lat.get(skey)
            if cur is None or lat < cur:
                self._best_lat[skey] = lat

    def bump(self, name: str, n: int = 1) -> None:
        """Thread-safe increment of one ``EvalStats`` counter — the hook an
        ``EvalWorkerPool`` uses to report infra events (crashes, timeouts,
        retries, recoveries, recycles) back to the evaluator that owns it."""
        with self._lock:
            setattr(self.stats, name, getattr(self.stats, name) + n)

    def stats_dict(self) -> dict:
        with self._lock:
            return self.stats.as_dict()
