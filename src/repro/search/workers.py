"""Sandboxed evaluation workers — crash isolation for the search hot path.

Candidate kernels are exactly the code you must assume will hang, segfault,
or OOM: the coding agent writes them, the testing agent runs them. In the
thread-pool path one pathological genome wedges or kills the whole search.
This module moves the expensive tier-1/2 work (profile + oracle +
interpret-mode validation) into **spawn-mode worker processes** so the
blast radius of a broken candidate is one child process:

  deadline    ``conn.poll(deadline_s)`` in the parent; an over-deadline
              worker is shot (``kill``) and respawned — a wedged
              evaluation can never hang the search.
  retry       Infra faults (worker died, deadline, corrupt payload) are
              retried with exponential backoff. A fault is *never* raised
              to the caller.
  quarantine  A genome that faults ``quarantine_after`` times is written
              off: the pool reports it and the evaluator records a final
              ``finish_reason="crashed"`` verdict in the cache — mirroring
              the serving layer's request lifecycle — so it is never
              re-run, not even by a later process.
  integrity   The child ships ``(payload, sha256(payload))``; the parent
              recomputes the checksum before unpickling, so a corrupted
              result is an infra fault, not a wrong verdict.
  recycling   Workers retire after ``recycle_after`` tasks (leak hygiene
              on long searches) and are respawned transparently.

Determinism: the worker runs the same ``TieredEvaluator`` cascade as the
thread path, against batch-frozen thresholds shipped with each task, on a
suite regenerated from the (seeded, deterministic) testing agent. For a
well-behaved genome the returned ``EvalResult`` is bit-identical to the
in-process one. Tasks therefore ship the kernel *name* plus
``suite_shapes`` — not the ``KernelSpace`` (whose oracle/run callables
don't pickle) — so process isolation requires registered kernels.

Chaos: a ``reliability.SearchChaosInjector`` attached to the pool arms
per-attempt directives (``kill_worker`` / ``hang_eval`` /
``corrupt_result``) that the child executes against itself, drilling every
fault path above deterministically.
"""

from __future__ import annotations

import dataclasses
import hashlib
import multiprocessing as mp
import os
import pickle
import queue
import threading
import time
import traceback
from typing import Any, Callable, Optional

from repro.search.types import EvalResult

_SENTINEL = None                    # shutdown message to a worker


# -- the child ---------------------------------------------------------------

class _TimeoutTesting:
    """Delegating wrapper that applies the pool's cooperative per-task
    budget to every ``validate`` call inside the worker (the parent's
    join-timeout kill remains the hard guarantee)."""

    def __init__(self, testing, timeout_s):
        self._testing = testing
        self._timeout_s = timeout_s

    def validate(self, space, variant, tests, *, oracle=None):
        return self._testing.validate(space, variant, tests, oracle=oracle,
                                      timeout_s=self._timeout_s)

    def __getattr__(self, name):
        return getattr(self._testing, name)


def _run_task(task: dict) -> tuple[EvalResult, dict]:
    """Evaluate one genome exactly as the thread path would: fresh local
    evaluator + cache, frozen thresholds from the parent's batch."""
    from repro.kernels.registry import get_space
    from repro.search.cache import EvalCache
    from repro.search.evaluator import _UNSET, TieredEvaluator

    space = get_space(task["kernel"])
    if tuple(task["suite_shapes"]) != tuple(space.suite_shapes):
        space = dataclasses.replace(
            space, suite_shapes=tuple(task["suite_shapes"]))
    testing = task["testing"]
    tests = testing.generate_tests(space)
    if task.get("soft_timeout_s"):
        testing = _TimeoutTesting(testing, task["soft_timeout_s"])
    cfg = task["config"]
    ev = TieredEvaluator(screen=cfg["screen"], smoke=cfg["smoke"],
                         share_oracle=cfg["share_oracle"],
                         dominate_factor=cfg["dominate_factor"])
    frozen = task["frozen"]
    result = ev.evaluate(
        space, task["variant"], tests, testing=testing,
        profiling=task["profiling"], cache=EvalCache(),
        validate=task["validate"], tests_digest=task["tests_digest"],
        _frozen=_UNSET if frozen is None else tuple(frozen))
    # delivery-time flags are the parent's business
    result = dataclasses.replace(result, cached=False, replayed=False)
    return result, ev.stats.as_dict()


def _worker_main(conn) -> None:
    """Child process loop: recv task -> evaluate -> send checksummed
    payload. Runs until the sentinel (or until the parent shoots it)."""
    while True:
        try:
            task = conn.recv()
        except (EOFError, OSError):
            return
        if task is _SENTINEL:
            conn.close()
            return
        chaos = task.get("chaos")
        if chaos and chaos["kind"] == "kill_worker":
            os._exit(17)            # simulated segfault/OOM kill
        if chaos and chaos["kind"] == "hang_eval":
            time.sleep(chaos.get("seconds") or 3600.0)
        try:
            payload = pickle.dumps(("ok",) + _run_task(task))
        except BaseException:       # noqa: BLE001 — child must not die here
            payload = pickle.dumps(("error", traceback.format_exc(limit=8)))
        digest = hashlib.sha256(payload).hexdigest()
        if chaos and chaos["kind"] == "corrupt_result":
            # bit-rot in transit: the digest describes the true payload
            payload = bytes([payload[0] ^ 0xFF]) + payload[1:]
        try:
            conn.send((payload, digest))
        except (BrokenPipeError, OSError):
            return


# -- the parent --------------------------------------------------------------

@dataclasses.dataclass
class Outcome:
    """What ``EvalWorkerPool.submit`` learned about one task. ``ok=False``
    means the genome exhausted its fault budget and must be quarantined —
    infra faults never raise."""
    ok: bool
    result: Optional[EvalResult] = None
    stats: Optional[dict] = None    # worker-side EvalStats deltas
    error: Optional[str] = None     # last fault detail when not ok
    attempts: int = 1


class _Worker:
    """One spawned child plus its parent-side pipe end."""

    def __init__(self, ctx, env_path: str):
        parent, child = ctx.Pipe()
        self.conn = parent
        self.tasks_done = 0
        # the spawned interpreter must be able to import repro; tests often
        # run with sys.path tweaks that children don't inherit, so splice
        # the package root into PYTHONPATH around start()
        old = os.environ.get("PYTHONPATH")
        parts = [env_path] + ([old] if old else [])
        os.environ["PYTHONPATH"] = os.pathsep.join(parts)
        try:
            self.proc = ctx.Process(target=_worker_main, args=(child,),
                                    daemon=True)
            self.proc.start()
        finally:
            if old is None:
                os.environ.pop("PYTHONPATH", None)
            else:
                os.environ["PYTHONPATH"] = old
        child.close()

    def alive(self) -> bool:
        return self.proc.is_alive()

    def shoot(self) -> None:
        if self.proc.is_alive():
            self.proc.kill()
        self.proc.join(timeout=5.0)
        self.conn.close()

    def retire(self) -> None:
        try:
            self.conn.send(_SENTINEL)
        except (BrokenPipeError, OSError):
            pass
        self.proc.join(timeout=5.0)
        if self.proc.is_alive():
            self.proc.kill()
            self.proc.join(timeout=5.0)
        self.conn.close()


class EvalWorkerPool:
    """Pool of spawn-mode evaluation workers with deadlines, bounded
    retries, quarantine, and recycling. Thread-safe: ``submit`` may be
    called concurrently (``evaluate_many`` does, one thread per genome);
    each submit checks a worker out of the pool for the task's duration.

    ``on_stat(name, n)`` reports infra events (``worker_crashes``,
    ``eval_timeouts``, ``corrupt_results``, ``retries``, ``recoveries``,
    ``quarantined`` is the evaluator's to count, ``workers_recycled``) —
    wire it to ``TieredEvaluator.bump``.
    """

    def __init__(self, *, workers: int = 1, deadline_s: float = 60.0,
                 max_retries: int = 2, quarantine_after: int = 2,
                 recycle_after: int = 50, backoff_s: float = 0.05,
                 chaos=None,
                 on_stat: Optional[Callable[..., Any]] = None):
        if workers < 1:
            raise ValueError("workers must be >= 1")
        if quarantine_after < 1:
            raise ValueError("quarantine_after must be >= 1")
        self.workers = workers
        self.deadline_s = deadline_s
        self.max_retries = max_retries
        self.quarantine_after = quarantine_after
        self.recycle_after = recycle_after
        self.backoff_s = backoff_s
        self.chaos = chaos
        self._on_stat = on_stat or (lambda name, n=1: None)
        self._ctx = mp.get_context("spawn")
        from repro.core import costmodel
        self._env_path = os.path.dirname(os.path.dirname(
            os.path.dirname(costmodel.__file__)))
        self._idle: queue.Queue = queue.Queue()
        self._lock = threading.Lock()
        self._dispatched = 0        # global attempt counter (chaos step)
        self._strikes: dict[str, int] = {}
        self._strike_errors: dict[str, str] = {}
        self._closed = False
        for _ in range(workers):
            self._idle.put(self._spawn())

    # -- lifecycle -----------------------------------------------------------

    def _spawn(self) -> _Worker:
        return _Worker(self._ctx, self._env_path)

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
        drained = []
        while True:
            try:
                drained.append(self._idle.get_nowait())
            except queue.Empty:
                break
        for w in drained:
            w.retire()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- the submit path -----------------------------------------------------

    def submit(self, task: dict, *, digest: str) -> Outcome:
        """Run one task to an outcome: a verdict, or quarantine after the
        genome's fault budget is spent. Blocks while all workers are busy.
        """
        with self._lock:
            strikes = self._strikes.get(digest, 0)
            if strikes >= self.quarantine_after:
                return Outcome(ok=False, attempts=0,
                               error=self._strike_errors.get(
                                   digest, "previously quarantined"))
        attempts = 0
        faults = 0
        last_error = "unknown fault"
        while True:
            attempts += 1
            status, value = self._attempt(task, digest)
            if status == "ok":
                result, stats = value
                if faults:
                    self._on_stat("recoveries")
                return Outcome(ok=True, result=result, stats=stats,
                               attempts=attempts)
            faults += 1
            last_error = value
            with self._lock:
                self._strikes[digest] = self._strikes.get(digest, 0) + 1
                self._strike_errors[digest] = last_error
                quarantine = self._strikes[digest] >= self.quarantine_after
            if quarantine or attempts > self.max_retries:
                return Outcome(ok=False, error=last_error, attempts=attempts)
            self._on_stat("retries")
            time.sleep(self.backoff_s * (2 ** (attempts - 1)))

    def _attempt(self, task: dict, digest: str) -> tuple[str, Any]:
        """One dispatch to one worker. Returns ("ok", (result, stats)) or
        ("fault", error-string); the faulted worker is already replaced."""
        with self._lock:
            index = self._dispatched
            self._dispatched += 1
        shipped = dict(task, soft_timeout_s=self.deadline_s)
        if self.chaos is not None:
            fault = self.chaos.directive_for(digest, index)
            if fault is not None:
                shipped["chaos"] = {"kind": fault.kind,
                                    "seconds": fault.seconds}
        worker = self._idle.get()
        try:
            try:
                worker.conn.send(shipped)
            except (BrokenPipeError, OSError):
                self._on_stat("worker_crashes")
                worker.shoot()
                worker = None
                return "fault", "worker dead at dispatch"
            if not worker.conn.poll(self.deadline_s):
                self._on_stat("eval_timeouts")
                worker.shoot()
                worker = None
                return "fault", \
                    f"evaluation exceeded deadline ({self.deadline_s}s)"
            try:
                payload, sent_digest = worker.conn.recv()
            except (EOFError, OSError):
                self._on_stat("worker_crashes")
                worker.shoot()
                worker = None
                return "fault", "worker died mid-task"
            if hashlib.sha256(payload).hexdigest() != sent_digest:
                self._on_stat("corrupt_results")
                worker.shoot()          # don't trust its stream state
                worker = None
                return "fault", "result checksum mismatch"
            msg = pickle.loads(payload)
            if msg[0] == "error":
                # the evaluation itself raised in the child; the worker is
                # healthy — count the genome's strike, keep the worker
                self._on_stat("worker_crashes")
                return "fault", f"evaluation raised in worker:\n{msg[1]}"
            worker.tasks_done += 1
            return "ok", (msg[1], msg[2])
        finally:
            if worker is None:
                worker = self._spawn()
            elif worker.tasks_done >= self.recycle_after:
                self._on_stat("workers_recycled")
                worker.retire()
                worker = self._spawn()
            self._idle.put(worker)

    # -- introspection -------------------------------------------------------

    def strikes(self, digest: str) -> int:
        with self._lock:
            return self._strikes.get(digest, 0)
