"""Write-ahead search journal — kill -9 a search, resume it bit-identically.

A long agentic search is exactly the process you must assume will be
killed: OOM, preemption, a broken candidate taking the parent down before
process isolation existed. The journal makes search progress durable the
same way the persistent ``EvalCache`` makes *verdicts* durable — as an
append-only JSONL file, one flushed ``write()`` per record:

  header   {"type": "header", kernel, strategy, strategy_config, rounds,
            tests_digest, salt, version}
           Identifies the exact search. Any mismatch on open means the
           file journals a *different* search (changed config or code) —
           it is discarded with a warning, never replayed.
  round    {"type": "round", "round": r, "candidates": [digests]}
           The write-ahead part: the candidate set is journaled before
           any of it is evaluated.
  eval     {"type": "eval", "key": [kernel, genome, suite], ...verdict}
           One evaluation *outcome* (``cache.encode_result`` fields) —
           exactly what is needed to skip the work on replay.
  finish   {"type": "finish", "entries": n}
           The search ran to completion; a resume is pure replay.

Resume does **not** checkpoint strategy state. Strategies are
deterministic given evaluation results, so ``--resume`` re-runs the
strategy from round 0 with journaled outcomes seeded into the cache as
``replayed`` entries: the replayed prefix costs dict hits, live evaluation
takes over at the first genome the journal doesn't know, and the final
``Log`` is bit-identical to an uninterrupted run (the ``replayed`` flag
re-applies smoke-ordering failure statistics exactly once at delivery, so
even the evaluator's internal state reconstructs). Re-journaling is
suppressed by the same mechanism: only non-cached deliveries are recorded,
so a resumed run appends only what the journal was missing.

A ``kill -9`` mid-append leaves a torn trailing line; ``open()`` keeps the
valid prefix and physically truncates the tail before appending. Round
records double as a replay self-check: a resumed strategy re-proposing a
*different* candidate set for a journaled round means nondeterminism
upstream (or a hand-edited file) and raises ``JournalMismatch`` rather
than silently journaling garbage.
"""

from __future__ import annotations

import json
import os
import warnings

from repro.search.cache import _jsonable, encode_result

_VERSION = 1


class JournalMismatch(RuntimeError):
    """A resumed search diverged from its journal (round candidates
    changed) — the journal no longer describes this search."""


class SearchJournal:
    """Append-only JSONL journal for one (kernel, strategy) search.

    Lifecycle: construct with a path, ``open(...)`` with the search's
    identity (returns True when prior progress was loaded), seed the
    cache from ``replay``, run the strategy with ``record_*`` wired in,
    ``finish()`` + ``close()``.
    """

    def __init__(self, path: str):
        self.path = path
        self.replay: dict[tuple, dict] = {}     # key -> verdict record
        self.rounds: dict[int, list[str]] = {}  # round -> candidate digests
        self.finished = False
        self._header: dict | None = None
        self._f = None

    # -- open / load ---------------------------------------------------------

    def open(self, *, kernel: str, strategy: str, strategy_config: dict,
             rounds: int, tests_digest: str, salt: str) -> bool:
        """Load any prior progress for exactly this search, then switch to
        append mode. Returns True when journaled evaluations were loaded
        (the caller should seed its cache from ``replay``)."""
        header = {"type": "header", "version": _VERSION, "kernel": kernel,
                  "strategy": strategy, "strategy_config": strategy_config,
                  "rounds": rounds, "tests_digest": tests_digest,
                  "salt": salt}
        keep = self._load(header)
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        if not keep:
            self.replay, self.rounds, self.finished = {}, {}, False
            self._f = open(self.path, "w")
            self._write(header)
        self._header = header
        if self._f is None:
            self._f = open(self.path, "a")
        return bool(self.replay)

    def _load(self, header: dict) -> bool:
        """Parse the existing file. Returns False when there is nothing
        (or nothing *compatible*) to resume — the caller rewrites."""
        if not os.path.exists(self.path):
            return False
        with open(self.path, "rb") as f:
            raw = f.read()
        offset = 0
        records = []
        lines = raw.split(b"\n")
        for i, bline in enumerate(lines):
            if i == len(lines) - 1 and bline == b"":
                break
            try:
                records.append(json.loads(bline.decode("utf-8")))
            except (UnicodeDecodeError, ValueError):
                warnings.warn(
                    f"search journal {self.path}: discarding torn/corrupt "
                    f"tail at byte {offset} ({len(raw) - offset} bytes)")
                break
            offset += len(bline) + 1
        if offset < len(raw):
            with open(self.path, "r+b") as f:
                f.truncate(offset)
        if not records or records[0].get("type") != "header":
            return False
        if records[0] != header:
            warnings.warn(
                f"search journal {self.path}: header mismatch (different "
                "search config or code version) — starting fresh")
            return False
        for rec in records[1:]:
            t = rec.get("type")
            if t == "eval":
                self.replay[tuple(rec["key"])] = rec
            elif t == "round":
                self.rounds[int(rec["round"])] = list(rec["candidates"])
            elif t == "finish":
                self.finished = True
        return True

    # -- append --------------------------------------------------------------

    def _write(self, rec: dict) -> None:
        self._f.write(json.dumps(rec, default=_jsonable) + "\n")
        self._f.flush()

    def record_round(self, round_: int, candidates: list[str]) -> None:
        """Journal a round's candidate set before evaluating it. On a
        resumed search this doubles as the determinism self-check."""
        prior = self.rounds.get(round_)
        if prior is not None:
            if prior != list(candidates):
                raise JournalMismatch(
                    f"round {round_} replayed different candidates than "
                    f"journaled ({self.path}): the search is not "
                    "deterministic or the journal is stale")
            return
        self.rounds[round_] = list(candidates)
        self._write({"type": "round", "round": round_,
                     "candidates": list(candidates)})

    def record_eval(self, key: tuple, result) -> None:
        """Journal one evaluation outcome (idempotent per key)."""
        if tuple(key) in self.replay:
            return
        rec = dict(type="eval", key=list(key), **encode_result(result))
        self.replay[tuple(key)] = rec
        self._write(rec)

    def finish(self, log) -> None:
        if not self.finished:
            self.finished = True
            self._write({"type": "finish", "entries": len(log.entries)})

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
