"""Shared deterministic fault-injection core.

Both reliability drills in the repo build on this module: the training
loop's restart drills (``training/fault_tolerance.FailureInjector``) and
the serving engine's chaos harness (``serving/chaos.ChaosInjector``).
Keeping the schedule here — one step-indexed, fire-once fault list — is
what makes chaos runs reproducible: the same ``Fault`` list against the
same request mix injects the same faults at the same step numbers every
time, so recovery behaviour can be pinned by golden files.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import Counter
from typing import Iterable, Optional, Sequence


class EvalTimeout(Exception):
    """A candidate evaluation exceeded its deadline. Raised by the
    cooperative budget in ``TestingAgent.validate(timeout_s=...)`` and
    recorded by the worker pool when it shoots an over-deadline worker."""


@dataclasses.dataclass
class Fault:
    """One scheduled fault. ``kind`` is interpreted by the consumer (the
    serving chaos harness understands ``device_fault`` /
    ``pool_exhaustion`` / ``corrupt_readback`` / ``stall`` / ``abort``;
    the training injector uses ``raise``; the search chaos injector uses
    ``kill_worker`` / ``hang_eval`` / ``corrupt_result``); the remaining
    fields are kind-specific knobs and ignored by kinds that don't use
    them."""

    kind: str
    step: int = -1                  # fires when the consumer reaches it
    slot: Optional[int] = None      # device_fault / corrupt_readback
    rid: Optional[int] = None       # abort
    pages: int = 0                  # pool_exhaustion: pages to seize
    steps: int = 1                  # pool_exhaustion: hold duration
    seconds: float = 0.0            # stall / hang_eval: sleep length
    # search chaos: match by genome digest instead of step index —
    # deterministic regardless of dispatch interleaving under workers>1
    digest: Optional[str] = None
    times: int = 1                  # search chaos: fire on the first N
    #                                 attempts (drives quarantine paths)


class FaultSchedule:
    """Step-indexed fault list where each fault fires exactly once.

    ``due(step)`` returns (and permanently marks fired) every not-yet-
    fired fault scheduled for exactly ``step``. Step numbers that the
    consumer never reaches simply leave their faults unfired — visible
    via ``exhausted`` so harnesses can assert their plan fully ran.
    """

    def __init__(self, faults: Iterable[Fault]):
        self.faults = list(faults)
        self._fired = [False] * len(self.faults)

    def due(self, step: int,
            kinds: Optional[Sequence[str]] = None) -> list[Fault]:
        out = []
        for i, f in enumerate(self.faults):
            if self._fired[i] or f.step != step:
                continue
            if kinds is not None and f.kind not in kinds:
                continue
            self._fired[i] = True
            out.append(f)
        return out

    @property
    def fired(self) -> int:
        return sum(self._fired)

    @property
    def exhausted(self) -> bool:
        return all(self._fired)


class SearchChaosInjector:
    """Deterministic fault plan for the search worker pool.

    Each ``Fault`` targets one evaluation *attempt* and names what happens
    to it: ``kill_worker`` (the child hard-exits mid-task), ``hang_eval``
    (the child sleeps ``seconds`` — set it past the pool deadline to drill
    the join-timeout kill), or ``corrupt_result`` (the child flips bytes in
    its result payload, which the parent's checksum must catch).

    Matching is by genome ``digest`` when set — deterministic under any
    dispatch interleaving, so it is the form chaos tests use with
    ``workers > 1`` — else by ``step`` against the pool's global dispatch
    counter (deterministic only with one worker). ``times=N`` arms the
    fault for the genome's first N attempts: N below the quarantine
    threshold proves retry-then-recover, N at the threshold proves
    quarantine. Every armed attempt fires at most once, so retries beyond
    the plan run clean.
    """

    KINDS = frozenset({"kill_worker", "hang_eval", "corrupt_result"})

    def __init__(self, faults: Iterable[Fault]):
        self.faults: list[Fault] = []
        for f in faults:
            if f.kind not in self.KINDS:
                raise ValueError(f"unknown search-chaos kind {f.kind!r}")
            if f.digest is None and f.step < 0:
                raise ValueError(
                    "search-chaos fault needs a digest or a step index")
            for _ in range(max(1, f.times)):
                self.faults.append(f)
        self._fired = [False] * len(self.faults)
        self._lock = threading.Lock()
        self.injected: Counter = Counter()

    def directive_for(self, digest: str,
                      dispatch_index: int) -> Optional[Fault]:
        """The fault (if any) armed for this attempt; marks it fired."""
        with self._lock:
            for i, f in enumerate(self.faults):
                if self._fired[i]:
                    continue
                if f.digest is not None:
                    if not digest.startswith(f.digest):
                        continue
                elif f.step != dispatch_index:
                    continue
                self._fired[i] = True
                self.injected[f.kind] += 1
                return f
        return None

    @property
    def fired(self) -> int:
        with self._lock:
            return sum(self._fired)

    @property
    def exhausted(self) -> bool:
        with self._lock:
            return all(self._fired)
