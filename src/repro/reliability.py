"""Shared deterministic fault-injection core.

Both reliability drills in the repo build on this module: the training
loop's restart drills (``training/fault_tolerance.FailureInjector``) and
the serving engine's chaos harness (``serving/chaos.ChaosInjector``).
Keeping the schedule here — one step-indexed, fire-once fault list — is
what makes chaos runs reproducible: the same ``Fault`` list against the
same request mix injects the same faults at the same step numbers every
time, so recovery behaviour can be pinned by golden files.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence


@dataclasses.dataclass
class Fault:
    """One scheduled fault. ``kind`` is interpreted by the consumer (the
    serving chaos harness understands ``device_fault`` /
    ``pool_exhaustion`` / ``corrupt_readback`` / ``stall`` / ``abort``;
    the training injector uses ``raise``); the remaining fields are
    kind-specific knobs and ignored by kinds that don't use them."""

    kind: str
    step: int                       # fires when the consumer reaches it
    slot: Optional[int] = None      # device_fault / corrupt_readback
    rid: Optional[int] = None       # abort
    pages: int = 0                  # pool_exhaustion: pages to seize
    steps: int = 1                  # pool_exhaustion: hold duration
    seconds: float = 0.0            # stall: sleep length


class FaultSchedule:
    """Step-indexed fault list where each fault fires exactly once.

    ``due(step)`` returns (and permanently marks fired) every not-yet-
    fired fault scheduled for exactly ``step``. Step numbers that the
    consumer never reaches simply leave their faults unfired — visible
    via ``exhausted`` so harnesses can assert their plan fully ran.
    """

    def __init__(self, faults: Iterable[Fault]):
        self.faults = list(faults)
        self._fired = [False] * len(self.faults)

    def due(self, step: int,
            kinds: Optional[Sequence[str]] = None) -> list[Fault]:
        out = []
        for i, f in enumerate(self.faults):
            if self._fired[i] or f.step != step:
                continue
            if kinds is not None and f.kind not in kinds:
                continue
            self._fired[i] = True
            out.append(f)
        return out

    @property
    def fired(self) -> int:
        return sum(self._fired)

    @property
    def exhausted(self) -> bool:
        return all(self._fired)
