"""Model/run configuration schema shared by all architectures.

Every assigned architecture is a ``ModelConfig`` in ``repro/configs/<id>.py``
(exact dims from the assignment) plus a ``smoke()`` reduction of the same
family for CPU tests. Input shapes are the four assigned cells
(train_4k / prefill_32k / decode_32k / long_500k).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


def pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | xlstm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # dense-attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    window: Optional[int] = None          # sliding-window attention
    rope_theta: float = 10000.0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    expert_ff: int = 0                    # d_ff per expert
    capacity_factor: float = 1.25

    # hybrid (recurrentgemma): layer pattern period — indices of attention
    # layers within each period; others are RG-LRU recurrent blocks.
    period: int = 0
    attn_in_period: tuple = ()
    conv_width: int = 4
    lru_width: int = 0

    # xlstm: blocks alternate (mLSTM, sLSTM) within each scanned period
    slstm_every: int = 0                  # 0 = all mLSTM

    # enc-dec
    enc_layers: int = 0                   # 0 = decoder-only

    # modality frontend stub (audio/vlm): train/prefill inputs are
    # precomputed frame/patch embeddings instead of token ids
    frontend: str = "tokens"              # tokens | frames

    dtype: str = "bfloat16"
    norm_eps: float = 1e-6

    # --- derived ---
    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        # vocab padded to a multiple of 256 so it shards over the model axis
        # (granite's 49155 / seamless's 256206 are not divisible by 16)
        return pad_to(self.vocab, 256)

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def activated_params(self) -> int:
        """~N for 6·N·D MODEL_FLOPS accounting (MoE: active experts only)."""
        d, L = self.d_model, self.n_layers
        emb = self.padded_vocab * d * (1 if self.enc_layers else 2)
        att = L * d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim \
            + L * self.n_heads * self.head_dim * d
        if self.family == "moe":
            mlp = L * 3 * d * self.expert_ff * self.top_k \
                + L * d * self.n_experts          # router
        elif self.family == "xlstm":
            att = L * d * d * 4                   # qkv+o equivalents & gates
            mlp = 0
        else:
            mlp = L * 3 * d * self.d_ff
        if self.enc_layers:
            att += self.enc_layers * 4 * d * d + self.n_layers * 4 * d * d
            mlp += self.enc_layers * 3 * d * self.d_ff
        return emb + att + mlp


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def long_context_ok(cfg: ModelConfig) -> bool:
    """long_500k runs only for sub-quadratic mixers (SSM / hybrid / SWA)."""
    return cfg.family in ("xlstm", "hybrid") or cfg.window is not None


def cells_for(cfg: ModelConfig) -> list[ShapeSpec]:
    cells = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if long_context_ok(cfg):
        cells.append(LONG_500K)
    return cells
