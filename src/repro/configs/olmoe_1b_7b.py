"""olmoe-1b-7b [moe] — 64 experts top-8 [arXiv:2409.02060; hf]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe", n_layers=16, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1024, vocab=50304,
    n_experts=64, top_k=8, expert_ff=1024)


def smoke() -> ModelConfig:
    return dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=2,
                               n_kv_heads=2, d_ff=64, vocab=256,
                               n_experts=4, top_k=2, expert_ff=64)
