"""granite-moe-3b-a800m [moe] — 40 experts top-8, d_ff=512/expert (per the
assignment; the HF card's granite-3.0 sibling lists 32e — we implement the
assigned 40e) [hf:ibm-granite/granite-3.0-1b-a400m-base]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536,
    n_heads=24, n_kv_heads=8, d_ff=512, vocab=49155,
    n_experts=40, top_k=8, expert_ff=512)


def smoke() -> ModelConfig:
    return dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=2,
                               n_kv_heads=1, d_ff=64, vocab=256,
                               n_experts=4, top_k=2, expert_ff=64)
