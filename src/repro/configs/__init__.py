"""One config per assigned architecture. ``get(name)`` accepts the
assignment ids (dashes); ``smoke(name)`` returns the reduced same-family
config used by CPU smoke tests."""

from repro.configs import (chameleon_34b, granite_moe_3b_a800m,
                           h2o_danube_1_8b, olmoe_1b_7b, qwen2_0_5b,
                           qwen3_8b, recurrentgemma_2b,
                           seamless_m4t_large_v2, xlstm_1_3b, yi_34b)
from repro.configs.base import (LONG_500K, PREFILL_32K, SHAPES, TRAIN_4K,
                                DECODE_32K, ModelConfig, ShapeSpec,
                                cells_for, long_context_ok)

_MODULES = {
    "qwen2-0.5b": qwen2_0_5b,
    "yi-34b": yi_34b,
    "qwen3-8b": qwen3_8b,
    "h2o-danube-1.8b": h2o_danube_1_8b,
    "xlstm-1.3b": xlstm_1_3b,
    "chameleon-34b": chameleon_34b,
    "granite-moe-3b-a800m": granite_moe_3b_a800m,
    "olmoe-1b-7b": olmoe_1b_7b,
    "seamless-m4t-large-v2": seamless_m4t_large_v2,
    "recurrentgemma-2b": recurrentgemma_2b,
}

ARCH_IDS = tuple(_MODULES)
CONFIGS = {k: m.CONFIG for k, m in _MODULES.items()}


def get(name: str) -> ModelConfig:
    return CONFIGS[name]


def smoke(name: str) -> ModelConfig:
    return _MODULES[name].smoke()
