"""chameleon-34b [vlm] — early-fusion; VQ image tokens are ordinary ids in
the unified 65536 vocab, so the modality frontend stub provides token ids
[arXiv:2405.09818]. Backbone = dense transformer with qk-norm."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="dense", n_layers=48, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=22016, vocab=65536, qk_norm=True)


def smoke() -> ModelConfig:
    return dataclasses.replace(CONFIG, n_layers=2, d_model=128, n_heads=4,
                               n_kv_heads=2, d_ff=256, vocab=512)
