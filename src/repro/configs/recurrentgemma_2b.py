"""recurrentgemma-2b [hybrid] — RG-LRU + local attention (2 rec : 1 attn),
MQA kv=1, window 2048 [arXiv:2402.19427; hf]. Sub-quadratic -> long_500k."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid", n_layers=26, d_model=2560,
    n_heads=10, n_kv_heads=1, d_ff=7680, vocab=256000,
    window=2048, lru_width=2560, period=3, attn_in_period=(2,))


def smoke() -> ModelConfig:
    return dataclasses.replace(CONFIG, n_layers=5, d_model=64, n_heads=2,
                               n_kv_heads=1, d_ff=128, vocab=256,
                               window=32, lru_width=64)
