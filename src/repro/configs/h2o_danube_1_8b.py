"""h2o-danube-1.8b [dense] — llama+mistral mix, sliding-window attention
[arXiv:2401.16818; hf]. SWA makes it sub-quadratic -> long_500k runs."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense", n_layers=24, d_model=2560,
    n_heads=32, n_kv_heads=8, d_ff=6912, vocab=32000, window=4096)


def smoke() -> ModelConfig:
    return dataclasses.replace(CONFIG, n_layers=2, d_model=128, n_heads=4,
                               n_kv_heads=2, d_ff=256, vocab=512, window=64)
