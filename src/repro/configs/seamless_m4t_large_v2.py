"""seamless-m4t-large-v2 [audio] — enc-dec backbone; audio frontend is a
STUB (input_specs provides precomputed frame embeddings)
[arXiv:2308.11596; hf]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec", n_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192, vocab=256206,
    enc_layers=24, frontend="frames")


def smoke() -> ModelConfig:
    return dataclasses.replace(CONFIG, n_layers=2, enc_layers=2, d_model=64,
                               n_heads=2, n_kv_heads=2, d_ff=128, vocab=256)
