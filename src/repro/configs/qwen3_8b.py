"""qwen3-8b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b", family="dense", n_layers=36, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=12288, vocab=151936,
    qk_norm=True, rope_theta=1e6)


def smoke() -> ModelConfig:
    return dataclasses.replace(CONFIG, n_layers=2, d_model=128, n_heads=4,
                               n_kv_heads=2, d_ff=256, vocab=512)
