"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks (7:1) [arXiv:2405.04517]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="xlstm", n_layers=48, d_model=2048,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304, slstm_every=8)


def smoke() -> ModelConfig:
    return dataclasses.replace(CONFIG, n_layers=8, d_model=64, n_heads=2,
                               n_kv_heads=2, vocab=256)
