"""qwen2-0.5b [dense] — GQA kv=2, QKV bias [arXiv:2407.10671; hf]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense", n_layers=24, d_model=896,
    n_heads=14, n_kv_heads=2, d_ff=4864, vocab=151936,
    qkv_bias=True, rope_theta=1e6)


def smoke() -> ModelConfig:
    return dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=2,
                               n_kv_heads=1, d_ff=128, vocab=256)
