"""Algorithm 1 — Multi-Agent CUDA(-to-Pallas) Optimization.

The loop wires the four agents exactly as the paper's pseudocode:

    T      <- TestingAgent.GenerateTests(S0)
    perf0  <- ProfilingAgent.Profile(S0, T)
    Log    <- [(0, S0, True, perf0)]
    for r in 1..R:
        sugg     <- PlanningAgent.Suggest(S_prev, pass_prev, perf_prev)
        S_new    <- CodingAgent.Apply(S_prev, sugg)
        pass_new <- TestingAgent.Validate(S_new, T)
        perf_new <- ProfilingAgent.Profile(S_new, T)
        Log.append((r, S_new, pass_new, perf_new))
        S_prev, pass_prev, perf_prev <- S_new, pass_new, perf_new

The implementation now lives in the pluggable search subsystem
(``repro.search``): ``optimize(strategy="greedy")`` is this exact loop
(``GreedyChain``), and ``"beam"`` / ``"population"`` explore many
candidates per round. Evaluation goes through the tiered engine
(``repro.search.evaluator``): cost-model screen, smoke test, full suite,
per-suite oracle memoization, concurrent ``workers=``-bounded batches, and
an optionally persistent evaluation cache. On the shipped policy's greedy
chains the engine is result-preserving end to end (see README
"Evaluation pipeline" for the exact semantics when a cascade stage does
trigger). This module is the back-compat façade — it lazily delegates
so that importing ``repro.core`` never drags in ``repro.search`` at
module-import time.
"""

from __future__ import annotations

from repro.core.oplog import Log
from repro.core.variants import KernelSpace


def optimize(kernel: str | KernelSpace, **kwargs) -> Log:
    """Run one search on one kernel (default: Algorithm 1's greedy chain).

    Accepts the historical agent-override kwargs plus ``strategy=`` and
    ``cache=`` — see ``repro.search.optimize`` for the full signature.
    """
    from repro.search.orchestrator import optimize as _optimize
    return _optimize(kernel, **kwargs)


def optimize_all(**kwargs) -> dict[str, Log]:
    """Optimize the paper's three kernels; returns {kernel: Log}."""
    from repro.search.orchestrator import optimize_all as _optimize_all
    return _optimize_all(**kwargs)


def reintegrate(results: dict[str, Log]) -> None:
    """Post-processing (paper §3.2): install each kernel's best correct
    variant process-wide so the serving/training framework picks it up."""
    from repro.search.orchestrator import reintegrate as _reintegrate
    return _reintegrate(results)
