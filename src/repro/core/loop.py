"""Algorithm 1 — Multi-Agent CUDA(-to-Pallas) Optimization, verbatim.

The loop wires the four agents exactly as the paper's pseudocode:

    T      <- TestingAgent.GenerateTests(S0)
    perf0  <- ProfilingAgent.Profile(S0, T)
    Log    <- [(0, S0, True, perf0)]
    for r in 1..R:
        sugg     <- PlanningAgent.Suggest(S_prev, pass_prev, perf_prev)
        S_new    <- CodingAgent.Apply(S_prev, sugg)
        pass_new <- TestingAgent.Validate(S_new, T)
        perf_new <- ProfilingAgent.Profile(S_new, T)
        Log.append((r, S_new, pass_new, perf_new))
        S_prev, pass_prev, perf_prev <- S_new, pass_new, perf_new

The optimized kernel reported in the paper's tables is the best *correct*
entry of the log (``Log.best()``); ``reintegrate`` installs it into the
framework via ``ops.set_variants`` (the paper's post-processing step).
"""

from __future__ import annotations

from repro.core.agents import (CodingAgent, PlanningAgent, ProfilingAgent,
                               TestingAgent)
from repro.core.oplog import Log, LogEntry
from repro.core.variants import SPACES, KernelSpace


def optimize(kernel: str | KernelSpace, *, rounds: int = 5,
             testing: TestingAgent | None = None,
             profiling: ProfilingAgent | None = None,
             planning: PlanningAgent | None = None,
             coding: CodingAgent | None = None,
             verbose: bool = False) -> Log:
    """Run Algorithm 1 on one kernel. Returns the optimization Log."""
    space = SPACES[kernel] if isinstance(kernel, str) else kernel
    testing = testing or TestingAgent()
    profiling = profiling or ProfilingAgent(reps=100)
    planning = planning or PlanningAgent()
    coding = coding or CodingAgent()

    # Initialization (Alg. 1 lines 1-7)
    tests = testing.generate_tests(space)
    s_prev = space.baseline
    perf_prev = profiling.profile(space, s_prev, tests)
    log = Log()
    log.append(LogEntry(0, s_prev, True, perf_prev, rationale="baseline"))
    pass_prev = True
    history = [{"variant": s_prev, "passed": True, "profile": perf_prev,
                "suggestion": None}]

    # Iterative optimization (lines 8-16)
    for r in range(1, rounds + 1):
        sugg = planning.suggest(space, s_prev, pass_prev, perf_prev, history)
        s_new = coding.apply(space, s_prev, sugg)
        pass_new, max_err = testing.validate(space, s_new, tests)
        perf_new = profiling.profile(space, s_new, tests)
        log.append(LogEntry(r, s_new, pass_new, perf_new,
                            rationale=sugg.rationale, max_err=max_err))
        history.append({"variant": s_new, "passed": pass_new,
                        "profile": perf_new, "suggestion": sugg})
        s_prev, pass_prev, perf_prev = s_new, pass_new, perf_new
        if verbose:
            print(f"[{space.name}] round {r}: {sugg.rationale}")
            print(f"    -> {s_new.describe()}  "
                  f"{'OK' if pass_new else 'FAIL'} "
                  f"{perf_new.geomean_latency_us:.2f}us")
    return log


def reintegrate(results: dict[str, Log]) -> None:
    """Post-processing (paper §3.2): install each kernel's best correct
    variant process-wide so the serving/training framework picks it up."""
    from repro.kernels import ops
    ops.set_variants(**{name: log.best().code
                        for name, log in results.items()})


def optimize_all(*, rounds: int = 5, verbose: bool = False,
                 kernels: tuple[str, ...] = ("merge_attn_states_lse",
                                             "fused_add_rmsnorm",
                                             "silu_and_mul"),
                 ) -> dict[str, Log]:
    """Optimize the paper's three kernels; returns {kernel: Log}."""
    return {k: optimize(k, rounds=rounds, verbose=verbose) for k in kernels}
