"""The four Astra agents (paper §3.2) and the single-agent baseline (§5.2).

Each agent is a small class with its own state and its own view of the
problem — that *separation* is the paper's thesis. The agents' "reasoning"
backend is pluggable (``backend.py``): the shipped backend is the
deterministic optimization policy in ``policy.py`` (the transformation
catalog the paper's LLM discovers, §5.3); an ``LLMBackend`` interface marks
where o4-mini would slot in.

Hardware note: the ProfilingAgent "measures" by evaluating the analytic
TPU-v5e cost model (``costmodel.py``) — the container has no TPU — plus a
deterministic pseudo-noise term that scales like 1/sqrt(reps), emulating
real profiling variance (the paper uses 20 warm-ups + 100 reps; noise is
what made the single-agent's sloppy profiling fail on Kernel 1).
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Any, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import costmodel
from repro.core.variants import KernelSpace, TestCase, make_inputs


def _tolerance(dtype) -> tuple[float, float]:
    """(rtol, atol) per dtype — paper §3.1's epsilon."""
    if jnp.dtype(dtype) == jnp.dtype(jnp.bfloat16):
        return 3e-2, 3e-2
    return 1e-5, 1e-4


def _pseudo_noise(tag: str, scale: float) -> float:
    """Deterministic 'measurement noise' in [-scale, +scale]."""
    h = int.from_bytes(hashlib.sha256(tag.encode()).digest()[:8], "big")
    return (h / 2**64 * 2.0 - 1.0) * scale


@dataclasses.dataclass
class Profile:
    """What the ProfilingAgent hands the PlanningAgent."""
    per_shape: list[dict]
    geomean_latency_us: float
    dominant: str
    signals: dict                   # term fractions + structural hints
    noise_scale: float


@dataclasses.dataclass(frozen=True)
class Suggestion:
    knob: str
    value: Any
    rationale: str


class TestingAgent:
    """Builds the test suite T and validates candidates against the oracle.

    The dedicated testing agent draws *representative* shapes (paper §4:
    dims of LLaMA-7B/13B/70B and the production configs) across dtypes,
    plus adversarial values (wide-dynamic-range scores, -inf empties,
    ragged row counts). Correctness = max error over T within epsilon.
    """

    def __init__(self, *, dtypes=(jnp.float32, jnp.bfloat16), seed: int = 0):
        self.dtypes = dtypes
        self.seed = seed

    def generate_tests(self, space: KernelSpace) -> list[TestCase]:
        tests = []
        for i, shape in enumerate(space.suite_shapes):
            for j, dt in enumerate(self.dtypes):
                tests.append(make_inputs(space.name, shape, dtype=dt,
                                         seed=self.seed + 31 * i + j))
        return tests

    def validate(self, space: KernelSpace, variant,
                 tests: Sequence[TestCase], *,
                 oracle=None,
                 timeout_s: float | None = None) -> tuple[bool, float]:
        """Check ``variant`` against the oracle over T.

        Tolerance is the standard mixed bound ``err <= atol + rtol*|want|``
        (NOT ``rel <= rtol + atol``, which conflates relative and absolute
        error and mis-handles near-zero oracle values). Non-finite oracle
        entries (e.g. -inf empty partitions) must match exactly. The
        returned ``max_err`` is tolerance-normalized: ``err / (atol +
        rtol*|want|)``, so <= 1.0 means within epsilon.

        Validation fail-fasts at the first failing case, and ``tests`` may
        be any subset or reordering of the suite (the tiered evaluator's
        smoke stage passes a single case). ``oracle`` optionally supplies
        precomputed oracle outputs — a sequence aligned with ``tests`` or a
        callable ``oracle(test) -> outputs`` — so the jnp oracle (which
        depends only on the suite, never the genome) is not recomputed for
        every candidate.

        ``timeout_s`` is a *cooperative* deadline checked between test
        cases: exceeding it raises ``reliability.EvalTimeout``. It cannot
        interrupt a single wedged interpret-mode run — that hard guarantee
        is the worker pool's join-timeout kill; this budget just stops a
        slow-but-alive validation from burning the whole suite.
        """
        deadline = None
        if timeout_s is not None:
            deadline = time.monotonic() + timeout_s
        worst = 0.0
        for i, t in enumerate(tests):
            if deadline is not None and time.monotonic() > deadline:
                from repro.reliability import EvalTimeout
                raise EvalTimeout(
                    f"validation of {space.name} exceeded {timeout_s}s "
                    f"({i}/{len(tests)} cases done)")
            rtol, atol = _tolerance(t.shape_info["dtype"])
            got = space.run(variant, *t.args, interpret=True)
            if oracle is None:
                want = space.oracle(*t.args)
            elif callable(oracle):
                want = oracle(t)
            else:
                want = oracle[i]
            flat_g = got if isinstance(got, tuple) else (got,)
            flat_w = want if isinstance(want, tuple) else (want,)
            for g, w in zip(flat_g, flat_w):
                g = np.asarray(g, np.float32)
                w = np.asarray(w, np.float32)
                finite = np.isfinite(w)
                with np.errstate(invalid="ignore", divide="ignore"):
                    err = np.abs(g - w)
                    bound = atol + rtol * np.abs(w)
                    norm = np.where(finite, err / bound,
                                    np.where(g == w, 0.0, 2.0))
                worst = max(worst, float(np.max(norm)))
                if not np.all(norm <= 1.0):
                    return False, worst
        return True, worst


class ProfilingAgent:
    """Evaluates performance of a variant over the suite.

    ``reps`` controls measurement fidelity: noise ~ 4%/sqrt(reps). The
    multi-agent setup uses the paper's 20 warm-ups + 100 reps; the
    single-agent baseline profiles with reps=1 (no dedicated methodology),
    which is exactly the failure the paper observed on Kernel 1.
    """

    def __init__(self, *, reps: int = 100, noise_base: float = 0.04):
        self.reps = reps
        self.noise = noise_base / max(reps, 1) ** 0.5

    def profile(self, space: KernelSpace, variant,
                tests: Sequence[TestCase]) -> Profile:
        rows, lats = [], []
        agg = {"memory": 0.0, "compute": 0.0, "overhead": 0.0}
        waste, vmem_frac = 0.0, 0.0
        for t in tests:
            try:
                c = space.cost(variant, **t.shape_info)
            except costmodel.Infeasible as e:
                # An infeasible tile: report a huge penalized latency — the
                # compiler would refuse; the planner must react.
                rows.append({"name": t.name, "infeasible": str(e),
                             "latency_us": 1e9})
                lats.append(1e9)
                continue
            s = c.summary()
            s["name"] = t.name
            noisy = c.latency_s * 1e6 * (
                1.0 + _pseudo_noise(f"{space.name}/{variant}/{t.name}",
                                    self.noise))
            s["latency_us"] = noisy
            rows.append(s)
            lats.append(noisy)
            agg["memory"] += c.mem_s
            agg["compute"] += c.compute_s
            agg["overhead"] += c.overhead_s
            waste += s["align_waste_frac"]
            vmem_frac = max(vmem_frac,
                            c.vmem_bytes * costmodel.VMEM_PIPELINE_FACTOR
                            / costmodel.VMEM_BYTES)
        total = sum(agg.values()) or 1.0
        geo = float(np.exp(np.mean(np.log(np.maximum(lats, 1e-9)))))
        return Profile(
            per_shape=rows,
            geomean_latency_us=geo,
            dominant=max(agg, key=agg.get),
            signals={
                "mem_frac": agg["memory"] / total,
                "compute_frac": agg["compute"] / total,
                "overhead_frac": agg["overhead"] / total,
                "align_waste_frac": waste / max(len(tests), 1),
                "vmem_frac": vmem_frac,
                "infeasible": any("infeasible" in r for r in rows),
            },
            noise_scale=self.noise,
        )


class PlanningAgent:
    """Proposes targeted modifications from correctness+performance signals.

    Backed by the deterministic policy (``policy.py``) — the same reasoning
    steps the paper's planning LLM verbalizes: identify the dominant
    bottleneck, pick the transformation family that attacks it, revert on
    regression, stop touching knobs that failed.
    """

    def __init__(self, backend=None):
        from repro.core.policy import PolicyBackend
        self.backend = backend or PolicyBackend()

    def suggest(self, space: KernelSpace, variant, passed: bool,
                profile: Profile, history: list) -> Suggestion:
        return self.backend.plan(space, variant, passed, profile, history)

    def suggest_many(self, space: KernelSpace, variant, passed: bool,
                     profile: Profile, history: list,
                     k: int = 4) -> list[Suggestion]:
        """Up to ``k`` distinct proposals, best-first — what multi-candidate
        strategies (beam search) consume. Falls back to the single ``plan``
        for backends that only speak Algorithm 1."""
        if hasattr(self.backend, "plan_many"):
            return self.backend.plan_many(space, variant, passed, profile,
                                          history, k=k)
        return [self.backend.plan(space, variant, passed, profile, history)]


class CodingAgent:
    """Applies a suggestion to the previous code (genome) — validating the
    move is legal (bounds, pow2 alignment) the way the paper's coding agent
    must produce compilable CUDA."""

    def apply(self, space: KernelSpace, variant, sug: Suggestion):
        knob = next(k for k in space.knobs if k.name == sug.knob)
        value = sug.value
        if knob.kind == "pow2":
            value = int(value)
            value = max(knob.lo, min(knob.hi, 1 << (value - 1).bit_length()))
        elif knob.kind == "bool":
            value = bool(value)
        return space.mutate(variant, knob, value)
