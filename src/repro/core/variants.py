"""Back-compat shim — kernel optimization spaces live with their kernels.

The hand-maintained ``SPACES`` dict that used to be defined here is gone:
each module under ``repro.kernels`` now declares its own ``KernelSpace``
via the ``@register_kernel_space`` decorator (``repro.kernels.registry``),
which keeps the "code" an Astra agent manipulates next to the kernel it
describes and makes adding a kernel a one-file change.

This module re-exports the registry types and the legacy names so existing
imports (``from repro.core.variants import SPACES, KernelSpace, ...``)
keep working unchanged.
"""

from __future__ import annotations

from repro.kernels.registry import (SPACES, KernelSpace, Knob, TestCase,
                                    get_space, make_inputs,
                                    register_kernel_space,
                                    registered_kernels)

__all__ = [
    "SPACES", "KernelSpace", "Knob", "TestCase", "get_space", "make_inputs",
    "register_kernel_space", "registered_kernels",
]
