"""Kernel optimization spaces — the "code" the Astra agents manipulate.

The paper's coding agent edits CUDA source. Our coding agent edits a
*variant genome*: a frozen dataclass of transformation knobs that the
kernel module compiles into a different Pallas lowering (tile geometry,
pass structure, math lowering). Each knob corresponds to one of the
transformation families the paper's LLM discovers (§5.3):

  loop-invariant hoisting  -> ``hoist``            (merge_attn_states)
  reduction restructuring  -> ``two_pass``         (fused_add_rmsnorm)
  vectorized memory access -> ``fused_split`` / tile geometry  (all)
  CUDA intrinsics          -> ``use_reciprocal`` / ``use_rsqrt``
  fast math (``__expf``)   -> ``fast_exp``
  occupancy / grid sizing  -> ``block_rows`` / ``block_cols`` / ``chunk``

``KernelSpace`` bundles everything an agent needs to act on a kernel:
how to run it, its oracle, its analytic cost, and the legal knob moves.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.kernels import flash_decode as _fd
from repro.kernels import fused_add_rmsnorm as _rms
from repro.kernels import merge_attn_states as _merge
from repro.kernels import silu_and_mul as _silu


@dataclasses.dataclass(frozen=True)
class Knob:
    """One legal move in the optimization space."""
    name: str
    kind: str                       # "pow2" | "bool"
    lo: int = 8                     # pow2 bounds
    hi: int = 1024
    # which roofline terms this knob attacks; the planning agent matches
    # knobs against the dominant term of the profile. A knob that removes a
    # whole pass attacks both memory (traffic) and overhead (launch).
    attacks: tuple = ("memory",)    # of "memory" | "compute" | "overhead"
    # For bool knobs: the catalog-optimized direction (paper §5.3). The
    # planning agent only ever moves TOWARD the target; knobs whose baseline
    # already sits at the target (e.g. fuse_s_out) are ablation-only.
    target: Any = None
    note: str = ""


@dataclasses.dataclass(frozen=True)
class TestCase:
    """One element of the test suite T (paper §3.1)."""
    name: str
    args: tuple                     # positional args to run_fn / oracle
    shape_info: dict                # kwargs for the cost function


@dataclasses.dataclass(frozen=True)
class KernelSpace:
    name: str
    baseline: Any
    run: Callable[..., Any]         # run(variant, *args, interpret=...)
    oracle: Callable[..., Any]
    cost: Callable[..., Any]        # cost(variant, **shape_info)
    knobs: tuple[Knob, ...]
    # shapes the TESTING agent draws the suite from (LLaMA-family dims per
    # paper §4); values are generator kwargs, see agents.TestingAgent.
    suite_shapes: tuple[dict, ...]

    def mutate(self, variant, knob: Knob, value) -> Any:
        new = dataclasses.replace(variant, **{knob.name: value})
        # name = genome digest, not lineage (lineage lives in the Log)
        return dataclasses.replace(new, name=f"{self.name}@{knob.name}={value}")


def _run_silu(variant, x, *, interpret=True):
    return _silu.silu_and_mul(x, variant, interpret=interpret)


def _run_rms(variant, x, res, w, *, interpret=True):
    return _rms.fused_add_rmsnorm(x, res, w, variant=variant,
                                  interpret=interpret)


def _run_merge(variant, va, sa, vb, sb, *, interpret=True):
    return _merge.merge_attn_states_lse(va, sa, vb, sb, variant,
                                        interpret=interpret)


def _run_flash(variant, q, k, v, kv_len, *, interpret=True):
    return _fd.flash_decode_attention(q, k, v, kv_len=kv_len,
                                      variant=variant, interpret=interpret)


def _oracle_flash(q, k, v, kv_len):
    from repro.kernels import ref
    return ref.flash_decode_attention(q, k, v, kv_len=kv_len)


# Paper Table 4 shapes: K1 [seq, heads, head_dim]; K2/K3 [batch, hidden]
# (LLaMA-7B/13B/70B dims), plus ragged/odd shapes for robustness.
SILU_SHAPES = ({"batch": 16, "hidden": 4096}, {"batch": 32, "hidden": 5120},
               {"batch": 64, "hidden": 8192}, {"batch": 16, "hidden": 12288},
               {"batch": 17, "hidden": 11008})
RMS_SHAPES = ({"batch": 256, "hidden": 4096}, {"batch": 1024, "hidden": 4096},
              {"batch": 128, "hidden": 11008}, {"batch": 512, "hidden": 14336},
              {"batch": 33, "hidden": 5120})
MERGE_SHAPES = ({"seq": 512, "heads": 32, "head_dim": 256},
                {"seq": 512, "heads": 40, "head_dim": 128},
                {"seq": 768, "heads": 32, "head_dim": 256},
                {"seq": 512, "heads": 64, "head_dim": 128},
                {"seq": 100, "heads": 7, "head_dim": 128})
FLASH_SHAPES = ({"batch": 8, "q_heads": 32, "kv_heads": 8, "head_dim": 128,
                 "seq": 4096},
                {"batch": 32, "q_heads": 14, "kv_heads": 2, "head_dim": 64,
                 "seq": 2048},
                {"batch": 4, "q_heads": 16, "kv_heads": 16, "head_dim": 128,
                 "seq": 8192})


SPACES: dict[str, KernelSpace] = {
    "silu_and_mul": KernelSpace(
        name="silu_and_mul",
        baseline=_silu.BASELINE,
        run=_run_silu,
        oracle=_silu.reference,
        cost=_silu.cost,
        knobs=(
            Knob("fused_split", "bool", attacks=("memory", "overhead"), target=True,
                 note="index gate/up in-place; kills the slice-copy pass "
                      "(round trip + launch)"),
            Knob("block_rows", "pow2", 8, 1024, attacks=("overhead",),
                 note="rows per grid step; bigger tiles amortize step issue"),
            Knob("block_cols", "pow2", 128, 2048, attacks=("overhead",),
                 note="lane-tile width; lane-aligned widths avoid padding"),
            Knob("use_reciprocal", "bool", attacks=("compute",), target=True,
                 note="rcp+mul instead of divide (__frcp_rn analogue)"),
            Knob("fast_exp", "bool", attacks=("compute",), target=True,
                 note="exp2-based sigmoid (__expf analogue)"),
        ),
        suite_shapes=SILU_SHAPES,
    ),
    "fused_add_rmsnorm": KernelSpace(
        name="fused_add_rmsnorm",
        baseline=_rms.BASELINE,
        run=_run_rms,
        oracle=_rms.reference,
        cost=_rms.cost,
        knobs=(
            Knob("two_pass", "bool", attacks=("memory", "overhead"), target=False,
                 note="False = one-pass VPU-tree reduction in VMEM "
                      "(register-resident shuffle analogue)"),
            Knob("block_rows", "pow2", 8, 1024, attacks=("overhead",)),
            Knob("use_rsqrt", "bool", attacks=("compute",), target=True,
                 note="rsqrt intrinsic instead of sqrt+div"),
        ),
        suite_shapes=RMS_SHAPES,
    ),
    "merge_attn_states_lse": KernelSpace(
        name="merge_attn_states_lse",
        baseline=_merge.BASELINE,
        run=_run_merge,
        oracle=_merge.reference,
        cost=_merge.cost,
        knobs=(
            Knob("block_rows", "pow2", 8, 2048, attacks=("overhead",)),
            Knob("hoist", "bool", attacks=("compute",), target=True,
                 note="hoist LSE weights out of the element dimension "
                      "(loop-invariant hoisting, paper Fig. 2)"),
            Knob("use_reciprocal", "bool", attacks=("compute",), target=True),
            Knob("fuse_s_out", "bool", attacks=("memory", "overhead"), target=True,
                 note="compute S_out in the same pass"),
        ),
        suite_shapes=MERGE_SHAPES,
    ),
    "flash_decode": KernelSpace(
        name="flash_decode",
        baseline=_fd.BASELINE,
        run=_run_flash,
        oracle=_oracle_flash,
        cost=_fd.cost,
        knobs=(
            Knob("mask_oob", "bool", attacks=("memory", "compute"), target=True,
                 note="predicate chunks past kv_len (skip DMA + compute)"),
            Knob("chunk", "pow2", 128, 4096, attacks=("overhead",),
                 note="KV rows per grid step"),
            Knob("use_reciprocal", "bool", attacks=("compute",), target=True),
        ),
        suite_shapes=FLASH_SHAPES,
    ),
}


def make_inputs(kernel: str, shape: dict, *, dtype=jnp.float32,
                seed: int = 0) -> TestCase:
    """Materialize one test case for a kernel from a shape spec."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    if kernel == "silu_and_mul":
        b, h = shape["batch"], shape["hidden"]
        x = jax.random.normal(ks[0], (b, 2 * h), dtype=dtype) * 2.0
        return TestCase(f"[{b},{h}]", (x,),
                        {"rows": b, "d": h, "dtype": dtype})
    if kernel == "fused_add_rmsnorm":
        b, h = shape["batch"], shape["hidden"]
        x = jax.random.normal(ks[0], (b, h), dtype=dtype)
        r = jax.random.normal(ks[1], (b, h), dtype=dtype)
        w = (1.0 + 0.1 * jax.random.normal(ks[2], (h,))).astype(dtype)
        return TestCase(f"[{b},{h}]", (x, r, w),
                        {"rows": b, "d": h, "dtype": dtype})
    if kernel == "merge_attn_states_lse":
        s, h, d = shape["seq"], shape["heads"], shape["head_dim"]
        va = jax.random.normal(ks[0], (s, h, d), dtype=dtype)
        vb = jax.random.normal(ks[1], (s, h, d), dtype=dtype)
        # scores with wide dynamic range + empty partitions (-inf)
        sa = jax.random.normal(ks[2], (s, h)) * 8.0
        sb = jax.random.normal(ks[3], (s, h)) * 8.0
        sb = jnp.where(jax.random.uniform(ks[4], (s, h)) < 0.05, -jnp.inf, sb)
        return TestCase(f"[{s},{h},{d}]", (va, sa, vb, sb),
                        {"rows": s * h, "d": d, "dtype": dtype})
    if kernel == "flash_decode":
        b, hq, hkv = shape["batch"], shape["q_heads"], shape["kv_heads"]
        dh, s = shape["head_dim"], shape["seq"]
        q = jax.random.normal(ks[0], (b, hq, dh), dtype=dtype)
        k = jax.random.normal(ks[1], (b, s, hkv, dh), dtype=dtype)
        v = jax.random.normal(ks[2], (b, s, hkv, dh), dtype=dtype)
        kv_len = jax.random.randint(ks[3], (b,), 1, s + 1)
        info = dict(shape)
        info.update(dtype=dtype, mean_kv_len=float(jnp.mean(kv_len)))
        return TestCase(f"[{b},{hq}/{hkv},{dh},s{s}]", (q, k, v, kv_len), info)
    raise KeyError(kernel)
