"""Astra core — the paper's contribution: a multi-agent system that
optimizes production kernels through iterative generation, testing,
profiling, and planning (Algorithm 1)."""

from repro.core.agents import (CodingAgent, PlanningAgent, ProfilingAgent,
                               Suggestion, TestingAgent)
from repro.core.loop import optimize, optimize_all, reintegrate
from repro.core.oplog import Log, LogEntry
from repro.core.single_agent import optimize_single_agent
from repro.core.variants import SPACES, KernelSpace, Knob, make_inputs

__all__ = [
    "CodingAgent", "PlanningAgent", "ProfilingAgent", "TestingAgent",
    "Suggestion", "optimize", "optimize_all", "reintegrate",
    "Log", "LogEntry", "optimize_single_agent",
    "SPACES", "KernelSpace", "Knob", "make_inputs",
]
