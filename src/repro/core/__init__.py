"""Astra core — the paper's contribution: a multi-agent system that
optimizes production kernels through iterative generation, testing,
profiling, and planning (Algorithm 1).

The search machinery itself (strategies, evaluation cache, orchestrator)
lives in ``repro.search``; this package hosts the four agents, the
planning policy, the cost model, and the back-compat entry points.
"""

from repro.core.agents import (CodingAgent, PlanningAgent, ProfilingAgent,
                               Suggestion, TestingAgent)
from repro.core.loop import optimize, optimize_all, reintegrate
from repro.core.oplog import Log, LogEntry
from repro.core.single_agent import optimize_single_agent
from repro.core.variants import (SPACES, KernelSpace, Knob, TestCase,
                                 get_space, make_inputs,
                                 register_kernel_space, registered_kernels)

__all__ = [
    "CodingAgent", "PlanningAgent", "ProfilingAgent", "TestingAgent",
    "Suggestion", "optimize", "optimize_all", "reintegrate",
    "Log", "LogEntry", "optimize_single_agent",
    "SPACES", "KernelSpace", "Knob", "TestCase", "get_space", "make_inputs",
    "register_kernel_space", "registered_kernels",
]
