"""The optimization log — Algorithm 1's ``Log`` of (round, code,
correctness, performance) tuples, plus JSON/pretty output."""

from __future__ import annotations

import dataclasses
import json
from typing import Any


@dataclasses.dataclass
class LogEntry:
    round: int
    code: Any                       # the variant (genome) — our "code"
    correct: bool
    perf: Any                       # Profile
    rationale: str = ""
    max_err: float = 0.0

    def row(self) -> dict:
        return {
            "round": self.round,
            "variant": self.code.describe(),
            "correct": bool(self.correct),
            "latency_us": round(self.perf.geomean_latency_us, 3),
            "dominant": self.perf.dominant,
            "rationale": self.rationale,
            "max_err": float(self.max_err),
        }


class Log:
    """List of LogEntry with selection + serialization helpers."""

    def __init__(self) -> None:
        self.entries: list[LogEntry] = []
        # search metadata: strategy name, cache hit counts, kernel, ...
        self.meta: dict = {}

    def append(self, entry: LogEntry) -> None:
        self.entries.append(entry)

    def best(self) -> LogEntry:
        """Best CORRECT entry by measured geomean latency (final selection)."""
        ok = [e for e in self.entries if e.correct]
        return min(ok, key=lambda e: e.perf.geomean_latency_us)

    def baseline(self) -> LogEntry:
        return self.entries[0]

    def speedup(self) -> float:
        """Geomean speedup of the selected variant over the round-0 baseline."""
        return (self.baseline().perf.geomean_latency_us
                / self.best().perf.geomean_latency_us)

    def table(self) -> str:
        lines = [f"{'rnd':>3} {'ok':>3} {'lat(us)':>10} {'dom':>9}  variant / rationale"]
        for e in self.entries:
            lines.append(
                f"{e.round:>3} {'✓' if e.correct else '✗':>3} "
                f"{e.perf.geomean_latency_us:>10.2f} {e.perf.dominant:>9}  "
                f"{e.code.describe()}"
                + (f"\n{'':>29}  ← {e.rationale}" if e.rationale else ""))
        return "\n".join(lines)

    def to_json(self) -> str:
        payload = {"meta": self.meta, "entries": [e.row() for e in self.entries]}
        return json.dumps(payload, indent=2, default=str)
