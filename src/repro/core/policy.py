"""Planning backends.

``PolicyBackend`` is the shipped deterministic planner: it encodes, as an
explicit decision procedure, the optimization reasoning the paper's LLM
verbalizes — read the profile, identify the dominant roofline term, pick
the transformation family that attacks it, never repeat a move that
regressed, revert when a round made things worse. It sees ONLY what the
paper's planning agent sees: profile signals and the optimization history
— never the oracle's implementation or the cost model's internals.

``LLMBackend`` is the interface where OpenAI o4-mini (paper §4) would slot
in; it is not runnable in this offline container.
"""

from __future__ import annotations

from typing import Any

from repro.core.agents import Profile, Suggestion
from repro.core.variants import KernelSpace, Knob

# term priority when the dominant term has no remaining moves
_FALLBACK = {"memory": ("compute", "overhead"),
             "compute": ("overhead", "memory"),
             "overhead": ("memory", "compute")}


class PolicyBackend:
    """Deterministic profile-driven hill-climbing planner."""

    def plan(self, space: KernelSpace, variant, passed: bool,
             profile: Profile, history: list) -> Suggestion:
        # explore=False: Algorithm 1 holds position when the catalog is
        # exhausted; exploratory resizes are beam-only breadth.
        suggs = self.plan_many(space, variant, passed, profile, history,
                               k=1, explore=False)
        if suggs:
            return suggs[0]
        # Nothing left: hold position (no-op move on the first knob).
        k = space.knobs[0]
        return Suggestion(k.name, getattr(variant, k.name),
                          "no profitable moves left; hold")

    def plan_many(self, space: KernelSpace, variant, passed: bool,
                  profile: Profile, history: list,
                  k: int = 4, explore: bool = True) -> list[Suggestion]:
        """Up to ``k`` distinct proposals, best-first.

        Proposal #1 is exactly what ``plan`` would pick (the greedy chain's
        move); the rest are the remaining catalog moves in term-priority
        order, then (``explore=True``) exploratory tile resizes — the extra
        breadth that multi-candidate strategies (beam search) spend their
        width on.
        """
        out: list[Suggestion] = []
        proposed: set = set()

        def add(sug: Suggestion | None) -> None:
            if sug is None or len(out) >= k:
                return
            move = (sug.knob, sug.value)
            if move in proposed or move in banned:
                return
            if sug.value == getattr(variant, sug.knob):
                return                  # no-op move
            proposed.add(move)
            out.append(sug)

        banned = self._banned_moves(space, history)
        best = self._best(history)
        noise = 2.0 * profile.noise_scale

        # 1. Regression / failure handling: revert the last move.
        if best is not None:
            best_var, best_lat = best
            cur_lat = profile.geomean_latency_us
            if (not passed) or cur_lat > best_lat * (1.0 + noise):
                diff = self._diff(variant, best_var, space)
                if diff is not None:
                    knob, val = diff
                    # a revert is never banned — it restores the best state
                    banned = banned - {(knob.name, val)}
                    add(Suggestion(
                        knob.name, val,
                        f"revert {knob.name}: round regressed "
                        f"({cur_lat:.1f}us vs best {best_lat:.1f}us)"
                        + ("" if passed else " and FAILED tests")))

        # 2. Attack the dominant term, then fallbacks.
        order = (profile.dominant,) + _FALLBACK[profile.dominant]
        for term in order:
            for knob in space.knobs:
                if term not in knob.attacks:
                    continue
                add(self._move(space, variant, knob, profile))

        # 3. Exploratory tile resizes (both directions) for extra beam width.
        if not explore:
            return out
        for term in order:
            for knob in space.knobs:
                if knob.kind != "pow2" or term not in knob.attacks:
                    continue
                cur = getattr(variant, knob.name)
                for val, why in ((cur * 2, "grow"), (cur // 2, "shrink")):
                    if knob.lo <= val <= knob.hi:
                        add(Suggestion(knob.name, val,
                                       f"explore: {why} {knob.name} to {val}"))
        return out

    # -- helpers -----------------------------------------------------------

    def _move(self, space, variant, knob: Knob, profile: Profile):
        cur = getattr(variant, knob.name)
        if knob.kind == "bool":
            # Only move toward the catalog-optimized direction; a knob whose
            # current value already sits at the target offers no move.
            if knob.target is not None and cur != knob.target:
                return Suggestion(knob.name, knob.target,
                                  f"{knob.name}→{knob.target}: attacks "
                                  f"{'/'.join(knob.attacks)} ({knob.note})")
            return None
        # pow2 tile knob
        if profile.signals.get("infeasible") or profile.signals["vmem_frac"] > 1.0:
            if cur > knob.lo:
                return Suggestion(knob.name, cur // 2,
                                  f"halve {knob.name}: VMEM over budget")
            return None
        if profile.signals["vmem_frac"] < 0.25 and cur < knob.hi:
            return Suggestion(knob.name, cur * 2,
                              f"double {knob.name}: amortize per-step issue "
                              f"overhead (vmem {profile.signals['vmem_frac']:.0%})")
        return None

    def _best(self, history):
        ok = [(h["variant"], h["profile"].geomean_latency_us)
              for h in history if h["passed"]]
        return min(ok, key=lambda t: t[1]) if ok else None

    def _diff(self, cur, target, space):
        for knob in space.knobs:
            if getattr(cur, knob.name) != getattr(target, knob.name):
                return knob, getattr(target, knob.name)
        return None

    def _banned_moves(self, space, history) -> set:
        """Moves that were tried and led to failure or regression."""
        banned = set()
        for i in range(1, len(history)):
            h, prev = history[i], history[i - 1]
            sug = h.get("suggestion")
            if sug is None:
                continue
            regressed = (not h["passed"]) or (
                prev["passed"]
                and h["profile"].geomean_latency_us
                > prev["profile"].geomean_latency_us
                * (1.0 + 2.0 * h["profile"].noise_scale))
            if regressed:
                banned.add((sug.knob, sug.value))
        return banned


class LLMBackend:
    """Where the paper's o4-mini planning agent would plug in.

    The prompt contract mirrors the paper: the model receives the current
    kernel (genome + generated Pallas source), the correctness verdict, the
    profile, and the history log; it must answer with a single knob move.
    This container has no network/LLM endpoint, so instantiation fails
    loudly rather than silently degrading.
    """

    def __init__(self, model: str = "o4-mini", endpoint: str | None = None):
        raise NotImplementedError(
            "No LLM endpoint is available in this offline container. "
            "Use PolicyBackend (default), or provide an endpoint and "
            "implement .plan() with your client.")
