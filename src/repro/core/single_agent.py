"""Single-agent baseline (paper §5.2, Table 3).

One agent, one shared context, same round budget R and the same tools —
but none of the role specialization. The paper diagnoses why this loses:

  "the slowdown of Kernel 1 under the single-agent setting was due to
   unrepresentative test inputs generated during test construction, which
   biased the profiling results."

We reproduce that failure *structurally*, not by nerfing the model:

  * Test construction: the single agent whips up ONE quick test case with
    whatever dims it reaches for first (a big power-of-two head_dim /
    hidden), instead of the testing agent's production-shape suite.
  * Profiling: reps=1, no warm-up discipline -> ~4% noise (the dedicated
    profiling agent runs the paper's 20 warm-ups + 100 reps -> ~0.4%).
  * Planning: no per-term roofline breakdown — it greedily walks a fixed
    transformation checklist and keeps any change that doesn't look worse
    than its own noisy single-rep measurement.

On simple kernels (K3) this is fine — matching the paper's observation
that SA ≈ MA there. On K1 the unrepresentative head_dim hides the cost of
a harmful 'neutral-looking' change, which the real evaluation suite then
exposes — the paper's 0.73×.
"""

from __future__ import annotations

import dataclasses
from collections import deque

from repro.core.agents import ProfilingAgent, Suggestion, TestingAgent
from repro.core.oplog import Log, LogEntry
from repro.kernels.registry import KernelSpace, get_space, make_inputs

# The single agent's quick-test dims: it grabs round numbers it has seen in
# model cards — unrepresentative of the serving shapes the kernels actually
# run on (paper Table 4). For Kernel 1 it confuses head_dim with a model's
# *hidden size* (4096) — production head dims are 128/256. At d=4096 the
# narrow-score side traffic is relatively tiny, so a harmful unfused-S_out
# change looks "within noise"; at real head dims it costs ~50% more HBM
# traffic. This is the paper's observed failure ("unrepresentative test
# inputs ... biased the profiling results"), reproduced mechanistically.
_QUICK_SHAPES = {
    "silu_and_mul": {"batch": 8, "hidden": 4096},
    "fused_add_rmsnorm": {"batch": 8, "hidden": 4096},
    "merge_attn_states_lse": {"seq": 256, "heads": 4, "head_dim": 4096},
    "flash_decode": {"batch": 1, "q_heads": 8, "kv_heads": 8,
                     "head_dim": 128, "seq": 1024},
}

# Fixed transformation checklist (no profile-driven targeting): intrinsics
# first (they're the famous tricks), then structure, then tiles.
_CHECKLIST = ("use_reciprocal", "use_rsqrt", "fast_exp", "fuse_s_out",
              "two_pass", "fused_split", "hoist", "mask_oob",
              "block_rows", "block_cols", "chunk")


def optimize_single_agent(kernel: str | KernelSpace, *, rounds: int = 5,
                          verbose: bool = False) -> Log:
    """Run the single-agent loop. Returns a Log comparable to Alg. 1's."""
    space = get_space(kernel) if isinstance(kernel, str) else kernel

    # The agent does its own test construction: one quick case.
    quick = [make_inputs(space.name, _QUICK_SHAPES[space.name], seed=7)]
    tester = TestingAgent()           # same tool access (validate only)
    profiler = ProfilingAgent(reps=1)  # sloppy single-rep measurements

    s_prev = space.baseline
    perf_prev = profiler.profile(space, s_prev, quick)
    log = Log()
    log.append(LogEntry(0, s_prev, True, perf_prev, rationale="baseline"))
    accepted_lat = perf_prev.geomean_latency_us

    knob_by_name = {k.name: k for k in space.knobs}
    todo = deque(n for n in _CHECKLIST if n in knob_by_name)
    for r in range(1, rounds + 1):
        if not todo:
            log.append(LogEntry(r, s_prev, True, perf_prev,
                                rationale="checklist exhausted; hold"))
            continue
        name = todo.popleft()
        knob = knob_by_name[name]
        if knob.kind == "bool":
            # the generalist just flips switches to see what happens — it
            # has no transformation catalog telling it the good direction
            value = not getattr(s_prev, name)
        else:
            value = min(knob.hi, getattr(s_prev, name) * 2)
        sugg = Suggestion(name, value, f"checklist: try {name}={value}")
        s_new = space.mutate(s_prev, knob, value)
        pass_new, max_err = tester.validate(space, s_new, quick)
        perf_new = profiler.profile(space, s_new, quick)
        log.append(LogEntry(r, s_new, pass_new, perf_new,
                            rationale=sugg.rationale, max_err=max_err))
        # accept unless it looks clearly worse on the (noisy) quick test
        if pass_new and perf_new.geomean_latency_us <= accepted_lat * 1.05:
            s_prev, perf_prev = s_new, perf_new
            accepted_lat = perf_new.geomean_latency_us
        if verbose:
            print(f"[SA {space.name}] r{r} {sugg.rationale} -> "
                  f"{'kept' if s_prev is s_new else 'rejected'} "
                  f"({perf_new.geomean_latency_us:.2f}us)")

    # The single agent SHIPS its last accepted kernel — it has no
    # independent log review (that's the planning agent's job in MA).
    final = dataclasses.replace(s_prev, name=f"{space.name}_single_agent")
    log.entries[-1].code = final
    log.final_variant = final
    return log
