"""Analytic TPU v5e cost model — the ProfilingAgent's "hardware".

The paper's profiling agent measures kernels on an H100 (20 warm-ups, 100
reps). This container has no TPU, so the profiling agent instead evaluates
an analytic roofline model of TPU v5e derived from the kernel's variant
parameters and input shapes. The model is deliberately mechanistic — it
charges for the same things Nsight Compute surfaces (DRAM traffic,
transcendental throughput, launch/step overhead, occupancy/alignment
waste) so the PlanningAgent can reason from the same kind of signals the
paper's planning agent reads out of a profile.

Hardware constants (TPU v5e, per chip — same numbers as the §Roofline
analysis so kernel-level and system-level reasoning agree):

  * 197 TFLOP/s bf16 on the MXU (fp32 ≈ 1/4 of that through the MXU).
  * ~7 TOP/s fp32 element-wise on the VPU (8×128 lanes, ~1.7 GHz, FMA=2);
    transcendentals cost multiple VPU ops (polynomial expansions).
  * 819 GB/s HBM bandwidth; DMA transactions are 512-byte granular.
  * ~128 MiB VMEM; a pipelined Pallas grid needs 2× (double buffering).
  * Grid-step issue overhead ~150 ns (DMA descriptor + semaphore wait,
    amortized by Mosaic's automatic pipelining); kernel launch ~2 µs.
"""

from __future__ import annotations

import dataclasses

# --- TPU v5e constants ------------------------------------------------------
PEAK_MXU_BF16 = 197e12          # FLOP/s
PEAK_MXU_FP32 = PEAK_MXU_BF16 / 4
PEAK_VPU_FP32 = 7e12            # element-ops/s (fp32 ALU, FMA counted as 2)
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link (used by §Roofline)
VMEM_BYTES = 128 * 2**20
VMEM_PIPELINE_FACTOR = 2        # double buffering
DMA_GRANULE = 512               # bytes; narrower reads are padded
STEP_OVERHEAD_S = 60e-9         # per grid step (scalar-core dispatch)
LAUNCH_OVERHEAD_S = 2e-6        # per pallas_call

# VPU op weights (fp32-equivalent element ops). Transcendentals lower to
# polynomial sequences on the VPU; divides iterate Newton steps.
OP = {
    "add": 1.0, "mul": 1.0, "fma": 1.0, "max": 1.0, "cmp": 1.0,
    "cast": 1.0,
    "exp": 12.0,      # range-reduce + poly (the __expf analogue costs ~3)
    "exp_fast": 3.0,
    "div": 8.0,       # Newton-Raphson refine
    "rcp": 3.0,       # the __frcp_rn analogue
    "sqrt": 8.0,
    "rsqrt": 3.0,
    "log": 12.0,
}


class Infeasible(Exception):
    """Variant cannot run (e.g. VMEM working set exceeds the budget)."""


@dataclasses.dataclass(frozen=True)
class Cost:
    """Analytic cost of one kernel invocation on one input shape."""
    hbm_bytes: float            # total HBM traffic (reads + writes), incl.
                                # DMA-granule padding waste
    vpu_ops: float              # weighted fp32-equivalent element ops
    mxu_flops: float = 0.0
    mxu_dtype: str = "bf16"
    grid_steps: int = 1
    n_calls: int = 1            # pallas_call launches (multi-pass variants)
    vmem_bytes: int = 0         # per-step working set (pre-pipelining)
    align_waste_bytes: float = 0.0  # traffic wasted on padding/misalignment

    def validate(self) -> None:
        if self.vmem_bytes * VMEM_PIPELINE_FACTOR > VMEM_BYTES:
            raise Infeasible(
                f"VMEM working set {self.vmem_bytes/2**20:.1f} MiB x"
                f"{VMEM_PIPELINE_FACTOR} exceeds {VMEM_BYTES/2**20:.0f} MiB")

    # --- roofline terms ---
    @property
    def mem_s(self) -> float:
        return (self.hbm_bytes + self.align_waste_bytes) / HBM_BW

    @property
    def compute_s(self) -> float:
        mxu_peak = PEAK_MXU_BF16 if self.mxu_dtype == "bf16" else PEAK_MXU_FP32
        return self.vpu_ops / PEAK_VPU_FP32 + self.mxu_flops / mxu_peak

    @property
    def prologue_s(self) -> float:
        # First tile's DMA fill is not overlapped with compute (pipeline
        # ramp-up); over-sized blocks pay for it — tile sizing is a
        # trade-off, not monotone.
        return self.n_calls * self.vmem_bytes / HBM_BW

    @property
    def overhead_s(self) -> float:
        return (self.grid_steps * STEP_OVERHEAD_S
                + self.n_calls * LAUNCH_OVERHEAD_S + self.prologue_s)

    @property
    def latency_s(self) -> float:
        # Mosaic pipelines DMA against compute; the winner of the roofline
        # max sets the steady-state rate, plus ramp-up + launch + step issue.
        return (max(self.mem_s, self.compute_s,
                    self.grid_steps * STEP_OVERHEAD_S)
                + self.prologue_s + self.n_calls * LAUNCH_OVERHEAD_S)

    def dominant(self) -> str:
        terms = {"memory": self.mem_s, "compute": self.compute_s,
                 "overhead": self.overhead_s}
        return max(terms, key=terms.get)

    def summary(self) -> dict:
        return {
            "latency_us": self.latency_s * 1e6,
            "mem_us": self.mem_s * 1e6,
            "compute_us": self.compute_s * 1e6,
            "overhead_us": self.overhead_s * 1e6,
            "dominant": self.dominant(),
            "hbm_mb": self.hbm_bytes / 2**20,
            "align_waste_frac": self.align_waste_bytes
            / max(self.hbm_bytes, 1.0),
            "vmem_kb": self.vmem_bytes / 1024,
            "grid_steps": self.grid_steps,
        }


def combine(costs: list[Cost]) -> Cost:
    """Sum the costs of a multi-pass variant (one Cost per pallas_call)."""
    return Cost(
        hbm_bytes=sum(c.hbm_bytes for c in costs),
        vpu_ops=sum(c.vpu_ops for c in costs),
        mxu_flops=sum(c.mxu_flops for c in costs),
        mxu_dtype=costs[0].mxu_dtype,
        grid_steps=sum(c.grid_steps for c in costs),
        n_calls=sum(c.n_calls for c in costs),
        vmem_bytes=max(c.vmem_bytes for c in costs),
        align_waste_bytes=sum(c.align_waste_bytes for c in costs),
    )


def dma_bytes(logical_bytes: float, row_bytes: float) -> tuple[float, float]:
    """(charged_bytes, waste) for a transfer whose rows are `row_bytes` wide.

    DMAs move at least ``DMA_GRANULE`` bytes per row; narrow rows (e.g. the
    ``[rows, 1]`` score columns of Kernel 1) pay padding.
    """
    if row_bytes >= DMA_GRANULE:
        return logical_bytes, 0.0
    factor = DMA_GRANULE / max(row_bytes, 1.0)
    return logical_bytes, logical_bytes * (factor - 1.0)
