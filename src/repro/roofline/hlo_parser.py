"""Trip-count-aware HLO cost analyzer.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE — with
scan-over-layers and microbatch grad-accum, that undercounts flops, bytes
and (critically) the collective schedule by the product of trip counts.
This parser walks the optimized SPMD module text:

  * computations are parsed into op lists with a per-computation symbol
    table (op -> shape) so dot contraction sizes are recoverable;
  * ``while`` ops carry ``backend_config={"known_trip_count":{"n":...}}`` —
    bodies/conditions are charged trip-count times;
  * fusion/call/to_apply edges propagate multipliers transitively;
  * flops: dot/convolution (2*out*contract) + 1/elem for arithmetic ops;
  * HBM traffic: operand+output bytes of fusions, dots, copies, gathers,
    scatters (the fusion boundary IS the HBM round trip in XLA's model);
  * collective bytes: payload of all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute, with ring factors (all-reduce 2x).

Everything is per-chip (the module is the per-device SPMD program).
"""

from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+)$")
_CALL_ATTR_RE = re.compile(
    r"(?:to_apply|body|condition|calls)=%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

_COLL_FACTOR = {
    "all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0, "ragged-all-to-all": 1.0,
}
_ARITH_1FLOP = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "compare", "select", "and", "or", "xor", "not", "clamp",
    "floor", "ceil", "round-nearest-afz", "sign",
}
_ARITH_XFLOP = {"exponential": 8, "log": 8, "tanh": 8, "rsqrt": 4,
                "sqrt": 4, "power": 10, "logistic": 8, "sine": 8,
                "cosine": 8, "exponential-minus-one": 8, "log-plus-one": 8,
                "erf": 8, "cbrt": 8, "atan2": 10}
_TRAFFIC_OPS = {"fusion", "copy", "gather", "scatter", "dot", "convolution",
                "dynamic-slice", "dynamic-update-slice", "transpose",
                "reduce", "broadcast", "iota", "concatenate", "reverse",
                "slice", "pad", "sort", "cholesky", "triangular-solve"}
_KERNEL_SCOPE_RE = re.compile(
    r"(flash|mlstm|slstm|rglru)_kernel")


def _shape_elems_bytes(shape_str: str):
    """Total (elems, bytes) over every typed buffer in a shape string."""
    elems = 0
    byts = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclasses.dataclass
class _Op:
    name: str
    opcode: str
    out_shape: str
    rhs: str
    operands: list
    callees: list
    trip: int = 1


class Module:
    def __init__(self, text: str):
        self.computations: dict[str, list[_Op]] = {}
        self.shapes: dict[tuple, str] = {}     # (comp, op) -> shape str
        self._parse(text)
        self._memo: dict[str, dict] = {}

    # -- parsing -------------------------------------------------------------
    def _parse(self, text: str):
        comp = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line or line.startswith("//"):
                continue
            header = None
            if line.startswith("ENTRY"):
                header = "ENTRY"
            elif line.startswith("%") and line.endswith("{"):
                header = line[1:].split(" ", 1)[0].split("(")[0]
            if header is not None:
                if header == "ENTRY":
                    header = line.split("%", 1)[1].split(" ", 1)[0] \
                        .split("(")[0]
                    self.entry = header
                comp = header
                self.computations[comp] = []
                continue
            if comp is None:
                continue
            if line.startswith("}"):
                comp = None
                continue
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            # rhs = "<shape> <opcode>(...)" — tuple shapes contain nested
            # parens and /*index=N*/ comments, so scan for balance.
            if rhs.startswith("("):
                depth = 0
                shape_end = -1
                for i, ch in enumerate(rhs):
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            shape_end = i + 1
                            break
                if shape_end < 0:
                    continue
                out_shape = rhs[:shape_end]
                om = re.match(r"\s*([\w\-]+)\(", rhs[shape_end:])
                if not om:
                    continue
                opcode = om.group(1)
                arg_str = rhs[shape_end + om.end():]
            else:
                om = re.match(r"(\S+)\s+([\w\-]+)\(", rhs)
                if not om:
                    continue
                out_shape, opcode = om.group(1), om.group(2)
                arg_str = rhs[om.end():]
            callees = _CALL_ATTR_RE.findall(rhs)
            # operands: %names inside the first (...) group only
            depth, i, end = 1, 0, len(arg_str)
            while i < len(arg_str) and depth:
                if arg_str[i] == "(":
                    depth += 1
                elif arg_str[i] == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                i += 1
            operands = _OPERAND_RE.findall(arg_str[:end])
            trip = 1
            tm = _TRIP_RE.search(rhs)
            if tm:
                trip = int(tm.group(1))
            op = _Op(name, opcode, out_shape, rhs, operands, callees, trip)
            self.computations[comp].append(op)
            self.shapes[(comp, name)] = out_shape

    # -- costing -------------------------------------------------------------
    def _dot_flops(self, comp: str, op: _Op) -> float:
        out_elems, _ = _shape_elems_bytes(op.out_shape)
        cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rhs)
        if not cm or not op.operands:
            return 2.0 * out_elems
        lhs_shape = self.shapes.get((comp, op.operands[0]), "")
        sm = _SHAPE_RE.search(lhs_shape)
        if not sm:
            return 2.0 * out_elems
        dims = [int(d) for d in sm.group(2).split(",") if d]
        contract = 1
        for idx in cm.group(1).split(","):
            if idx and int(idx) < len(dims):
                contract *= dims[int(idx)]
        return 2.0 * out_elems * contract

    def _op_cost(self, comp: str, op: _Op) -> dict:
        flops = 0.0
        traffic = 0.0
        coll = defaultdict(float)
        out_elems, out_bytes = _shape_elems_bytes(op.out_shape)
        kind = op.opcode
        base = kind.replace("-start", "")
        if base in _COLL_FACTOR and not kind.endswith("-done"):
            coll[base] += out_bytes * _COLL_FACTOR[base]
            traffic += out_bytes
        elif kind == "dot":
            flops += self._dot_flops(comp, op)
        elif kind == "convolution":
            flops += 2.0 * out_elems * 128  # rare here; coarse
        elif kind in _ARITH_1FLOP:
            flops += out_elems
        elif kind in _ARITH_XFLOP:
            flops += out_elems * _ARITH_XFLOP[kind]
        elif kind == "reduce":
            flops += out_elems  # ~1 op per output elem per reduced elem is
            # overcounted inside fusions; reduces outside fusions are rare
        in_fused = "fused" in comp
        # named_scope "*_kernel" marks regions that are ONE fused Pallas
        # kernel on the TPU target — interior tensors live in VMEM, so their
        # XLA-CPU fusion round trips are not TPU HBM traffic. The analytic
        # kernel traffic is added back by roofline/analysis.kernel_traffic.
        in_kernel = _KERNEL_SCOPE_RE.search(op.rhs) is not None
        if kind in _TRAFFIC_OPS and not in_fused and not in_kernel:
            op_bytes = [ _shape_elems_bytes(self.shapes[(comp, o)])[1]
                         for o in op.operands
                         if (comp, o) in self.shapes ]
            if kind == "dynamic-slice":
                traffic += 2 * out_bytes            # read + write the slice
            elif kind in ("dynamic-update-slice", "scatter"):
                # in-place update: only the touched region moves
                if op_bytes:
                    traffic += 2 * (sum(op_bytes) - max(op_bytes))
            elif kind == "fusion" and self._fusion_slices(op):
                # fusion wrapping a slice/update of a scan-carried buffer:
                # the big operand (and, for updates, the aliased output)
                # is not streamed — only the touched region moves
                if op_bytes:
                    rest = sum(op_bytes) - max(op_bytes)
                    traffic += (2 * rest if self._fusion_updates(op)
                                else out_bytes + rest)
            else:
                traffic += out_bytes + sum(op_bytes)
        return {"flops": flops, "traffic": traffic, "coll": dict(coll)}

    def _fusion_slices(self, op: _Op) -> bool:
        return any(o2.opcode in ("dynamic-slice", "dynamic-update-slice",
                                 "scatter")
                   for c in op.callees
                   for o2 in self.computations.get(c, []))

    def _fusion_updates(self, op: _Op) -> bool:
        return any(o2.opcode in ("dynamic-update-slice", "scatter")
                   for c in op.callees
                   for o2 in self.computations.get(c, []))

    def comp_cost(self, comp: str) -> dict:
        """Aggregate cost of one computation incl. its callees."""
        if comp in self._memo:
            return self._memo[comp]
        total = {"flops": 0.0, "traffic": 0.0, "coll": defaultdict(float)}
        for op in self.computations.get(comp, []):
            c = self._op_cost(comp, op)
            mult = op.trip if op.opcode == "while" else 1
            total["flops"] += c["flops"]
            total["traffic"] += c["traffic"]
            for k, v in c["coll"].items():
                total["coll"][k] += v
            for callee in op.callees:
                sub = self.comp_cost(callee)
                total["flops"] += sub["flops"] * mult
                total["traffic"] += sub["traffic"] * mult
                for k, v in sub["coll"].items():
                    total["coll"][k] += v * mult
        out = {"flops": total["flops"], "traffic": total["traffic"],
               "coll": dict(total["coll"])}
        self._memo[comp] = out
        return out

    def entry_cost(self) -> dict:
        c = self.comp_cost(self.entry)
        c["coll_bytes"] = sum(c["coll"].values())
        return c


def analyze_text(hlo_text: str) -> dict:
    """Per-chip {flops, traffic, coll, coll_bytes} with trip counts."""
    return Module(hlo_text).entry_cost()
