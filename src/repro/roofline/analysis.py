"""Roofline analysis from compiled dry-run artifacts (TPU v5e targets).

Three terms per (arch x shape x mesh):

    compute    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / link_bw

``compiled.cost_analysis()`` operates on the post-partitioning (per-device)
SPMD module, so its flops/bytes are already per-chip. Collective bytes are
NOT in cost_analysis — we parse the optimized HLO text and sum the output
payload of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op (ring-transfer factors folded into a per-op weight).

MODEL_FLOPS (6·N·D train / 2·N·D inference, N = active params) gives the
"useful compute" yardstick; HLO/MODEL ratio exposes remat and dispatch
waste.
"""

from __future__ import annotations

import dataclasses
import json
import re

# TPU v5e, per chip
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9          # per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

# collective op -> effective wire factor per output byte (ring algorithms):
# all-reduce moves ~2x payload, all-gather/reduce-scatter ~1x, all-to-all
# ~1x, collective-permute 1x.
_COLLECTIVES = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
    "ragged-all-to-all": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute"
    r"|ragged-all-to-all)(?:-start)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum wire bytes per collective kind from optimized HLO text."""
    out = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        if "-done" in line.split("=")[1].split("(")[0]:
            continue
        out[kind] += _shape_bytes(shape_str) * _COLLECTIVES[kind]
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: dict
    model_flops_global: float
    peak_memory_per_chip: float
    raw_cost_flops: float = 0.0   # XLA cost_analysis (while bodies once)

    @property
    def compute_s(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline-model step latency: the dominant term binds."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (global) — remat/dispatch waste gauge."""
        hlo_global = self.flops_per_chip * self.chips
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline-model step time."""
        denom = self.step_time_s * PEAK_FLOPS_BF16 * self.chips
        return self.model_flops_global / denom if denom else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_ms": self.compute_s * 1e3,
            "memory_ms": self.memory_s * 1e3,
            "collective_ms": self.collective_s * 1e3,
            "dominant": self.dominant,
            "step_ms": self.step_time_s * 1e3,
            "useful_flops_ratio": self.useful_ratio,
            "mfu_at_roofline": self.mfu,
            "hbm_gb_per_chip": self.peak_memory_per_chip / 2**30,
            "coll_breakdown_mb": {k: v / 2**20
                                  for k, v in self.coll_breakdown.items()
                                  if v},
        }


def analyze(*, arch, shape, mesh_name, chips, cost, hlo_text, mem_stats,
            model_flops_global, kernel_traffic: float = 0.0) -> Roofline:
    """Build a Roofline from dry-run outputs.

    flops/bytes/collectives come from the trip-count-aware HLO parser
    (``hlo_parser``) — XLA's cost_analysis counts while bodies once, which
    undercounts scan-over-layers modules by ~L x microbatches. The raw
    cost_analysis flops are kept as a cross-check field.
    """
    from repro.roofline import hlo_parser
    parsed = hlo_parser.analyze_text(hlo_text)
    parsed["traffic"] += kernel_traffic
    peak_mem = 0.0
    if mem_stats is not None:
        peak_mem = (getattr(mem_stats, "temp_size_in_bytes", 0)
                    + getattr(mem_stats, "argument_size_in_bytes", 0)
                    + getattr(mem_stats, "output_size_in_bytes", 0)
                    - getattr(mem_stats, "alias_size_in_bytes", 0))
    r = Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_chip=parsed["flops"],
        bytes_per_chip=parsed["traffic"],
        coll_bytes_per_chip=parsed["coll_bytes"],
        coll_breakdown=parsed["coll"],
        model_flops_global=model_flops_global,
        peak_memory_per_chip=peak_mem,
    )
    r.raw_cost_flops = float(cost.get("flops", 0.0))
    return r


def model_flops(cfg, shape_kind: str, tokens: int) -> float:
    """6·N·D train, 2·N·D inference (N = activated params)."""
    n = cfg.activated_params
    return (6.0 if shape_kind == "train" else 2.0) * n * tokens


def _attention_calls(cfg) -> int:
    """Flash-attention invocations per full forward, by family."""
    if cfg.family == "xlstm":
        return 0
    if cfg.family == "hybrid":
        return cfg.n_layers // 3            # attention layers only
    if cfg.family == "encdec":
        return cfg.enc_layers + 2 * cfg.n_layers   # enc + dec self + cross
    return cfg.n_layers


def kernel_traffic(cfg, spec, chips: int) -> float:
    """Analytic per-chip HBM bytes of the named ``*_kernel`` regions.

    flash_kernel: streams Q,K,V once, writes O(+stats): fwd = Q+K+V+O;
    backward reads Q,K,V,O,dO and writes dQ,dK,dV (~2x fwd); training
    remat replays the forward (~+1x). Interior probability tiles never
    touch HBM — that is the point of the kernel.

    mlstm/slstm_kernel (GLA-style linear-scan kernels): stream q,k,v /
    z,i,f once per sweep, write h once; the recurrent state (C,n,m) stays
    in VMEM across the sweep (chunk-boundary states spill for remat).
    """
    if spec.kind == "decode":
        return 0.0                          # decode uses flash_decode path
    b, s = spec.global_batch, spec.seq_len
    item = 4                                # fp32 compute in the reference
    train_factor = 4.0 if spec.kind == "train" else 1.0
    total = 0.0

    # flash attention regions
    q_bytes = b * s * cfg.n_heads * cfg.head_dim * item
    kv_bytes = 2 * b * s * cfg.n_kv_heads * cfg.head_dim * item
    total += _attention_calls(cfg) * (2 * q_bytes + kv_bytes) * train_factor

    if cfg.family == "xlstm":
        period = 8
        n_p = cfg.n_layers // period
        h, d_inner = cfg.n_heads, 2 * cfg.d_model
        dh = d_inner // h
        dqk = dh // 2
        # mLSTM sweep: q,k [B,S,H,dqk], v,h [B,S,H,dh], gates 2x[B,S,H],
        # chunk-boundary state spills [S/CHUNK, B, H, dqk, dh]
        per = (b * s * h * (2 * dqk + 2 * dh + 2) * item
               + (s // 64) * b * h * dqk * dh * item)
        total += n_p * (period - 1) * per * train_factor
        # sLSTM sweep: z,i,f reads + h write [B,S,D]
        total += n_p * 4 * b * s * cfg.d_model * item * train_factor
    return total / chips


flash_traffic = kernel_traffic   # backwards-compatible alias


def save_rows(rows: list[dict], path: str):
    with open(path, "w") as f:
        json.dump(rows, f, indent=2, default=str)
