"""Model definitions for the assigned architectures (5 families)."""
