"""RecurrentGemma-2B (Griffin) — RG-LRU recurrent blocks + local MQA
attention in a 2:1 pattern [arXiv:2402.19427].

Layer pattern: periods of (recurrent, recurrent, local-attention); 26
layers = 8 scanned periods + 2 recurrent tail layers.

RG-LRU: a_t = exp(-c softplus(Λ) ⊙ r_t); h_t = a_t h_{t-1} +
sqrt(1-a_t²)(i_t ⊙ x_t). Training/prefill runs it as a PARALLEL
associative scan (affine composition (a,b)∘(a',b') = (aa', a'b + b')) —
the production-correct TPU formulation (log-depth, MXU-free); decode is the
O(1) per-step update. Bounded window + O(1) state = the long_500k story.

Local attention uses the shared sliding-window path (rolling cache), so
decode consumes the flash-decode kernel and its Kernel-1 merge math.
Adaptation notes (DESIGN.md): MLP is SwiGLU (exercises paper Kernel 3;
Gemma's GeGLU differs only in the activation), conv1d width 4 causal.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L

C_RGLRU = 8.0

# The RG-LRU recurrence and the causal-conv state absorb every processed
# token, so right-padded bucketed prefill would corrupt both. The serving
# engine prefills Griffin prompts at exact length.
PAD_PREFILL = False

# The cache mixes rolling-window K/V with fixed-size recurrent + conv
# state leaves: the recurrent leaves do not page, and the windowed K/V is
# already bounded. Contiguous per-slot pool only.
PAGED_OK = False


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _rec_params(key, cfg, dtype):
    d, r = cfg.d_model, cfg.lru_width or cfg.d_model
    ks = jax.random.split(key, 7)
    lam = jax.random.uniform(ks[5], (r,), jnp.float32, 0.9, 0.999)
    return {
        "norm": L.ones_init((d,), ("embed",)),
        "w_main": L.dense_init(ks[0], (d, r), ("embed", "lru"), dtype=dtype),
        "w_gate": L.dense_init(ks[1], (d, r), ("embed", "lru"), dtype=dtype),
        "conv_w": L.zeros_init((4, r), ("conv", "lru"), dtype),
        "w_a": L.dense_init(ks[2], (r, r), ("lru", None), dtype=dtype),
        "w_x": L.dense_init(ks[3], (r, r), ("lru", None), dtype=dtype),
        # Λ parametrized pre-softplus so a stays in (0, 1)
        "lam": (jnp.log(jnp.exp(-jnp.log(lam) / C_RGLRU) - 1.0), ("lru",)),
        "w_out": L.dense_init(ks[4], (r, d), ("lru", "embed"), dtype=dtype),
        "mlp": L.mlp_params(ks[6], cfg, dtype),
        "mlp_norm": L.ones_init((d,), ("embed",)),
    }


def _attn_layer_params(key, cfg, dtype):
    ka, km = jax.random.split(key)
    return {
        "attn": L.attn_params(ka, cfg, dtype),
        "mlp": L.mlp_params(km, cfg, dtype),
        "attn_norm": L.ones_init((cfg.d_model,), ("embed",)),
        "mlp_norm": L.ones_init((cfg.d_model,), ("embed",)),
    }


def init(cfg: ModelConfig, key):
    n_periods = cfg.n_layers // 3            # 26 -> 8 periods + 2 tail
    n_tail = cfg.n_layers - 3 * n_periods
    keys = jax.random.split(key, 5)
    dtype = jnp.float32

    def one_period(k):
        k1, k2, k3 = jax.random.split(k, 3)
        recs = [L.split_tree(_rec_params(kk, cfg, dtype)) for kk in (k1, k2)]
        rec_stack = jax.tree.map(lambda *ts: jnp.stack(ts),
                                 *[r[0] for r in recs])
        att, att_ax = L.split_tree(_attn_layer_params(k3, cfg, dtype))
        rec_ax = jax.tree.map(lambda ax: ("stack",) + ax, recs[0][1],
                              is_leaf=lambda x: isinstance(x, tuple))
        return {"rec": rec_stack, "attn": att}, {"rec": rec_ax, "attn": att_ax}

    p_keys = jax.random.split(keys[0], n_periods)
    stacked = jax.vmap(lambda k: one_period(k)[0])(p_keys)
    _, axes_one = one_period(p_keys[0])
    period_axes = jax.tree.map(lambda ax: ("layers",) + ax, axes_one,
                               is_leaf=lambda x: isinstance(x, tuple))

    t_keys = jax.random.split(keys[1], max(n_tail, 1))
    tails = [L.split_tree(_rec_params(kk, cfg, dtype))
             for kk in t_keys[:n_tail]]
    tail_stack = jax.tree.map(lambda *ts: jnp.stack(ts),
                              *[t[0] for t in tails]) if tails else {}
    tail_axes = jax.tree.map(lambda ax: ("layers",) + ax, tails[0][1],
                             is_leaf=lambda x: isinstance(x, tuple)) \
        if tails else {}

    emb, emb_ax = L.dense_init(keys[2], (cfg.padded_vocab, cfg.d_model),
                               ("embed_vocab", "mlp"), scale=1.0, dtype=dtype)
    head, head_ax = L.dense_init(keys[3], (cfg.d_model, cfg.padded_vocab),
                                 ("embed", "vocab"), dtype=dtype)
    fnorm, fnorm_ax = L.ones_init((cfg.d_model,), ("embed",))
    return ({"embed": emb, "periods": stacked, "tail": tail_stack,
             "final_norm": fnorm, "lm_head": head},
            {"embed": emb_ax, "periods": period_axes, "tail": tail_axes,
             "final_norm": fnorm_ax, "lm_head": head_ax})


# --------------------------------------------------------------------------
# RG-LRU block
# --------------------------------------------------------------------------

def _causal_conv(x, w, state=None):
    """Depthwise causal conv, width 4. x: [B,S,R]; state: [B,3,R] or None."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(width))
    new_state = xp[:, -(width - 1):] if width > 1 else None
    return out, new_state


def rglru(p, xi, h0=None):
    """RG-LRU over a segment. xi: [B,S,R] (conv'd branch). Returns (y, h_S)."""
    xf = xi.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("bsr,rq->bsq", xf, p["w_a"]))
    i = jax.nn.sigmoid(jnp.einsum("bsr,rq->bsq", xf, p["w_x"]))
    log_a = -C_RGLRU * jax.nn.softplus(p["lam"]) * r        # [B,S,R]
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * xf)
    if h0 is not None:
        # fold the carried state into step 0's offset
        gated = gated.at[:, 0].add(a[:, 0] * h0)
    # parallel affine scan: (a, b) ∘ (a', b') = (a·a', a'·b + b')
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    _, h = lax.associative_scan(combine, (a, gated), axis=1)
    return h.astype(xi.dtype), h[:, -1]


def rec_block(p, x, cfg: ModelConfig, state=None):
    """Full Griffin recurrent residual block (+ its MLP sublayer)."""
    conv_state, h0 = (None, None) if state is None else state
    normed = L.rms_norm(x, p["norm"], cfg.norm_eps)
    main = jnp.einsum("bsd,dr->bsr", normed, p["w_main"].astype(x.dtype))
    gate = jnp.einsum("bsd,dr->bsr", normed, p["w_gate"].astype(x.dtype))
    main, new_conv = _causal_conv(main, p["conv_w"], conv_state)
    h, h_last = rglru(p, main, h0)
    y = h * jax.nn.gelu(gate)
    y = jnp.einsum("bsr,rd->bsd", y, p["w_out"].astype(x.dtype))
    x = x + y
    normed = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    x = x + L.mlp_block(p["mlp"], normed)
    return x, (new_conv, h_last)


def attn_layer(p, x, cfg: ModelConfig, chunk=512):
    normed = L.rms_norm(x, p["attn_norm"], cfg.norm_eps)
    attn_out, kv = L.attention_block(p["attn"], normed, cfg, chunk=chunk)
    x = x + attn_out
    normed = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    x = x + L.mlp_block(p["mlp"], normed)
    return x, kv


# --------------------------------------------------------------------------
# model API
# --------------------------------------------------------------------------

def _period_fwd(p_period, x, cfg, chunk, collect=False):
    x = L.shard_batch(x)
    kvs = None
    for i in range(2):
        p_i = jax.tree.map(lambda t: t[i], p_period["rec"])
        x, _ = rec_block(p_i, x, cfg)
    x, kvs = attn_layer(p_period["attn"], x, cfg, chunk)
    return x, kvs


def forward(params, cfg: ModelConfig, tokens, *, chunk: int = 512):
    x = L.embed_tokens(params["embed"], tokens).astype(cfg.jnp_dtype)

    def body(x, p_period):
        fn = jax.checkpoint(
            lambda p, xx: _period_fwd(p, xx, cfg, chunk)[0],
            policy=jax.checkpoint_policies.nothing_saveable)
        return fn(p_period, x), None

    x, _ = lax.scan(body, x, params["periods"])
    if params["tail"]:
        def tbody(x, p_rec):
            return rec_block(p_rec, x, cfg)[0], None
        x, _ = lax.scan(tbody, x, params["tail"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(x, params["lm_head"])


def loss_fn(params, cfg: ModelConfig, batch, *, chunk: int = 512):
    logits = forward(params, cfg, batch["tokens"])
    return L.ce_loss(logits, batch["labels"], cfg.vocab)


def cache_spec(cfg: ModelConfig, batch: int, seq: int):
    n_periods = cfg.n_layers // 3
    n_tail = cfg.n_layers - 3 * n_periods
    r = cfg.lru_width or cfg.d_model
    w = min(seq, cfg.window or seq)
    f32, dt = jnp.float32, cfg.jnp_dtype
    spec = {
        "conv": jax.ShapeDtypeStruct((n_periods, 2, batch, 3, r), dt),
        "h": jax.ShapeDtypeStruct((n_periods, 2, batch, r), f32),
        "k": jax.ShapeDtypeStruct(
            (n_periods, batch, w, cfg.n_kv_heads, cfg.head_dim), dt),
        "v": jax.ShapeDtypeStruct(
            (n_periods, batch, w, cfg.n_kv_heads, cfg.head_dim), dt),
        "tconv": jax.ShapeDtypeStruct((max(n_tail, 1), batch, 3, r), dt),
        "th": jax.ShapeDtypeStruct((max(n_tail, 1), batch, r), f32),
    }
    axes = {
        "conv": ("layers", "stack", "batch", "conv", "lru"),
        "h": ("layers", "stack", "batch", "lru"),
        "k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        "v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        "tconv": ("layers", "batch", "conv", "lru"),
        "th": ("layers", "batch", "lru"),
    }
    return spec, axes


def init_cache(cfg: ModelConfig, batch: int, seq: int):
    spec, axes = cache_spec(cfg, batch, seq)
    return {k: jnp.zeros(v.shape, v.dtype) for k, v in spec.items()}, axes


def prefill(params, cfg: ModelConfig, tokens, *, chunk: int = 512,
            cache_len: int | None = None, length=None):
    assert length is None, "griffin prefill does not support padded prompts"
    b, s = tokens.shape
    x = L.embed_tokens(params["embed"], tokens).astype(cfg.jnp_dtype)
    w = min(s, cfg.window or s)

    def body(x, p_period):
        states = []
        for i in range(2):
            p_i = jax.tree.map(lambda t: t[i], p_period["rec"])
            x, st = rec_block(p_i, x, cfg)
            states.append(st)
        x, (k, v) = attn_layer(p_period["attn"], x, cfg, chunk)
        conv = jnp.stack([st[0] for st in states])
        h = jnp.stack([st[1] for st in states])
        if cfg.window and s > w:
            pos = jnp.arange(s - w, s)
            order = jnp.argsort(pos % w)
            k = k[:, s - w:][:, order]
            v = v[:, s - w:][:, order]
        return x, (conv, h, k, v)

    x, (convs, hs, ks, vs) = lax.scan(body, x, params["periods"])

    if params["tail"]:
        def tbody(x, p_rec):
            x, st = rec_block(p_rec, x, cfg)
            return x, st
        x, (tconv, th) = lax.scan(tbody, x, params["tail"])
    else:
        tconv = jnp.zeros((1, b, 3, cfg.lru_width or cfg.d_model),
                          cfg.jnp_dtype)
        th = jnp.zeros((1, b, cfg.lru_width or cfg.d_model), jnp.float32)

    target = min(cache_len, cfg.window) if (cache_len and cfg.window) \
        else cache_len
    if target and target > ks.shape[2]:
        pad = ((0, 0), (0, 0), (0, target - ks.shape[2]), (0, 0), (0, 0))
        ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
    cache = {"conv": convs, "h": hs, "k": ks, "v": vs,
             "tconv": tconv, "th": th}
    x = L.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return L.unembed(x[:, 0], params["lm_head"]), cache


def decode_step(params, cfg: ModelConfig, cache, token, pos, *,
                seq_shard_axis=None):
    x = L.embed_tokens(params["embed"], token[:, None]).astype(cfg.jnp_dtype)
    w = cfg.window
    slot = pos % w if w else pos
    kv_len = jnp.minimum(pos + 1, w) if w else pos + 1

    def body(x, inp):
        p_period, conv, h, k_l, v_l = inp
        new_conv, new_h = [], []
        for i in range(2):
            p_i = jax.tree.map(lambda t: t[i], p_period["rec"])
            x, (c_i, h_i) = rec_block(p_i, x, cfg,
                                      (conv[i], h[i]))
            new_conv.append(c_i)
            new_h.append(h_i)
        # local attention decode
        p_a = p_period["attn"]
        normed = L.rms_norm(x, p_a["attn_norm"], cfg.norm_eps)
        q, k_new, v_new = L.qkv_proj(p_a["attn"], normed, cfg)
        q = L.rope(q, pos[:, None], cfg.rope_theta)
        k_new = L.rope(k_new, pos[:, None], cfg.rope_theta)
        k_l, v_l = L.update_cache(k_l, v_l, k_new[:, 0], v_new[:, 0], slot)
        from repro.models.transformer import _cached_attention
        o = _cached_attention(q[:, 0], k_l, v_l, kv_len, cfg, seq_shard_axis)
        x = x + L.out_proj(p_a["attn"], o[:, None], o.dtype)
        normed = L.rms_norm(x, p_a["mlp_norm"], cfg.norm_eps)
        x = x + L.mlp_block(p_a["mlp"], normed)
        return x, (jnp.stack(new_conv), jnp.stack(new_h), k_l, v_l)

    x, (convs, hs, ks, vs) = lax.scan(
        body, x, (params["periods"], cache["conv"], cache["h"],
                  cache["k"], cache["v"]))

    if params["tail"]:
        def tbody(x, inp):
            p_rec, tc, th_ = inp
            x, (c, h) = rec_block(p_rec, x, cfg, (tc, th_))
            return x, (c, h)
        x, (tconv, th) = lax.scan(tbody, x, (params["tail"], cache["tconv"],
                                             cache["th"]))
    else:
        tconv, th = cache["tconv"], cache["th"]

    new_cache = {"conv": convs, "h": hs, "k": ks, "v": vs,
                 "tconv": tconv, "th": th}
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(x[:, 0], params["lm_head"]), new_cache
