"""Architecture registry — one uniform API over all model families.

Every family module exposes:
    init(cfg, key) -> (params, logical_axes)
    loss_fn(params, cfg, batch) -> scalar        (training)
    prefill(params, cfg, prompt) -> (logits, cache)
    decode_step(params, cfg, cache, token, pos, *, seq_shard_axis) -> ...
    cache_spec(cfg, batch, seq) -> (ShapeDtypeStruct tree, logical axes)

``batch_spec``/``prompt_spec`` build the ShapeDtypeStruct stand-ins for the
dry-run (no allocation) and the synthetic-data pipeline shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import moe, recurrentgemma, seamless, transformer, xlstm

_FAMILY = {
    "dense": transformer,
    "moe": moe,
    "xlstm": xlstm,
    "hybrid": recurrentgemma,
    "encdec": seamless,
}


def module_for(cfg: ModelConfig):
    return _FAMILY[cfg.family]


def init(cfg: ModelConfig, key):
    return module_for(cfg).init(cfg, key)


def loss_fn(params, cfg: ModelConfig, batch):
    return module_for(cfg).loss_fn(params, cfg, batch)


def prefill(params, cfg: ModelConfig, prompt, *, cache_len=None,
            length=None):
    return module_for(cfg).prefill(params, cfg, prompt,
                                   cache_len=cache_len, length=length)


def decode_step(params, cfg: ModelConfig, cache, token, pos, *,
                seq_shard_axis=None):
    return module_for(cfg).decode_step(params, cfg, cache, token, pos,
                                       seq_shard_axis=seq_shard_axis)


def cache_spec(cfg: ModelConfig, batch: int, seq: int):
    return module_for(cfg).cache_spec(cfg, batch, seq)


def init_cache(cfg: ModelConfig, batch: int, seq: int):
    return module_for(cfg).init_cache(cfg, batch, seq)


def pad_prefill_ok(cfg: ModelConfig) -> bool:
    """True when this family's prefill is exact under right-padding — the
    serving engine buckets prompt lengths to powers of two only then."""
    return bool(getattr(module_for(cfg), "PAD_PREFILL", False))


def paged_ok(cfg: ModelConfig) -> bool:
    """True when this arch can serve from a paged KV pool: the family
    declares ``PAGED_OK`` (positional K/V, slot-independent decode, exact
    recompute preemption) AND the arch has no rolling window (a windowed
    cache is already bounded and its pos%window layout does not page)."""
    return (bool(getattr(module_for(cfg), "PAGED_OK", False))
            and not cfg.window)


def paged_cache_spec(cfg: ModelConfig, num_pages: int, page_size: int):
    return module_for(cfg).paged_cache_spec(cfg, num_pages, page_size)


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int):
    return module_for(cfg).init_paged_cache(cfg, num_pages, page_size)


def decode_step_paged(params, cfg: ModelConfig, pool, page_table, token,
                      pos, *, seq_shard_axis=None, write_mask=None):
    return module_for(cfg).decode_step_paged(
        params, cfg, pool, page_table, token, pos,
        seq_shard_axis=seq_shard_axis, write_mask=write_mask)


def decode_cached(params, cfg: ModelConfig, cache, token, pos, *,
                  page_table=None, seq_shard_axis=None, write_mask=None):
    """One decode step against either cache layout — the single decode
    surface the serving ``CacheManager`` implementations dispatch through:
    ``page_table=None`` selects the contiguous per-slot pool,
    a ``[B, pages_per_slot]`` table selects the paged block pool.
    ``write_mask`` (paged only) routes masked rows' K/V writes to the trap
    page — the speculative-decoding verify path."""
    if page_table is None:
        if write_mask is not None:
            raise ValueError("write_mask requires the paged cache layout "
                             "(the contiguous pool has no trap page)")
        return decode_step(params, cfg, cache, token, pos,
                           seq_shard_axis=seq_shard_axis)
    return decode_step_paged(params, cfg, cache, page_table, token, pos,
                             seq_shard_axis=seq_shard_axis,
                             write_mask=write_mask)


def write_cached(cfg: ModelConfig, cache, new, *, slot=None, pages=None,
                 max_seq=None, page_size=None):
    """Scatter one request's prefill cache into either layout — the single
    write surface behind ``CacheManager.write``: pass ``slot`` (+
    ``max_seq``) for the contiguous pool or ``pages`` (+ ``page_size``)
    for the paged pool. Exactly one of ``slot``/``pages`` must be given."""
    if (slot is None) == (pages is None):
        raise ValueError("write_cached wants exactly one of slot= / pages=")
    if pages is not None:
        return write_pages(cfg, cache, new, pages, page_size)
    return write_slot(cfg, cache, new, slot, max_seq)


def write_slot(cfg: ModelConfig, pool, new, slot, max_seq: int):
    """Scatter one request's prefill cache (batch=1) into pool slot ``slot``.

    The batch/seq axes differ per family (xLSTM stacks states as
    [periods, stack, batch, ...]; Griffin mixes KV and recurrent leaves), so
    the scatter is driven by the logical axes from ``cache_spec`` — each
    leaf is written along its "batch" axis and clipped along "kv_seq" to
    the pool's sequence capacity. ``slot`` may be a traced scalar, so one
    jitted admission function serves every slot. (The historical engine
    hardcoded axis 1, silently corrupting xLSTM/Griffin recurrent state on
    slot scatter.)"""
    _, axes = cache_spec(cfg, 1, max_seq)
    is_ax = lambda x: isinstance(x, tuple)
    pool_leaves, treedef = jax.tree.flatten(pool)
    new_leaves = jax.tree.leaves(new)
    ax_leaves = jax.tree.leaves(axes, is_leaf=is_ax)
    out = []
    for p, n, ax in zip(pool_leaves, new_leaves, ax_leaves):
        ba = ax.index("batch")
        if "kv_seq" in ax:
            sa = ax.index("kv_seq")
            cap, s = p.shape[sa], n.shape[sa]
            if s > cap:  # rolling-window prefill keeps the last `cap`
                n = jax.lax.slice_in_dim(n, s - cap, s, axis=sa)
        starts = [0] * p.ndim
        starts[ba] = jnp.asarray(slot, jnp.int32)
        out.append(jax.lax.dynamic_update_slice(
            p, n.astype(p.dtype), tuple(starts)))
    return jax.tree.unflatten(treedef, out)


def read_pages(cfg: ModelConfig, pool, pages, page_size: int):
    """Gather whole pages out of the paged pool back into prefill layout
    (``[..., batch=1, n*page_size, ...]`` per leaf) — the exact inverse of
    ``write_pages``. The serving engine's swap-preemption path reads a
    victim's pages to host with this and later writes the same bytes back
    through ``write_pages``, so a preempted request's logical cache is
    restored bit-for-bit."""
    _, axes = cache_spec(cfg, 1, page_size)
    is_ax = lambda x: isinstance(x, tuple)
    pool_leaves, treedef = jax.tree.flatten(pool)
    ax_leaves = jax.tree.leaves(axes, is_leaf=is_ax)
    out = []
    for p, ax in zip(pool_leaves, ax_leaves):
        ba, sa = ax.index("batch"), ax.index("kv_seq")
        if sa != ba + 1:
            raise ValueError(f"paged layout needs adjacent (batch, kv_seq) "
                             f"axes, got {ax}")
        g = jnp.moveaxis(jnp.moveaxis(p, ba, 0)[pages], 0, ba)
        g = g.reshape(g.shape[:ba] + (-1,) + g.shape[ba + 2:])
        out.append(jnp.expand_dims(g, ba))
    return jax.tree.unflatten(treedef, out)


def write_pages(cfg: ModelConfig, pool, new, pages, page_size: int):
    """Scatter one request's prefill cache (batch=1) into whole pool pages.

    ``pool`` is the paged block pool from ``init_paged_cache`` (the
    contiguous cache's adjacent (batch, kv_seq) axes become the global
    (pages, page) axes); ``pages`` is a ``[n]`` int32 vector of physical
    page destinations for the prompt's logical pages 0..n-1.  Like
    ``write_slot``, the scatter is axes-driven off ``cache_spec``'s logical
    axes, so it holds for any paged family layout.  ``pages`` may be a
    traced vector: one jitted admission function serves every allocation
    pattern of a given prompt bucket.  Entries in ``pages`` may repeat the
    engine's trap page (bucket tail past the allocated prefix); duplicate
    destinations only ever carry masked pad garbage."""
    _, axes = cache_spec(cfg, 1, page_size)
    is_ax = lambda x: isinstance(x, tuple)
    pool_leaves, treedef = jax.tree.flatten(pool)
    new_leaves = jax.tree.leaves(new)
    ax_leaves = jax.tree.leaves(axes, is_leaf=is_ax)
    n_pages = pages.shape[0]
    target = n_pages * page_size
    out = []
    for p, n, ax in zip(pool_leaves, new_leaves, ax_leaves):
        ba, sa = ax.index("batch"), ax.index("kv_seq")
        if sa != ba + 1:
            raise ValueError(f"paged layout needs adjacent (batch, kv_seq) "
                             f"axes, got {ax}")
        n = jnp.squeeze(n, axis=ba)              # batch=1 -> seq at axis ba
        s = n.shape[ba]
        if s < target:
            pad = [(0, 0)] * n.ndim
            pad[ba] = (0, target - s)
            n = jnp.pad(n, pad)
        elif s > target:
            n = jax.lax.slice_in_dim(n, 0, target, axis=ba)
        n = n.reshape(n.shape[:ba] + (n_pages, page_size) + n.shape[ba + 1:])
        pm = jnp.moveaxis(p, ba, 0)              # pages axis leading
        nm = jnp.moveaxis(n, ba, 0)
        pm = pm.at[pages].set(nm.astype(p.dtype))
        out.append(jnp.moveaxis(pm, 0, ba))
    return jax.tree.unflatten(treedef, out)


def prefix_cache_ok(cfg: ModelConfig) -> bool:
    """True when this arch can reuse radix-cached prefix pages: it must
    serve paged (``paged_ok``), take token-id prompts (frame frontends
    have no hashable token chunks), and implement ``prefill_suffix``."""
    return (paged_ok(cfg) and cfg.frontend != "frames"
            and hasattr(module_for(cfg), "prefill_suffix"))


def prefill_suffix(params, cfg: ModelConfig, tokens, prefix, *,
                   prefix_len, length=None):
    """Prefill only a prompt's suffix against gathered prefix KV rows —
    the radix-prefix-hit admission path. See the family module."""
    return module_for(cfg).prefill_suffix(params, cfg, tokens, prefix,
                                          prefix_len=prefix_len,
                                          length=length)


def copy_pages(cfg: ModelConfig, pool, src, dst, page_size: int):
    """Device-side whole-page duplication (copy-on-write): copy physical
    page ``src`` into ``dst`` on every cache leaf. Axes-driven like
    ``write_pages`` — the pool's pages axis sits where the contiguous
    spec's batch axis was."""
    _, axes = cache_spec(cfg, 1, page_size)
    is_ax = lambda x: isinstance(x, tuple)
    pool_leaves, treedef = jax.tree.flatten(pool)
    ax_leaves = jax.tree.leaves(axes, is_leaf=is_ax)
    out = []
    for p, ax in zip(pool_leaves, ax_leaves):
        ba = ax.index("batch")
        pm = jnp.moveaxis(p, ba, 0)
        pm = pm.at[dst].set(pm[src])
        out.append(jnp.moveaxis(pm, 0, ba))
    return jax.tree.unflatten(treedef, out)


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStructs; the dry-run's only "data")
# --------------------------------------------------------------------------

def batch_spec(cfg: ModelConfig, batch: int, seq: int):
    """Training batch ShapeDtypeStructs + logical shard axes."""
    i32 = jnp.int32
    if cfg.frontend == "frames":
        spec = {"frames": jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                               cfg.jnp_dtype),
                "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
                "labels": jax.ShapeDtypeStruct((batch, seq), i32)}
        axes = {"frames": ("batch", None, None), "tokens": ("batch", None),
                "labels": ("batch", None)}
    else:
        spec = {"tokens": jax.ShapeDtypeStruct((batch, seq), i32),
                "labels": jax.ShapeDtypeStruct((batch, seq), i32)}
        axes = {"tokens": ("batch", None), "labels": ("batch", None)}
    return spec, axes


def prompt_spec(cfg: ModelConfig, batch: int, seq: int):
    """Prefill prompt ShapeDtypeStructs + logical axes."""
    if cfg.frontend == "frames":
        return (jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                     cfg.jnp_dtype),
                ("batch", None, None))
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32), ("batch", None)


def make_batch(cfg: ModelConfig, batch: int, seq: int, key):
    """Synthetic concrete batch (smoke tests / examples)."""
    k1, k2 = jax.random.split(key)
    tokens = jax.random.randint(k1, (batch, seq), 0, cfg.vocab)
    out = {"tokens": tokens,
           "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.frontend == "frames":
        out["frames"] = jax.random.normal(k2, (batch, seq, cfg.d_model),
                                          cfg.jnp_dtype)
    return out


def train_batch_arg(cfg: ModelConfig, batch):
    """The positional arg loss_fn expects (tokens-only families ignore
    frames)."""
    return batch
