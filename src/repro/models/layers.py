"""Shared model layers — all architectures are built from these.

Every layer calls the kernel package through ``repro.kernels.ops`` (never a
Pallas kernel directly): that is the "SGLang role" — the framework consumes
whatever variant Astra last reintegrated. On the CPU dry-run host the ops
dispatch to the pure-jnp references, which lower/differentiate cleanly; on
a TPU backend the serving paths pick up the Pallas kernels.

Training attention is an online-softmax scan over KV chunks (FlashAttention
schedule in pure jnp): activation memory O(seq x chunk) instead of
O(seq^2), which is what makes the 32k-prefill cells lowerable. Causal
masking is applied inside each chunk; fully-masked chunks still execute
(SPMD cannot skip) — the §Roofline "useful-FLOPs ratio" accounts for this
and the TPU-target Pallas path (splash-style skipping) is costed there.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.sharding import tp


# ---------------------------------------------------------------------------
# initialization helpers: params + logical-axes trees are built together
# ---------------------------------------------------------------------------

def dense_init(key, shape, axes, scale=None, dtype=jnp.float32):
    """(array, logical_axes) — truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) > 1 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            .astype(dtype) * scale, axes)


def ones_init(shape, axes, dtype=jnp.float32):
    return jnp.ones(shape, dtype), axes


def zeros_init(shape, axes, dtype=jnp.float32):
    return jnp.zeros(shape, dtype), axes


def split_tree(pairs: dict):
    """{name: (array, axes)} -> (params dict, axes dict)."""
    params, axes = {}, {}
    for k, v in pairs.items():
        if isinstance(v, dict):
            params[k], axes[k] = split_tree(v)
        else:
            params[k], axes[k] = v
    return params, axes


# ---------------------------------------------------------------------------
# norms / rope / embeddings
# ---------------------------------------------------------------------------

def _current_mesh():
    from jax.interpreters import pxla
    m = pxla.thread_resources.env.physical_mesh
    return None if m.empty else m


def shard_batch(x, n_batch_dims: int = 1):
    """Constrain the leading dim(s) of an activation to the batch mesh axes.

    GSPMD's propagation, given 2-D-sharded FSDP weights, often prefers to
    keep weights sharded and REPLICATE activations over the data axis —
    catastrophic at batch 256 x 4k. Pinning activations batch-sharded at
    block boundaries makes the solver insert the per-layer weight
    all-gathers instead (the FSDP pattern). No-op off-mesh (smoke tests).
    """
    mesh = _current_mesh()
    if mesh is None:
        return x
    axes = tuple(n for n in ("pod", "data") if n in mesh.axis_names)
    if not axes:
        return x
    import numpy as np
    size = int(np.prod([mesh.shape[a] for a in axes]))
    if x.shape[0] % size != 0:
        axes = ("data",) if "data" in mesh.axis_names \
            and x.shape[0] % mesh.shape["data"] == 0 else ()
        if not axes:
            return x
    lead = axes if len(axes) > 1 else axes[0]
    spec = jax.sharding.PartitionSpec(
        lead, *([None] * (x.ndim - 1)))
    return lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


def rms_norm(x, w, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def add_rms_norm(x, residual, w, eps=1e-6):
    """Fused residual-add + RMSNorm — paper Kernel 2 via ops dispatch."""
    return ops.fused_add_rmsnorm(x, residual, w, eps)


def rope(x, positions, theta=10000.0):
    """Rotary embedding. x: [..., seq, heads, head_dim], positions: [..., seq]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [.., S, half]
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def embed_tokens(embedding, tokens):
    return jnp.take(embedding, tokens, axis=0)


def unembed(x, lm_head):
    # vocab-sharded under an active serving TP plan: the local partial
    # covers a contiguous vocab slice, all-gathered back to full order
    return tp.gather_vocab(
        jnp.einsum("...d,dv->...v", x, lm_head.astype(x.dtype)))


def ce_loss(logits, labels, vocab: int):
    """Vocab-shard-friendly cross-entropy.

    ``take_along_axis`` over a model-sharded vocab axis forces GSPMD to
    all-gather the logits ([B,S,V] fp32 — gigabytes); selecting via an
    iota==label mask keeps every op elementwise/reduce, so the vocab axis
    stays sharded and the reduce lowers to a psum. (§Perf iteration 1.)
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    sel = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                   logits.ndim - 1) == labels[..., None]
    gold = jnp.sum(jnp.where(sel, logits, 0.0), axis=-1)
    mask = (labels >= 0) & (labels < vocab)
    nll = jnp.where(mask, logz - gold, 0.0)
    return nll.sum() / jnp.maximum(mask.sum(), 1)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def qkv_proj(p, x, cfg: ModelConfig):
    """x: [B, S, D] -> q [B,S,Hq,dh], k/v [B,S,Hkv,dh].

    Projection weights keep the head axis EXPLICIT ([D, H, dh]) so the
    sharding rules can only shard whole heads over the model axis — a
    flattened [D, H*dh] output dim lets GSPMD split head_dim itself, which
    turns every attention contraction into a partial-sum all-reduce of the
    score tensor (§Perf iteration 4).
    """
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def out_proj(p, o, dtype):
    """o: [B, S, Hq, dh] -> [B, S, D] via wo [Hq, dh, D].

    Under an active serving TP plan the incoming heads are a local
    shard; they are all-gathered (concatenated, no partial sums) before
    the replicated ``wo`` contraction so the result stays bit-identical
    to the single-device einsum."""
    o = tp.gather_heads(o)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dtype))


def shard_attention(q, k, v):
    """Pick the attention parallelism per arch (§Perf iteration 5).

    * heads divide the model axis -> tensor-parallel heads (classic TP);
    * otherwise -> CONTEXT parallelism: shard q (and the output) along the
      sequence axis over the model axis; K/V stay replicated and stream
      through every chip's flash scan. Without this, archs whose head count
      doesn't divide the mesh (qwen2: 14, yi: 56) recompute full attention
      on all 16 model-axis chips.
    """
    mesh = _current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return q, k, v
    import numpy as np
    m = mesh.shape["model"]
    P, NS = jax.sharding.PartitionSpec, jax.sharding.NamedSharding
    batch = tuple(n for n in ("pod", "data") if n in mesh.axis_names)
    if q.shape[0] % int(np.prod([mesh.shape[a] for a in batch])):
        batch = ()
    b_ax = (batch if len(batch) > 1 else (batch[0] if batch else None))
    hq, hkv = q.shape[2], k.shape[2]
    if hq % m == 0 and hkv % m == 0:
        spec = P(b_ax, None, "model", None)
        return (lax.with_sharding_constraint(q, NS(mesh, spec)),
                lax.with_sharding_constraint(k, NS(mesh, spec)),
                lax.with_sharding_constraint(v, NS(mesh, spec)))
    if q.shape[1] % m == 0:
        qs = lax.with_sharding_constraint(
            q, NS(mesh, P(b_ax, "model", None, None)))
        kv = P(b_ax, None, None, None)
        return (qs, lax.with_sharding_constraint(k, NS(mesh, kv)),
                lax.with_sharding_constraint(v, NS(mesh, kv)))
    return q, k, v


def attn_params(key, cfg: ModelConfig, dtype):
    dh, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    pairs = {
        "wq": dense_init(ks[0], (d, hq, dh), ("embed", "heads", "head_dim"),
                         scale=d ** -0.5, dtype=dtype),
        "wk": dense_init(ks[1], (d, hkv, dh),
                         ("embed", "kv_heads", "head_dim"),
                         scale=d ** -0.5, dtype=dtype),
        "wv": dense_init(ks[2], (d, hkv, dh),
                         ("embed", "kv_heads", "head_dim"),
                         scale=d ** -0.5, dtype=dtype),
        "wo": dense_init(ks[3], (hq, dh, d), ("heads", "head_dim", "embed"),
                         scale=(hq * dh) ** -0.5, dtype=dtype),
    }
    if cfg.qkv_bias:
        pairs["bq"] = zeros_init((hq, dh), ("heads", "head_dim"), dtype)
        pairs["bk"] = zeros_init((hkv, dh), ("kv_heads", "head_dim"), dtype)
        pairs["bv"] = zeros_init((hkv, dh), ("kv_heads", "head_dim"), dtype)
    if cfg.qk_norm:
        pairs["q_norm"] = ones_init((dh,), ("head_dim",))
        pairs["k_norm"] = ones_init((dh,), ("head_dim",))
    return pairs


def chunked_attention(q, k, v, *, causal=True, window=None, chunk=512,
                      q_offset=0, kv_len=None, cross=False):
    """Online-softmax attention, scanned over KV chunks.

    q: [B, Sq, Hq, dh]; k, v: [B, Skv, Hkv, dh]. GQA via head grouping.
    ``q_offset``: absolute position of q[0] (prefill continuation).
    ``kv_len``: [B] valid KV length (decode with padded caches).
    ``cross``: no causal mask (encoder-decoder cross attention).
    Returns [B, Sq, Hq, dh].
    """
    b, sq, hq, dh = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = dh ** -0.5
    chunk = min(chunk, skv)
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, dh) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    q_pos = q_offset + jnp.arange(sq)

    def body(carry, idx):
        m, l, acc = carry
        ks = lax.dynamic_slice_in_dim(kf, idx * chunk, chunk, axis=1)
        vs = lax.dynamic_slice_in_dim(vf, idx * chunk, chunk, axis=1)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, ks)
        k_pos = idx * chunk + jnp.arange(chunk)
        mask = jnp.ones((sq, chunk), bool) if cross else \
            (k_pos[None, :] <= q_pos[:, None])
        if window is not None and not cross:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        mask = jnp.broadcast_to(mask[None], (b, sq, chunk))
        if kv_len is not None:
            mask &= (k_pos[None, None, :] < kv_len[:, None, None])
        s = jnp.where(mask[:, None, None, :, :], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd",
                                                      p, vs)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, dh), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), jnp.arange(n_chunks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.swapaxes(out, 2, 3).reshape(b, hkv, sq, g * dh)  # merge heads
    out = jnp.swapaxes(out, 1, 2).reshape(b, sq, hq * dh)
    return out.astype(q.dtype).reshape(b, sq, hq, dh)


# ---------------------------------------------------------------------------
# flash attention with recomputing backward (custom VJP)
#
# The naive scan saves every chunk's probability matrix as a residual —
# stacked [n_chunks, B, Hkv, G, Sq, chunk] fp32 buffers that dominate HBM
# traffic and temp memory (§Perf iteration 3). FlashAttention's backward
# recomputes p = exp(qk - lse) per chunk instead. Everything runs under
# jax.named_scope("flash_kernel"): on the TPU target this region IS one
# fused Pallas kernel (interior tensors live in VMEM), and the roofline
# parser costs the region analytically (see roofline/hlo_parser.py).
# ---------------------------------------------------------------------------

def _flash_mask(q_pos, k_pos, *, causal, window):
    mask = None
    if causal:
        mask = k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
    return mask


def _flash_fwd_scan(qf, kf, vf, *, causal, window, chunk):
    """qf: [B,Hkv,G,Sq,D] pre-scaled fp32; kf/vf: [B,Skv,Hkv,D] fp32.
    Returns (acc, m, l)."""
    b, hkv, g, sq, dh = qf.shape
    skv = kf.shape[1]
    n_chunks = skv // chunk
    q_pos = jnp.arange(sq)

    def body(carry, idx):
        m, l, acc = carry
        ks = lax.dynamic_slice_in_dim(kf, idx * chunk, chunk, axis=1)
        vs = lax.dynamic_slice_in_dim(vf, idx * chunk, chunk, axis=1)
        s = jnp.einsum("bhgqd,bkhd->bhgqk", qf, ks)
        mask = _flash_mask(q_pos, idx * chunk + jnp.arange(chunk),
                           causal=causal, window=window)
        if mask is not None:
            s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vs)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, dh), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), jnp.arange(n_chunks))
    return acc, m, l


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal=True, window=None, chunk=512,
                    cross=False):
    """Memory-efficient attention. q: [B,Sq,Hq,D], k/v: [B,Skv,Hkv,D]."""
    out, _ = _flash_forward(q, k, v, causal, window, chunk, cross)
    return out


def _prep(q, k, v, chunk):
    b, sq, hq, dh = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    chunk = min(chunk, skv)
    pad = (-skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, dh) * (dh ** -0.5)
    qf = qf.transpose(0, 2, 3, 1, 4)                      # [B,Hkv,G,Sq,D]
    return qf, k.astype(jnp.float32), v.astype(jnp.float32), chunk, pad


def _unprep(acc, b, sq, hq, dh):
    # [B,Hkv,G,Sq,D] -> [B,Sq,Hq,D]
    return acc.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, dh)


def _flash_forward(q, k, v, causal, window, chunk, cross):
    with jax.named_scope("flash_kernel"):
        b, sq, hq, dh = q.shape
        qf, kf, vf, chunk, _ = _prep(q, k, v, chunk)
        acc, m, l = _flash_fwd_scan(qf, kf, vf,
                                    causal=causal and not cross,
                                    window=window if not cross else None,
                                    chunk=chunk)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        out = _unprep(acc / jnp.maximum(l, 1e-30)[..., None],
                      b, sq, hq, dh).astype(q.dtype)
    return out, (q, k, v, out, lse)


def _flash_backward(causal, window, chunk, cross, res, dout):
    q, k, v, out, lse = res
    with jax.named_scope("flash_kernel"):
        b, sq, hq, dh = q.shape
        skv = k.shape[1]
        hkv = k.shape[2]
        g = hq // hkv
        qf, kf, vf, chunk, pad = _prep(q, k, v, chunk)
        dof = dout.astype(jnp.float32).reshape(b, sq, hkv, g, dh) \
            .transpose(0, 2, 3, 1, 4)                     # [B,Hkv,G,Sq,D]
        of = out.astype(jnp.float32).reshape(b, sq, hkv, g, dh) \
            .transpose(0, 2, 3, 1, 4)
        delta = jnp.sum(dof * of, axis=-1)                # [B,Hkv,G,Sq]
        is_causal = causal and not cross
        win = window if not cross else None
        q_pos = jnp.arange(sq)
        n_chunks = kf.shape[1] // chunk

        def body(dq, idx):
            ks = lax.dynamic_slice_in_dim(kf, idx * chunk, chunk, axis=1)
            vs = lax.dynamic_slice_in_dim(vf, idx * chunk, chunk, axis=1)
            s = jnp.einsum("bhgqd,bkhd->bhgqk", qf, ks)
            mask = _flash_mask(q_pos, idx * chunk + jnp.arange(chunk),
                               causal=is_causal, window=win)
            if mask is not None:
                s = jnp.where(mask[None, None, None], s, -1e30)
            p = jnp.exp(s - lse[..., None])               # recomputed
            dp = jnp.einsum("bhgqd,bkhd->bhgqk", dof, vs)
            ds = p * (dp - delta[..., None])
            dq = dq + jnp.einsum("bhgqk,bkhd->bhgqd", ds, ks)
            dk_j = jnp.einsum("bhgqk,bhgqd->bkhd", ds, qf)
            dv_j = jnp.einsum("bhgqk,bhgqd->bkhd", p, dof)
            return dq, (dk_j, dv_j)

        dq0 = jnp.zeros_like(qf)
        dqf, (dks, dvs) = lax.scan(body, dq0, jnp.arange(n_chunks))
        dq = _unprep(dqf * (dh ** -0.5), b, sq, hq, dh).astype(q.dtype)
        dk = jnp.moveaxis(dks, 0, 1).reshape(b, n_chunks * chunk, hkv, dh)
        dv = jnp.moveaxis(dvs, 0, 1).reshape(b, n_chunks * chunk, hkv, dh)
        if pad:
            dk, dv = dk[:, :skv], dv[:, :skv]
        return dq, dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(
    lambda q, k, v, causal, window, chunk, cross:
        _flash_forward(q, k, v, causal, window, chunk, cross),
    _flash_backward)


def attention_block(p, x, cfg: ModelConfig, *, positions=None, chunk=512):
    """Full-sequence (training/prefill) self-attention sublayer body."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = qkv_proj(p, x, cfg)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q, k, v = shard_attention(q, k, v)
    o = flash_attention(q, k, v, True, cfg.window, chunk, False)
    return out_proj(p, o, x.dtype), (k, v)


def prefix_attention(q, k_new, v_new, k_prefix, v_prefix, prefix_len):
    """Suffix-prefill attention over a two-segment KV: cached prefix rows
    followed by the suffix's own keys/values.

    q, k_new, v_new: [B, S, H*, dh] — the suffix (right-padded to its
    bucket); k_prefix, v_prefix: [B, P, Hkv, dh] — prefix rows gathered
    from the paged pool, of which only the first ``prefix_len`` (traced
    i32) are valid; the tail is trap-page garbage and must be masked, which
    is why ``chunked_attention``'s single ``kv_len`` cut-off cannot express
    this layout. Causality is over *absolute* positions: suffix query i
    sits at ``prefix_len + i`` and sees the valid prefix plus suffix keys
    ``<= i``.
    """
    b, s, hq, dh = q.shape
    hkv = k_new.shape[2]
    g = hq // hkv
    p_rows = k_prefix.shape[1]
    k = jnp.concatenate([k_prefix, k_new], 1).astype(jnp.float32)
    v = jnp.concatenate([v_prefix, v_new], 1).astype(jnp.float32)
    qf = q.astype(jnp.float32).reshape(b, s, hkv, g, dh) * dh ** -0.5
    sc = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k)
    kpos = jnp.arange(p_rows + s)
    qpos = jnp.asarray(prefix_len, jnp.int32) + jnp.arange(s)
    valid = (kpos < prefix_len) | (kpos >= p_rows)
    pos_of_k = jnp.where(kpos < p_rows, kpos, prefix_len + (kpos - p_rows))
    mask = valid[None, :] & (pos_of_k[None, :] <= qpos[:, None])
    sc = jnp.where(mask[None, None, None], sc, -1e30)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", jax.nn.softmax(sc, -1), v)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, s, hq, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU) — paper Kernel 3 consumer
# ---------------------------------------------------------------------------

def mlp_params(key, cfg: ModelConfig, dtype, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    k1, k2 = jax.random.split(key)
    return {
        "w_gateup": dense_init(k1, (cfg.d_model, 2 * d_ff),
                               ("embed", "mlp"), dtype=dtype),
        "w_down": dense_init(k2, (d_ff, cfg.d_model),
                             ("mlp", "embed"), dtype=dtype),
    }


def mlp_block(p, x):
    """SwiGLU: gate/up fused matmul -> silu_and_mul kernel -> down proj.

    Under an active serving TP plan ``w_gateup`` columns are sharded
    (pre-permuted so each shard holds its own gate/up pair, see
    ``sharding.tp.gateup_permutation``); the local ``silu_and_mul``
    outputs are all-gathered before the replicated down projection."""
    h = jnp.einsum("bsd,df->bsf", x, p["w_gateup"].astype(x.dtype))
    h = ops.silu_and_mul(h)
    h = tp.gather_mlp(h)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))


# ---------------------------------------------------------------------------
# decode-time attention over a KV cache (single new token)
# ---------------------------------------------------------------------------

def decode_attention(p, x, cache_k, cache_v, pos, cfg: ModelConfig, *,
                     kv_len=None, seq_shard_axis=None):
    """One-token decode self-attention.

    x: [B, 1, D]; cache_k/v: [B, S, Hkv, dh] (already containing this
    token's k/v at position ``pos``); pos: [B] absolute positions.
    When ``seq_shard_axis`` is set (inside shard_map), the KV cache is
    sequence-sharded: each shard computes a partial (V, LSE) with the flash
    decode kernel and partials merge with the Kernel-1 LSE math via
    collectives — the distributed form of merge_attn_states_lse.
    """
    b = x.shape[0]
    q, k_new, v_new = qkv_proj(p, x, cfg)          # [B,1,H,dh]
    q = rope(q, pos[:, None], cfg.rope_theta)
    q = q[:, 0]                                    # [B,Hq,dh]
    if kv_len is None:
        kv_len = pos + 1

    if seq_shard_axis is None:
        o = ops.flash_decode_attention(q, cache_k, cache_v, kv_len=kv_len)
    else:
        # split-KV across devices: local partial + distributed LSE merge
        axis = seq_shard_axis
        idx = lax.axis_index(axis)
        shard = cache_k.shape[1]
        local_len = jnp.clip(kv_len - idx * shard, 0, shard)
        o_part, lse = ops.flash_decode_attention(
            q, cache_k, cache_v, kv_len=local_len, return_lse=True)
        o_part = jnp.where(jnp.isneginf(lse)[..., None], 0.0,
                           o_part.astype(jnp.float32))
        m = lax.pmax(lse, axis)
        m_safe = jnp.where(jnp.isneginf(m), 0.0, m)
        w = jnp.exp(lse - m_safe)
        w = jnp.where(jnp.isneginf(lse), 0.0, w)
        num = lax.psum(w[..., None] * o_part, axis)
        den = lax.psum(w, axis)
        o = (num / jnp.maximum(den, 1e-30)[..., None]).astype(x.dtype)

    o = o.reshape(b, 1, cfg.n_heads * cfg.head_dim)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(x.dtype))
    return out, (k_new[:, 0], v_new[:, 0])


def update_cache(cache_k, cache_v, k_new, v_new, pos):
    """Write one token's K/V at [b, pos[b]] via scatter.

    A one-hot multiply-add formulation reads+writes the ENTIRE cache
    (~6 cache round trips per layer per step); the scatter touches one row
    per sequence. GSPMD keeps the batch dim sharded and masks the
    (possibly sharded) sequence dim — decode_32k's memory term dropped
    ~8x with this (§Perf hillclimb C, EXPERIMENTS.md).
    """
    b = cache_k.shape[0]
    idx = jnp.arange(b)
    return (cache_k.at[idx, pos].set(k_new.astype(cache_k.dtype)),
            cache_v.at[idx, pos].set(v_new.astype(cache_v.dtype)))
