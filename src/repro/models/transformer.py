"""Dense decoder-only transformer (llama-family).

Covers qwen2-0.5b (GQA kv=2 + QKV bias), yi-34b, qwen3-8b (qk-norm),
h2o-danube-1.8b (sliding window), and the chameleon-34b backbone
(early-fusion VQ tokens are ordinary ids in the unified vocab).

Structure: scan-over-layers with stacked parameter pytrees (HLO depth
O(1)), per-layer remat, and the SGLang fused-add-RMSNorm residual pattern —
each block consumes paper Kernel 2 twice and Kernel 3 once, decode consumes
the flash-decode kernel whose combiner is paper Kernel 1.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding import tp

# Right-padding a prompt to a bucketed length is safe here: the cache is
# positional K/V and attention is causal, so pad positions can never
# influence positions < length, and decode's kv_len mask hides them until
# they are overwritten in place. The serving engine keys bucketed prefill
# admission on this flag.
PAD_PREFILL = True

# Paged-KV serving is exact here: the cache is positional K/V, decode is
# per-slot independent (no cross-request coupling in any op), and recompute
# preemption — re-prefilling prompt + generated prefix — reproduces the
# straight-through stream under greedy sampling. Families with recurrent
# state (xlstm/hybrid), cross-attention caches (encdec), or slot-coupled
# routing (moe capacity) keep the contiguous pool. Rolling-window archs
# (cfg.window) are excluded by ``registry.paged_ok``: their cache is already
# bounded and its pos%window layout does not page.
PAGED_OK = True


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init(cfg: ModelConfig, key) -> tuple[dict, dict]:
    """Returns (params, logical_axes). Layer params are stacked on axis 0."""
    keys = jax.random.split(key, 4)
    dtype = jnp.float32  # master weights; compute casts per-use

    def one_layer(k):
        ka, km = jax.random.split(k)
        pairs = {
            "attn": L.attn_params(ka, cfg, dtype),
            "mlp": L.mlp_params(km, cfg, dtype),
            "attn_norm": L.ones_init((cfg.d_model,), ("embed",)),
            "mlp_norm": L.ones_init((cfg.d_model,), ("embed",)),
        }
        return L.split_tree(pairs)

    layer_keys = jax.random.split(keys[0], cfg.n_layers)
    stacked = jax.vmap(lambda k: one_layer(k)[0])(layer_keys)
    _, axes_one = one_layer(layer_keys[0])
    layer_axes = jax.tree.map(lambda ax: ("layers",) + ax, axes_one,
                              is_leaf=lambda x: isinstance(x, tuple))

    emb, emb_ax = L.dense_init(keys[1], (cfg.padded_vocab, cfg.d_model),
                               ("embed_vocab", "mlp"), scale=1.0, dtype=dtype)
    head, head_ax = L.dense_init(keys[2], (cfg.d_model, cfg.padded_vocab),
                                 ("embed", "vocab"), dtype=dtype)
    fnorm, fnorm_ax = L.ones_init((cfg.d_model,), ("embed",))
    params = {"embed": emb, "layers": stacked, "final_norm": fnorm,
              "lm_head": head}
    axes = {"embed": emb_ax, "layers": layer_axes, "final_norm": fnorm_ax,
            "lm_head": head_ax}
    return params, axes


# --------------------------------------------------------------------------
# training / prefill forward
# --------------------------------------------------------------------------

def _block_train(p_layer, carry, cfg: ModelConfig, chunk: int):
    hidden, residual = carry
    hidden = L.shard_batch(hidden)
    residual = L.shard_batch(residual)
    normed, residual = L.add_rms_norm(hidden, residual,
                                      p_layer["attn_norm"], cfg.norm_eps)
    attn_out, _ = L.attention_block(p_layer["attn"], normed, cfg, chunk=chunk)
    normed, residual = L.add_rms_norm(attn_out, residual,
                                      p_layer["mlp_norm"], cfg.norm_eps)
    hidden = L.mlp_block(p_layer["mlp"], normed)
    return hidden, residual


def forward(params, cfg: ModelConfig, tokens, *, chunk: int = 512):
    """Teacher-forced logits [B, S, V_pad] (compute dtype = cfg.dtype)."""
    hidden = L.embed_tokens(params["embed"], tokens).astype(cfg.jnp_dtype)
    residual = jnp.zeros_like(hidden)

    block = jax.checkpoint(
        functools.partial(_block_train, cfg=cfg, chunk=chunk),
        policy=jax.checkpoint_policies.nothing_saveable)

    def body(carry, p_layer):
        return block(p_layer, carry), None

    (hidden, residual), _ = lax.scan(body, (hidden, residual),
                                     params["layers"])
    normed, _ = L.add_rms_norm(hidden, residual, params["final_norm"],
                               cfg.norm_eps)
    return L.unembed(normed, params["lm_head"])


def loss_fn(params, cfg: ModelConfig, batch, *, chunk: int = 512):
    """Next-token cross-entropy; batch = {"tokens", "labels"} of [B, S]."""
    logits = forward(params, cfg, batch["tokens"], chunk=chunk)
    return L.ce_loss(logits, batch["labels"], cfg.vocab)


# --------------------------------------------------------------------------
# serving: prefill + single-token decode over a KV cache
# --------------------------------------------------------------------------

def cache_spec(cfg: ModelConfig, batch: int, seq: int):
    """(ShapeDtypeStruct cache tree, logical axes). Sliding-window archs
    keep a rolling window-sized cache."""
    s = min(seq, cfg.window) if cfg.window else seq
    shape = (cfg.n_layers, batch, s, cfg.n_kv_heads, cfg.head_dim)
    axes = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    return ({"k": jax.ShapeDtypeStruct(shape, cfg.jnp_dtype),
             "v": jax.ShapeDtypeStruct(shape, cfg.jnp_dtype)},
            {"k": axes, "v": axes})


def init_cache(cfg: ModelConfig, batch: int, seq: int):
    spec, axes = cache_spec(cfg, batch, seq)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec), axes


def paged_cache_spec(cfg: ModelConfig, num_pages: int, page_size: int):
    """Paged pool layout: the contiguous cache's (batch, kv_seq) axes become
    one global (pages, page) block pool shared by every request."""
    if cfg.window:
        raise ValueError("rolling-window caches do not page "
                         "(registry.paged_ok gates on cfg.window)")
    shape = (cfg.n_layers, num_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
    axes = ("layers", "pages", "page", "kv_heads", "head_dim")
    return ({"k": jax.ShapeDtypeStruct(shape, cfg.jnp_dtype),
             "v": jax.ShapeDtypeStruct(shape, cfg.jnp_dtype)},
            {"k": axes, "v": axes})


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int):
    spec, axes = paged_cache_spec(cfg, num_pages, page_size)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec), axes


def _block_prefill(p_layer, carry, cfg: ModelConfig, chunk: int):
    hidden, residual = carry
    hidden = L.shard_batch(hidden)
    residual = L.shard_batch(residual)
    normed, residual = L.add_rms_norm(hidden, residual,
                                      p_layer["attn_norm"], cfg.norm_eps)
    attn_out, (k, v) = L.attention_block(p_layer["attn"], normed, cfg,
                                         chunk=chunk)
    normed, residual = L.add_rms_norm(attn_out, residual,
                                      p_layer["mlp_norm"], cfg.norm_eps)
    hidden = L.mlp_block(p_layer["mlp"], normed)
    return (hidden, residual), (k, v)


def prefill(params, cfg: ModelConfig, tokens, *, chunk: int = 512,
            cache_len: int | None = None, length=None):
    """Process the prompt; returns (last-position logits, filled cache).
    ``cache_len`` pre-sizes the cache for subsequent decode_steps.
    ``length`` (traced i32 scalar) marks the true prompt length when
    ``tokens`` is right-padded to a bucket: logits are taken at position
    ``length - 1`` instead of the (pad) last position."""
    b, s = tokens.shape
    hidden = L.embed_tokens(params["embed"], tokens).astype(cfg.jnp_dtype)
    residual = jnp.zeros_like(hidden)

    block = jax.checkpoint(
        functools.partial(_block_prefill, cfg=cfg, chunk=chunk),
        policy=jax.checkpoint_policies.nothing_saveable)

    def body(carry, p_layer):
        carry, kv = block(p_layer, carry)
        return carry, kv

    (hidden, residual), (ks, vs) = lax.scan(body, (hidden, residual),
                                            params["layers"])
    if cfg.window and s > cfg.window:
        # rolling cache keeps the last `window` positions, laid out at
        # slot = pos % window
        w = cfg.window
        pos = jnp.arange(s - w, s)
        slots = pos % w
        order = jnp.argsort(slots)
        ks = ks[:, :, s - w:][:, :, order]
        vs = vs[:, :, s - w:][:, :, order]
    target = min(cache_len, cfg.window) if (cache_len and cfg.window) \
        else cache_len
    if target and target > ks.shape[2]:
        pad = ((0, 0), (0, 0), (0, target - ks.shape[2]), (0, 0), (0, 0))
        ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
    cache = {"k": ks, "v": vs}
    h_last, r_last = _last_position(hidden, residual, length)
    normed, _ = L.add_rms_norm(h_last, r_last,
                               params["final_norm"], cfg.norm_eps)
    return L.unembed(normed[:, 0], params["lm_head"]), cache


def prefill_suffix(params, cfg: ModelConfig, tokens, prefix, *,
                   prefix_len, length=None):
    """Prefill only the *suffix* of a prompt whose first ``prefix_len``
    positions are already cached (radix prefix hit).

    ``tokens``: [1, S] suffix ids, right-padded to the suffix bucket.
    ``prefix``: {"k","v"} of [L, 1, P, Hkv, dh] — prefix rows gathered
    from the paged pool (``registry.read_pages``); only the first
    ``prefix_len`` rows are valid, the tail is trap garbage that
    ``prefix_attention`` masks. ``prefix_len`` and ``length`` (true suffix
    length) are traced i32 scalars, so the compile key is the pair of
    bucket shapes only. Returns (logits at suffix position ``length - 1``,
    suffix KV {"k","v"} [L, 1, S, Hkv, dh]) for the page scatter."""
    if cfg.window:
        raise ValueError("rolling-window caches do not serve from the "
                         "paged pool, so they never suffix-prefill")
    b, s = tokens.shape
    hidden = L.embed_tokens(params["embed"], tokens).astype(cfg.jnp_dtype)
    residual = jnp.zeros_like(hidden)
    positions = jnp.asarray(prefix_len, jnp.int32) + \
        jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(carry, xs):
        p_layer, pk, pv = xs
        hidden, residual = carry
        hidden = L.shard_batch(hidden)
        residual = L.shard_batch(residual)
        normed, residual = L.add_rms_norm(hidden, residual,
                                          p_layer["attn_norm"], cfg.norm_eps)
        q, k, v = L.qkv_proj(p_layer["attn"], normed, cfg)
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
        q, k, v = L.shard_attention(q, k, v)
        attn = L.prefix_attention(q, k, v, pk, pv, prefix_len)
        attn_out = L.out_proj(p_layer["attn"], attn, normed.dtype)
        normed, residual = L.add_rms_norm(attn_out, residual,
                                          p_layer["mlp_norm"], cfg.norm_eps)
        hidden = L.mlp_block(p_layer["mlp"], normed)
        return (hidden, residual), (k, v)

    (hidden, residual), (ks, vs) = lax.scan(
        body, (hidden, residual),
        (params["layers"], prefix["k"], prefix["v"]))
    h_last, r_last = _last_position(hidden, residual, length)
    normed, _ = L.add_rms_norm(h_last, r_last,
                               params["final_norm"], cfg.norm_eps)
    return L.unembed(normed[:, 0], params["lm_head"]), {"k": ks, "v": vs}


def _last_position(hidden, residual, length):
    """[B,1,D] slices of the final prompt position (``length-1`` when the
    prompt is right-padded, else the literal last position)."""
    if length is None:
        return hidden[:, -1:], residual[:, -1:]
    idx = jnp.asarray(length, jnp.int32) - 1
    return (lax.dynamic_slice_in_dim(hidden, idx, 1, axis=1),
            lax.dynamic_slice_in_dim(residual, idx, 1, axis=1))


def decode_step(params, cfg: ModelConfig, cache, token, pos, *,
                seq_shard_axis=None):
    """One decode step. token: [B] ids; pos: [B] absolute positions.
    Returns (logits [B, V_pad], updated cache)."""
    hidden = L.embed_tokens(params["embed"], token[:, None]) \
        .astype(cfg.jnp_dtype)                                  # [B,1,D]
    residual = jnp.zeros_like(hidden)
    w = cfg.window
    slot = pos % w if w else pos
    kv_len = jnp.minimum(pos + 1, w) if w else pos + 1

    # The cache rides in the scan CARRY and is updated in place with
    # dynamic_update_index_in_dim — XLA aliases carry updates, so only the
    # touched layer slice moves. Passing it as scan xs/ys instead forces a
    # whole-cache read + write every decode step (§Perf hillclimb C).
    def body(carry, layer_in):
        p_layer, li = layer_in
        hidden, residual, ks, vs = carry
        k_l = lax.dynamic_index_in_dim(ks, li, 0, keepdims=False)
        v_l = lax.dynamic_index_in_dim(vs, li, 0, keepdims=False)
        normed, residual = L.add_rms_norm(hidden, residual,
                                          p_layer["attn_norm"], cfg.norm_eps)
        # project + rope the new token, write it into the cache first
        q, k_new, v_new = L.qkv_proj(p_layer["attn"], normed, cfg)
        q = L.rope(q, pos[:, None], cfg.rope_theta)
        k_new = L.rope(k_new, pos[:, None], cfg.rope_theta)
        k_l, v_l = L.update_cache(k_l, v_l, k_new[:, 0], v_new[:, 0], slot)
        ks = lax.dynamic_update_index_in_dim(ks, k_l, li, 0)
        vs = lax.dynamic_update_index_in_dim(vs, v_l, li, 0)
        o = _cached_attention(q[:, 0], k_l, v_l, kv_len, cfg,
                              seq_shard_axis)
        attn_out = L.out_proj(p_layer["attn"], o[:, None], o.dtype)
        normed, residual = L.add_rms_norm(attn_out, residual,
                                          p_layer["mlp_norm"], cfg.norm_eps)
        hidden = L.mlp_block(p_layer["mlp"], normed)
        return (hidden, residual, ks, vs), None

    (hidden, residual, ks, vs), _ = lax.scan(
        body, (hidden, residual, cache["k"], cache["v"]),
        (params["layers"], jnp.arange(cfg.n_layers)))
    normed, _ = L.add_rms_norm(hidden, residual, params["final_norm"],
                               cfg.norm_eps)
    logits = L.unembed(normed[:, 0], params["lm_head"])
    return logits, {"k": ks, "v": vs}


def decode_step_paged(params, cfg: ModelConfig, pool, page_table, token,
                      pos, *, seq_shard_axis=None, write_mask=None):
    """One decode step over the paged KV pool.

    pool: ``{"k","v": [L, num_pages, page, Hkv, dh]}`` global block pool;
    page_table: ``[B, pages_per_slot]`` int32 (physical page of logical page
    ``j`` for slot ``b``; unallocated tail entries point at the engine's
    trap page). token/pos as in ``decode_step``. ``write_mask`` (``[B]``
    bool, full slot batch) routes masked-out rows' K/V writes to the trap
    page instead of their table page — the speculative-decoding verify
    program uses it so rejected draft positions never touch the pool; the
    logits math is untouched (a masked row's output is discarded by the
    caller).

    The new token's K/V scatter goes through the table —
    ``(page_table[b, pos//page], pos % page)`` — and attention gathers
    blocks through the same table (``ops.paged_flash_decode_attention``),
    so the math is bit-identical to ``decode_step`` over the contiguous
    cache the table describes. The pool rides in the scan carry exactly
    like the contiguous cache (in-place aliased carry updates).

    Under an active serving TP plan (``sharding.tp``, traced inside the
    engine's ``shard_map``), the slot batch splits over ``data``: each
    data shard embeds/attends/samples only its own rows, but the pool
    is replicated over ``data`` (radix-shared pages and swap-out reads
    need every row addressable), so the freshly-computed K/V rows are
    all-gathered across ``data`` before the full-batch pool scatter.
    The incoming ``page_table`` is ``data``-sharded (local rows drive
    the attention gather); write indices come from the gathered full
    table. With no plan every ``tp.*`` call is the identity."""
    from repro.kernels import ops
    if seq_shard_axis is not None:
        raise NotImplementedError(
            "sequence-sharded decode uses the contiguous split-KV path")
    hidden = L.embed_tokens(params["embed"], token[:, None]) \
        .astype(cfg.jnp_dtype)                                  # [B,1,D]
    page = pool["k"].shape[2]
    n_pt = page_table.shape[1]
    b_idx = jnp.arange(token.shape[0])
    pt_all = tp.gather_data(page_table)     # full table for write indices
    pidx = jnp.clip(pos // page, 0, n_pt - 1)
    phys = pt_all[b_idx, pidx]              # [B] physical page being written
    if write_mask is not None:
        # rejected speculative positions write to the trap page: the pool
        # never sees their K/V rows, at zero extra cost (page 0 absorbs
        # masked writes by construction)
        phys = jnp.where(write_mask, phys, 0)
    off = pos % page
    hidden = tp.data_shard(hidden)          # this shard's slot rows
    pos_q = tp.data_shard(pos)
    residual = jnp.zeros_like(hidden)
    kv_len = pos_q + 1

    def body(carry, layer_in):
        p_layer, li = layer_in
        hidden, residual, ks, vs = carry
        k_l = lax.dynamic_index_in_dim(ks, li, 0, keepdims=False)
        v_l = lax.dynamic_index_in_dim(vs, li, 0, keepdims=False)
        normed, residual = L.add_rms_norm(hidden, residual,
                                          p_layer["attn_norm"], cfg.norm_eps)
        q, k_new, v_new = L.qkv_proj(p_layer["attn"], normed, cfg)
        q = L.rope(q, pos_q[:, None], cfg.rope_theta)
        k_new = L.rope(k_new, pos_q[:, None], cfg.rope_theta)
        k_w = tp.gather_data(k_new[:, 0])   # full-batch rows for the pool
        v_w = tp.gather_data(v_new[:, 0])
        k_l = k_l.at[phys, off].set(k_w.astype(k_l.dtype))
        v_l = v_l.at[phys, off].set(v_w.astype(v_l.dtype))
        ks = lax.dynamic_update_index_in_dim(ks, k_l, li, 0)
        vs = lax.dynamic_update_index_in_dim(vs, v_l, li, 0)
        o = ops.paged_flash_decode_attention(q[:, 0], k_l, v_l, page_table,
                                             kv_len=kv_len)
        attn_out = L.out_proj(p_layer["attn"], o[:, None], o.dtype)
        normed, residual = L.add_rms_norm(attn_out, residual,
                                          p_layer["mlp_norm"], cfg.norm_eps)
        hidden = L.mlp_block(p_layer["mlp"], normed)
        return (hidden, residual, ks, vs), None

    (hidden, residual, ks, vs), _ = lax.scan(
        body, (hidden, residual, pool["k"], pool["v"]),
        (params["layers"], jnp.arange(cfg.n_layers)))
    normed, _ = L.add_rms_norm(hidden, residual, params["final_norm"],
                               cfg.norm_eps)
    logits = L.unembed(normed[:, 0], params["lm_head"])
    return logits, {"k": ks, "v": vs}


def _cached_attention(q, k_cache, v_cache, kv_len, cfg: ModelConfig,
                      seq_shard_axis):
    """Decode attention over the cache; seq-sharded split-KV when mapped."""
    from repro.kernels import ops
    if seq_shard_axis is None:
        return ops.flash_decode_attention(q, k_cache, v_cache, kv_len=kv_len)
    idx = lax.axis_index(seq_shard_axis)
    shard = k_cache.shape[1]
    local_len = jnp.clip(kv_len - idx * shard, 0, shard)
    o_part, lse = ops.flash_decode_attention(
        q, k_cache, v_cache, kv_len=local_len, return_lse=True)
    o_part = jnp.where(jnp.isneginf(lse)[..., None], 0.0,
                       o_part.astype(jnp.float32))
    m = lax.pmax(lse, seq_shard_axis)
    m_safe = jnp.where(jnp.isneginf(m), 0.0, m)
    w = jnp.where(jnp.isneginf(lse), 0.0, jnp.exp(lse - m_safe))
    num = lax.psum(w[..., None] * o_part, seq_shard_axis)
    den = lax.psum(w, seq_shard_axis)
    return (num / jnp.maximum(den, 1e-30)[..., None]).astype(q.dtype)
