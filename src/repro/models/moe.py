"""Mixture-of-Experts transformer (granite-moe-3b-a800m, olmoe-1b-7b).

Expert-parallel (EP) design: GShard/Mesh-TF grouped capacity dispatch —
tokens are split into groups; within each group a top-k router builds a
dispatch one-hot [G, E, C]; dispatch/combine einsums against the token
block lower to all-to-all under GSPMD when the group axis is data-sharded
and the expert axis is model-sharded. Dispatch FLOPs overhead is visible
in the §Roofline useful-FLOPs ratio (group size is a hillclimb knob).

Expert FFNs are SwiGLU — paper Kernel 3 runs inside every expert.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.kernels import ops


GROUP = 256  # tokens per dispatch group

# Attention is causal, but right-padded bucketed prefill is NOT safe here:
# pad tokens compete with real tokens for expert capacity inside the
# router's grouped dispatch, so padding can change real-token outputs (a
# capacity drop that an exact-length prefill would not have). The serving
# engine therefore prefills MoE prompts at exact length.
PAD_PREFILL = False

# Paged-KV serving is NOT exact here even though the cache itself is
# positional K/V: capacity routing couples decode across pool slots, so a
# preemption (which changes which requests occupy the other slots) would
# change the surviving requests' tokens. The serving engine keeps the
# contiguous per-slot pool for this family.
PAGED_OK = False


def capacity(cfg: ModelConfig, group: int) -> int:
    c = int(group * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(cfg.top_k, -(-c // 8) * 8)  # sublane-align, >= top_k


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init(cfg: ModelConfig, key):
    keys = jax.random.split(key, 4)
    dtype = jnp.float32

    def one_layer(k):
        ka, kr, k1, k2 = jax.random.split(k, 4)
        pairs = {
            "attn": L.attn_params(ka, cfg, dtype),
            "router": L.dense_init(kr, (cfg.d_model, cfg.n_experts),
                                   ("embed", "experts"), dtype=dtype),
            "w_gateup": L.dense_init(
                k1, (cfg.n_experts, cfg.d_model, 2 * cfg.expert_ff),
                ("experts", "embed", "expert_mlp"),
                scale=cfg.d_model ** -0.5, dtype=dtype),
            "w_down": L.dense_init(
                k2, (cfg.n_experts, cfg.expert_ff, cfg.d_model),
                ("experts", "expert_mlp", "embed"),
                scale=cfg.expert_ff ** -0.5, dtype=dtype),
            "attn_norm": L.ones_init((cfg.d_model,), ("embed",)),
            "mlp_norm": L.ones_init((cfg.d_model,), ("embed",)),
        }
        return L.split_tree(pairs)

    layer_keys = jax.random.split(keys[0], cfg.n_layers)
    stacked = jax.vmap(lambda k: one_layer(k)[0])(layer_keys)
    _, axes_one = one_layer(layer_keys[0])
    layer_axes = jax.tree.map(lambda ax: ("layers",) + ax, axes_one,
                              is_leaf=lambda x: isinstance(x, tuple))
    emb, emb_ax = L.dense_init(keys[1], (cfg.padded_vocab, cfg.d_model),
                               ("embed_vocab", "mlp"), scale=1.0, dtype=dtype)
    head, head_ax = L.dense_init(keys[2], (cfg.d_model, cfg.padded_vocab),
                                 ("embed", "vocab"), dtype=dtype)
    fnorm, fnorm_ax = L.ones_init((cfg.d_model,), ("embed",))
    return ({"embed": emb, "layers": stacked, "final_norm": fnorm,
             "lm_head": head},
            {"embed": emb_ax, "layers": layer_axes, "final_norm": fnorm_ax,
             "lm_head": head_ax})


# --------------------------------------------------------------------------
# MoE block
# --------------------------------------------------------------------------

def moe_block(p, x, cfg: ModelConfig):
    """x: [B, S, D] -> [B, S, D] through top-k routed experts."""
    b, s, d = x.shape
    tokens = b * s
    g = min(GROUP, tokens)
    n_groups = tokens // g
    cap = capacity(cfg, g)
    xt = x.reshape(n_groups, g, d)

    logits = jnp.einsum("ngd,de->nge", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                   # [N,G,E]
    topv, topi = lax.top_k(probs, cfg.top_k)                  # [N,G,K]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # position of each routed slot within its expert (slot-major cumsum)
    onehot = jax.nn.one_hot(topi, cfg.n_experts, dtype=jnp.float32)
    flat = onehot.reshape(n_groups, g * cfg.top_k, cfg.n_experts)
    pos = jnp.cumsum(flat, axis=1) - flat                     # [N,G*K,E]
    pos = jnp.einsum("nse,nse->ns", pos, flat).reshape(
        n_groups, g, cfg.top_k)                               # [N,G,K]
    keep = pos < cap
    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32) \
        * keep[..., None]                                     # [N,G,K,C]
    # dispatch [N,G,E,C] / combine (weighted) tensors
    dispatch = jnp.einsum("ngke,ngkc->ngec", onehot, pos_oh)
    combine = jnp.einsum("ngke,ngkc,ngk->ngec", onehot, pos_oh, topv)

    dt = cfg.jnp_dtype
    # dispatch: [E, N, C, D] token blocks (all-to-all under GSPMD when the
    # group axis is data-sharded and the expert axis is model-sharded)
    expert_in = jnp.einsum("ngec,ngd->encd", dispatch.astype(dt), xt)
    expert_in = expert_in.reshape(cfg.n_experts, n_groups * cap, d)
    h = jnp.einsum("etd,edf->etf", expert_in, p["w_gateup"].astype(dt))
    h = ops.silu_and_mul(h)
    out_e = jnp.einsum("etf,efd->etd", h, p["w_down"].astype(dt))
    out_e = out_e.reshape(cfg.n_experts, n_groups, cap, d)
    out = jnp.einsum("ngec,encd->ngd", combine.astype(dt), out_e)
    return out.reshape(b, s, d)


# --------------------------------------------------------------------------
# forward / loss / serving — same skeleton as the dense transformer
# --------------------------------------------------------------------------

def _block_train(p_layer, carry, cfg: ModelConfig, chunk: int):
    hidden, residual = carry
    hidden = L.shard_batch(hidden)
    residual = L.shard_batch(residual)
    normed, residual = L.add_rms_norm(hidden, residual,
                                      p_layer["attn_norm"], cfg.norm_eps)
    attn_out, _ = L.attention_block(p_layer["attn"], normed, cfg, chunk=chunk)
    normed, residual = L.add_rms_norm(attn_out, residual,
                                      p_layer["mlp_norm"], cfg.norm_eps)
    hidden = moe_block(p_layer, normed, cfg)
    return hidden, residual


def forward(params, cfg: ModelConfig, tokens, *, chunk: int = 512):
    hidden = L.embed_tokens(params["embed"], tokens).astype(cfg.jnp_dtype)
    residual = jnp.zeros_like(hidden)
    block = jax.checkpoint(
        functools.partial(_block_train, cfg=cfg, chunk=chunk),
        policy=jax.checkpoint_policies.nothing_saveable)

    def body(carry, p_layer):
        return block(p_layer, carry), None

    (hidden, residual), _ = lax.scan(body, (hidden, residual),
                                     params["layers"])
    normed, _ = L.add_rms_norm(hidden, residual, params["final_norm"],
                               cfg.norm_eps)
    return L.unembed(normed, params["lm_head"])


def loss_fn(params, cfg: ModelConfig, batch, *, chunk: int = 512):
    logits = forward(params, cfg, batch["tokens"], chunk=chunk)
    return L.ce_loss(logits, batch["labels"], cfg.vocab)


cache_spec = T.cache_spec
init_cache = T.init_cache


def prefill(params, cfg: ModelConfig, tokens, *, chunk: int = 512,
            cache_len: int | None = None, length=None):
    b, s = tokens.shape
    hidden = L.embed_tokens(params["embed"], tokens).astype(cfg.jnp_dtype)
    residual = jnp.zeros_like(hidden)

    def block(p_layer, carry):
        hidden, residual = carry
        normed, residual = L.add_rms_norm(hidden, residual,
                                          p_layer["attn_norm"], cfg.norm_eps)
        attn_out, (k, v) = L.attention_block(p_layer["attn"], normed, cfg,
                                             chunk=chunk)
        normed, residual = L.add_rms_norm(attn_out, residual,
                                          p_layer["mlp_norm"], cfg.norm_eps)
        hidden = moe_block(p_layer, normed, cfg)
        return (hidden, residual), (k, v)

    block = jax.checkpoint(block,
                           policy=jax.checkpoint_policies.nothing_saveable)

    def body(carry, p_layer):
        return block(p_layer, carry)

    (hidden, residual), (ks, vs) = lax.scan(body, (hidden, residual),
                                            params["layers"])
    if cache_len and cache_len > ks.shape[2]:
        pad = ((0, 0), (0, 0), (0, cache_len - ks.shape[2]), (0, 0), (0, 0))
        ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
    cache = {"k": ks, "v": vs}
    h_last, r_last = T._last_position(hidden, residual, length)
    normed, _ = L.add_rms_norm(h_last, r_last,
                               params["final_norm"], cfg.norm_eps)
    return L.unembed(normed[:, 0], params["lm_head"]), cache


def decode_step(params, cfg: ModelConfig, cache, token, pos, *,
                seq_shard_axis=None):
    hidden = L.embed_tokens(params["embed"], token[:, None]) \
        .astype(cfg.jnp_dtype)
    residual = jnp.zeros_like(hidden)
    kv_len = pos + 1

    def body(carry, layer_in):
        p_layer, k_l, v_l = layer_in
        hidden, residual = carry
        normed, residual = L.add_rms_norm(hidden, residual,
                                          p_layer["attn_norm"], cfg.norm_eps)
        q, k_new, v_new = L.qkv_proj(p_layer["attn"], normed, cfg)
        q = L.rope(q, pos[:, None], cfg.rope_theta)
        k_new = L.rope(k_new, pos[:, None], cfg.rope_theta)
        k_l, v_l = L.update_cache(k_l, v_l, k_new[:, 0], v_new[:, 0], pos)
        o = T._cached_attention(q[:, 0], k_l, v_l, kv_len, cfg,
                                seq_shard_axis)
        attn_out = L.out_proj(p_layer["attn"], o[:, None], o.dtype)
        normed, residual = L.add_rms_norm(attn_out, residual,
                                          p_layer["mlp_norm"], cfg.norm_eps)
        hidden = moe_block(p_layer, normed, cfg)
        return (hidden, residual), (k_l, v_l)

    (hidden, residual), (ks, vs) = lax.scan(
        body, (hidden, residual), (params["layers"], cache["k"], cache["v"]))
    normed, _ = L.add_rms_norm(hidden, residual, params["final_norm"],
                               cfg.norm_eps)
    return L.unembed(normed[:, 0], params["lm_head"]), {"k": ks, "v": vs}
