"""SeamlessM4T-large-v2 backbone — encoder-decoder transformer
[arXiv:2308.11596].

Per the assignment, only the transformer BACKBONE is modeled: the audio
frontend is a STUB — ``input_specs()`` provides precomputed frame
embeddings [B, S_src, d_model] (the real model's mel-filterbank +
conformer-conv subsampling happens upstream). Adaptation note (DESIGN.md):
the speech encoder's conformer convolutions are replaced by plain
bidirectional transformer layers of the assigned dims; the text decoder is
causal with cross-attention.

24 encoder + 24 decoder layers (the v2 speech-enc/text-dec split), both
scanned. Decode keeps two caches: self-attention K/V (grows with generated
tokens) and cross-attention K/V (computed once from the encoder output).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L


# The encoder is bidirectional: pad frames would contaminate every
# position's encoding, so source frames are always encoded at exact length.
PAD_PREFILL = False

# Dual self+cross KV caches: the cross-attention cache is encoder-length
# (not decode-position) indexed, so the uniform (pages, page) pool layout
# does not describe it. Contiguous per-slot pool only.
PAGED_OK = False


def _cross_attn_params(key, cfg, dtype):
    return L.attn_params(key, cfg, dtype)


def init(cfg: ModelConfig, key):
    keys = jax.random.split(key, 6)
    dtype = jnp.float32

    def enc_layer(k):
        ka, km = jax.random.split(k)
        return L.split_tree({
            "attn": L.attn_params(ka, cfg, dtype),
            "mlp": L.mlp_params(km, cfg, dtype),
            "attn_norm": L.ones_init((cfg.d_model,), ("embed",)),
            "mlp_norm": L.ones_init((cfg.d_model,), ("embed",)),
        })

    def dec_layer(k):
        ka, kx, km = jax.random.split(k, 3)
        return L.split_tree({
            "attn": L.attn_params(ka, cfg, dtype),
            "cross": _cross_attn_params(kx, cfg, dtype),
            "mlp": L.mlp_params(km, cfg, dtype),
            "attn_norm": L.ones_init((cfg.d_model,), ("embed",)),
            "cross_norm": L.ones_init((cfg.d_model,), ("embed",)),
            "mlp_norm": L.ones_init((cfg.d_model,), ("embed",)),
        })

    enc_keys = jax.random.split(keys[0], cfg.enc_layers)
    dec_keys = jax.random.split(keys[1], cfg.n_layers)
    enc_stack = jax.vmap(lambda k: enc_layer(k)[0])(enc_keys)
    dec_stack = jax.vmap(lambda k: dec_layer(k)[0])(dec_keys)
    _, enc_ax = enc_layer(enc_keys[0])
    _, dec_ax = dec_layer(dec_keys[0])
    lift = functools.partial(jax.tree.map, lambda ax: ("layers",) + ax,
                             is_leaf=lambda x: isinstance(x, tuple))

    emb, emb_ax = L.dense_init(keys[2], (cfg.padded_vocab, cfg.d_model),
                               ("embed_vocab", "mlp"), scale=1.0, dtype=dtype)
    head, head_ax = L.dense_init(keys[3], (cfg.d_model, cfg.padded_vocab),
                                 ("embed", "vocab"), dtype=dtype)
    return ({"embed": emb, "enc_layers": enc_stack, "dec_layers": dec_stack,
             "enc_norm": L.ones_init((cfg.d_model,), ("embed",))[0],
             "final_norm": L.ones_init((cfg.d_model,), ("embed",))[0],
             "lm_head": head},
            {"embed": emb_ax, "enc_layers": lift(enc_ax),
             "dec_layers": lift(dec_ax),
             "enc_norm": ("embed",), "final_norm": ("embed",),
             "lm_head": head_ax})


# --------------------------------------------------------------------------
# encoder
# --------------------------------------------------------------------------

def encode(params, cfg: ModelConfig, frames, *, chunk: int = 512):
    """frames: [B, S_src, D] precomputed frame embeddings -> [B, S_src, D]."""
    x = frames.astype(cfg.jnp_dtype)

    def block(p, x):
        x = L.shard_batch(x)
        normed = L.rms_norm(x, p["attn_norm"], cfg.norm_eps)
        b, s, _ = x.shape
        q, k, v = L.qkv_proj(p["attn"], normed, cfg)
        pos = jnp.broadcast_to(jnp.arange(s), (b, s))
        q = L.rope(q, pos, cfg.rope_theta)
        k = L.rope(k, pos, cfg.rope_theta)
        o = L.flash_attention(q, k, v, False, None, chunk, True)
        x = x + L.out_proj(p["attn"], o, x.dtype)
        normed = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
        return x + L.mlp_block(p["mlp"], normed)

    block = jax.checkpoint(block,
                           policy=jax.checkpoint_policies.nothing_saveable)

    def body(x, p):
        return block(p, x), None

    x, _ = lax.scan(body, x, params["enc_layers"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


# --------------------------------------------------------------------------
# decoder
# --------------------------------------------------------------------------

def _dec_block(p, carry, enc_out, cfg, chunk):
    x = L.shard_batch(carry)
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    # causal self-attention
    normed = L.rms_norm(x, p["attn_norm"], cfg.norm_eps)
    q, k, v = L.qkv_proj(p["attn"], normed, cfg)
    q, k = L.rope(q, pos, cfg.rope_theta), L.rope(k, pos, cfg.rope_theta)
    o = L.flash_attention(q, k, v, True, None, chunk, False)
    x = x + L.out_proj(p["attn"], o, x.dtype)
    # cross-attention to the encoder output
    normed = L.rms_norm(x, p["cross_norm"], cfg.norm_eps)
    qc, _, _ = L.qkv_proj(p["cross"], normed, cfg)
    _, kc, vc = L.qkv_proj(p["cross"], enc_out.astype(x.dtype), cfg)
    oc = L.flash_attention(qc, kc, vc, False, None, chunk, True)
    x = x + L.out_proj(p["cross"], oc, x.dtype)
    # MLP
    normed = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    return x + L.mlp_block(p["mlp"], normed)


def forward(params, cfg: ModelConfig, batch, *, chunk: int = 512):
    """Teacher-forced translation logits.

    batch: {"frames": [B, S_src, D], "tokens": [B, S_tgt]}.
    """
    enc_out = encode(params, cfg, batch["frames"], chunk=chunk)
    x = L.embed_tokens(params["embed"], batch["tokens"]).astype(cfg.jnp_dtype)

    block = jax.checkpoint(
        lambda p, c: _dec_block(p, c, enc_out, cfg, chunk),
        policy=jax.checkpoint_policies.nothing_saveable)

    def body(x, p):
        return block(p, x), None

    x, _ = lax.scan(body, x, params["dec_layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(x, params["lm_head"])


def loss_fn(params, cfg: ModelConfig, batch, *, chunk: int = 512):
    logits = forward(params, cfg, batch, chunk=chunk)
    return L.ce_loss(logits, batch["labels"], cfg.vocab)


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

def cache_spec(cfg: ModelConfig, batch: int, seq: int):
    """Self cache sized `seq` (generated side) + cross K/V sized `seq`."""
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    dt = cfg.jnp_dtype
    self_shape = (cfg.n_layers, batch, seq, hkv, dh)
    axes = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    return ({"k": jax.ShapeDtypeStruct(self_shape, dt),
             "v": jax.ShapeDtypeStruct(self_shape, dt),
             "ck": jax.ShapeDtypeStruct(self_shape, dt),
             "cv": jax.ShapeDtypeStruct(self_shape, dt)},
            {"k": axes, "v": axes, "ck": axes, "cv": axes})


def init_cache(cfg: ModelConfig, batch: int, seq: int):
    spec, axes = cache_spec(cfg, batch, seq)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec), axes


def prefill(params, cfg: ModelConfig, frames, *, chunk: int = 512,
            cache_len: int | None = None, length=None):
    """Encode source frames and precompute cross K/V; self cache empty.

    Returns (BOS logits, cache). frames: [B, S_src, D].
    """
    assert length is None, "enc-dec prefill does not support padded frames"
    b, s_src, _ = frames.shape
    enc_out = encode(params, cfg, frames, chunk=chunk)

    def cross_kv(p):
        _, kc, vc = L.qkv_proj(p["cross"], enc_out.astype(cfg.jnp_dtype), cfg)
        return kc, vc

    cks, cvs = lax.map(cross_kv, params["dec_layers"])
    n = cache_len or cks.shape[2]
    zshape = (cks.shape[0], cks.shape[1], n) + cks.shape[3:]
    cache = {"k": jnp.zeros(zshape, cks.dtype),
             "v": jnp.zeros(zshape, cvs.dtype),
             "ck": cks, "cv": cvs}
    bos = jnp.zeros((b,), jnp.int32)
    logits, cache = decode_step(params, cfg, cache, bos,
                                jnp.zeros((b,), jnp.int32))
    return logits, cache


def decode_step(params, cfg: ModelConfig, cache, token, pos, *,
                seq_shard_axis=None):
    from repro.models.transformer import _cached_attention
    b = token.shape[0]
    x = L.embed_tokens(params["embed"], token[:, None]).astype(cfg.jnp_dtype)
    kv_len = pos + 1
    s_src = cache["ck"].shape[2]
    src_len = jnp.full((b,), s_src, jnp.int32)

    def body(x, inp):
        p, k_l, v_l, ck_l, cv_l = inp
        # self-attention over the generated-token cache
        normed = L.rms_norm(x, p["attn_norm"], cfg.norm_eps)
        q, k_new, v_new = L.qkv_proj(p["attn"], normed, cfg)
        q = L.rope(q, pos[:, None], cfg.rope_theta)
        k_new = L.rope(k_new, pos[:, None], cfg.rope_theta)
        k_l, v_l = L.update_cache(k_l, v_l, k_new[:, 0], v_new[:, 0], pos)
        o = _cached_attention(q[:, 0], k_l, v_l, kv_len, cfg, seq_shard_axis)
        x = x + L.out_proj(p["attn"], o[:, None], o.dtype)
        # cross-attention to the precomputed encoder K/V
        normed = L.rms_norm(x, p["cross_norm"], cfg.norm_eps)
        qc, _, _ = L.qkv_proj(p["cross"], normed, cfg)
        oc = _cached_attention(qc[:, 0], ck_l, cv_l, src_len, cfg,
                               seq_shard_axis)
        x = x + L.out_proj(p["cross"], oc[:, None], oc.dtype)
        normed = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
        x = x + L.mlp_block(p["mlp"], normed)
        return x, (k_l, v_l)

    x, (ks, vs) = lax.scan(body, x, (params["dec_layers"], cache["k"],
                                     cache["v"], cache["ck"], cache["cv"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(x[:, 0], params["lm_head"])
    return logits, {"k": ks, "v": vs, "ck": cache["ck"], "cv": cache["cv"]}
