"""xLSTM (sLSTM + mLSTM blocks) — xlstm-1.3b [arXiv:2405.04517].

Block mix follows the cited 1.3B model's xLSTM[7:1] recipe: periods of
8 blocks = 7 mLSTM + 1 sLSTM, scanned over periods (stacked params).

mLSTM: matrix memory C [d_qk, d_v] per head with exponential input gate and
log-space max-stabilizer m (the paper's eq. 19-27). Sequence processing is
an outer scan over chunks with the inner per-step scan rematerialized —
chunk-boundary states are the only saved residuals (the production TPU path
would be a chunkwise matmul kernel; noted in DESIGN.md / roofline).

sLSTM: scalar memory per unit with exponential gating — a true nonlinear
recurrence (not parallelizable), scanned per step.

Decode state is O(1) in sequence length — this is why the arch runs the
long_500k cell. No softmax attention exists here, so paper Kernel 1 is
inapplicable to the mixer (DESIGN.md §Arch-applicability); pre-norms use
the fused add+RMSNorm kernel and gates use SiLU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L

CHUNK = 64  # remat chunk for the recurrent scans

# Recurrent state: every processed token (pad or not) mutates (C, n, m), so
# right-padded bucketed prefill would corrupt the carried state. The serving
# engine prefills xLSTM prompts at exact length.
PAD_PREFILL = False

# The "cache" is fixed-size recurrent state, not a growing positional K/V
# sequence — there is nothing to page. Contiguous per-slot pool only.
PAGED_OK = False


def _dims(cfg: ModelConfig):
    d_inner = 2 * cfg.d_model
    dh = d_inner // cfg.n_heads
    dqk = dh // 2
    return d_inner, dh, dqk


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _mlstm_params(key, cfg, dtype):
    d, h = cfg.d_model, cfg.n_heads
    d_inner, dh, dqk = _dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "norm": L.ones_init((d,), ("embed",)),
        "w_up": L.dense_init(ks[0], (d, d_inner), ("embed", "mlp"), dtype=dtype),
        "w_gate": L.dense_init(ks[1], (d, d_inner), ("embed", "mlp"), dtype=dtype),
        # block-diagonal per-head projections
        "w_q": L.dense_init(ks[2], (h, dh, dqk), ("heads", "head_dim", None), dtype=dtype),
        "w_k": L.dense_init(ks[3], (h, dh, dqk), ("heads", "head_dim", None), dtype=dtype),
        "w_v": L.dense_init(ks[4], (h, dh, dh), ("heads", "head_dim", None), dtype=dtype),
        "w_i": L.dense_init(ks[5], (h, dh), ("heads", "head_dim"), dtype=dtype),
        "w_f": L.dense_init(ks[6], (h, dh), ("heads", "head_dim"), dtype=dtype),
        "w_down": L.dense_init(ks[7], (d_inner, d), ("mlp", "embed"), dtype=dtype),
    }


def _slstm_params(key, cfg, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    return {
        "norm": L.ones_init((d,), ("embed",)),
        "w_z": L.dense_init(ks[0], (d, d), ("embed", "mlp"), dtype=dtype),
        "w_i": L.dense_init(ks[1], (d, d), ("embed", "mlp"), dtype=dtype),
        "w_f": L.dense_init(ks[2], (d, d), ("embed", "mlp"), dtype=dtype),
        "w_o": L.dense_init(ks[3], (d, d), ("embed", "mlp"), dtype=dtype),
        "w_down": L.dense_init(ks[4], (d, d), ("mlp", "embed"), dtype=dtype),
    }


def init(cfg: ModelConfig, key):
    period = 8
    n_periods = cfg.n_layers // period          # 48 -> 6 periods of 7m+1s
    n_m = period - 1
    keys = jax.random.split(key, 4)
    dtype = jnp.float32

    def one_period(k):
        km, ks_ = jax.random.split(k)
        m_keys = jax.random.split(km, n_m)
        m_stack = jax.vmap(lambda kk: L.split_tree(
            _mlstm_params(kk, cfg, dtype))[0])(m_keys)
        _, m_axes = L.split_tree(_mlstm_params(m_keys[0], cfg, dtype))
        s_params, s_axes = L.split_tree(_slstm_params(ks_, cfg, dtype))
        return ({"mlstm": m_stack, "slstm": s_params},
                {"mlstm": jax.tree.map(lambda ax: ("stack",) + ax, m_axes,
                                       is_leaf=lambda x: isinstance(x, tuple)),
                 "slstm": s_axes})

    p_keys = jax.random.split(keys[0], n_periods)
    stacked = jax.vmap(lambda k: one_period(k)[0])(p_keys)
    _, axes_one = one_period(p_keys[0])
    period_axes = jax.tree.map(lambda ax: ("layers",) + ax, axes_one,
                               is_leaf=lambda x: isinstance(x, tuple))
    emb, emb_ax = L.dense_init(keys[1], (cfg.padded_vocab, cfg.d_model),
                               ("embed_vocab", "mlp"), scale=1.0, dtype=dtype)
    head, head_ax = L.dense_init(keys[2], (cfg.d_model, cfg.padded_vocab),
                                 ("embed", "vocab"), dtype=dtype)
    fnorm, fnorm_ax = L.ones_init((cfg.d_model,), ("embed",))
    return ({"embed": emb, "periods": stacked, "final_norm": fnorm,
             "lm_head": head},
            {"embed": emb_ax, "periods": period_axes, "final_norm": fnorm_ax,
             "lm_head": head_ax})


# --------------------------------------------------------------------------
# mLSTM cell
# --------------------------------------------------------------------------

def _mlstm_qkvif(p, x, cfg):
    """x: [B,S,D] -> per-head q,k,v and log-gates. Shapes [B,S,H,*]."""
    d_inner, dh, dqk = _dims(cfg)
    b, s, _ = x.shape
    u = jnp.einsum("bsd,de->bse", x, p["w_up"].astype(x.dtype))
    z = jnp.einsum("bsd,de->bse", x, p["w_gate"].astype(x.dtype))
    uh = u.reshape(b, s, cfg.n_heads, dh)
    q = jnp.einsum("bshe,heq->bshq", uh, p["w_q"].astype(x.dtype))
    k = jnp.einsum("bshe,heq->bshq", uh, p["w_k"].astype(x.dtype)) \
        * (dqk ** -0.5)
    v = jnp.einsum("bshe,hev->bshv", uh, p["w_v"].astype(x.dtype))
    log_i = jnp.einsum("bshe,he->bsh", uh.astype(jnp.float32),
                       p["w_i"].astype(jnp.float32))
    log_f = -jax.nn.softplus(-jnp.einsum(
        "bshe,he->bsh", uh.astype(jnp.float32),
        p["w_f"].astype(jnp.float32)))       # log sigmoid(f̃)
    return q, k, v, log_i, log_f, z


def _mlstm_step(state, inp):
    """One mLSTM timestep. state: (C [B,H,K,V], n [B,H,K], m [B,H])."""
    C, n, m = state
    q, k, v, log_i, log_f = inp              # [B,H,K],[B,H,K],[B,H,V],[B,H]
    m_new = jnp.maximum(log_f + m, log_i)
    i_ = jnp.exp(log_i - m_new)[..., None]                  # [B,H,1]
    f_ = jnp.exp(log_f + m - m_new)[..., None]
    C = f_[..., None] * C + i_[..., None] * (k[..., :, None] * v[..., None, :])
    n = f_ * n + i_ * k
    h_num = jnp.einsum("bhkv,bhk->bhv", C, q)
    h_den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, q))
    h = h_num / jnp.maximum(h_den, jnp.exp(-m_new))[..., None]
    return (C, n, m_new), h


def _mlstm_scan(state, q, k, v, log_i, log_f):
    """Scan a [B,S,...] segment through the cell; returns (state, h)."""
    def body(st, xs):
        return _mlstm_step(st, xs)
    xs = (jnp.moveaxis(q.astype(jnp.float32), 1, 0),
          jnp.moveaxis(k.astype(jnp.float32), 1, 0),
          jnp.moveaxis(v.astype(jnp.float32), 1, 0),
          jnp.moveaxis(log_i, 1, 0), jnp.moveaxis(log_f, 1, 0))
    state, hs = lax.scan(body, state, xs)
    return state, jnp.moveaxis(hs, 0, 1)                     # [B,S,H,V]


def mlstm_block(p, x, cfg: ModelConfig, state=None):
    """Full mLSTM residual block. x: [B,S,D]. Returns (y, state)."""
    b, s, d = x.shape
    d_inner, dh, dqk = _dims(cfg)
    normed = L.rms_norm(x, p["norm"], cfg.norm_eps)
    q, k, v, log_i, log_f, z = _mlstm_qkvif(p, normed, cfg)
    if state is None:
        state = (jnp.zeros((b, cfg.n_heads, dqk, dh), jnp.float32),
                 jnp.zeros((b, cfg.n_heads, dqk), jnp.float32),
                 jnp.full((b, cfg.n_heads), -1e30, jnp.float32))

    if s == 1:
        xs = tuple(t[:, 0] for t in
                   (q.astype(jnp.float32), k.astype(jnp.float32),
                    v.astype(jnp.float32), log_i, log_f))
        state, h = _mlstm_step(state, xs)
        h = h[:, None]
    else:
        # outer scan over remat chunks; inner per-step scan recomputed in
        # backward (only chunk-boundary states are saved). The whole sweep
        # is named_scope'd as ONE fused kernel region: the TPU target (a
        # GLA-style Pallas linear-scan kernel) streams q,k,v,gates once and
        # keeps (C,n,m) in VMEM — per-token state churn never touches HBM.
        # Costed analytically in roofline/analysis.kernel_traffic.
        with jax.named_scope("mlstm_kernel"):
            n_chunks = max(1, s // CHUNK)
            c = s // n_chunks

            def chunk_fn(st, xs):
                return jax.checkpoint(
                    lambda st_, xs_: _mlstm_scan(st_, *xs_))(st, xs)

            def reshape(t):
                return jnp.moveaxis(
                    t.reshape(b, n_chunks, c, *t.shape[2:]), 1, 0)

            state, h = lax.scan(
                chunk_fn, state,
                tuple(reshape(t) for t in (q, k, v, log_i, log_f)))
            h = jnp.moveaxis(h, 0, 1).reshape(b, s, cfg.n_heads, dh)

    h = h.reshape(b, s, d_inner).astype(x.dtype)
    h = h * jax.nn.silu(z)                       # output gate (SiLU)
    y = jnp.einsum("bse,ed->bsd", h, p["w_down"].astype(x.dtype))
    return x + y, state


# --------------------------------------------------------------------------
# sLSTM cell
# --------------------------------------------------------------------------

def slstm_block(p, x, cfg: ModelConfig, state=None):
    """Scalar-memory LSTM block with exponential gating. x: [B,S,D]."""
    b, s, d = x.shape
    normed = L.rms_norm(x, p["norm"], cfg.norm_eps)
    zt = jnp.einsum("bsd,de->bse", normed, p["w_z"].astype(x.dtype))
    it = jnp.einsum("bsd,de->bse", normed, p["w_i"].astype(x.dtype)) \
        .astype(jnp.float32)
    ft = jnp.einsum("bsd,de->bse", normed, p["w_f"].astype(x.dtype)) \
        .astype(jnp.float32)
    ot = jnp.einsum("bsd,de->bse", normed, p["w_o"].astype(x.dtype))
    if state is None:
        state = (jnp.zeros((b, d), jnp.float32),
                 jnp.zeros((b, d), jnp.float32),
                 jnp.full((b, d), -1e30, jnp.float32))

    def step(st, inp):
        c_, n_, m_ = st
        z_, i_, f_ = inp
        log_f = -jax.nn.softplus(-f_)
        m_new = jnp.maximum(log_f + m_, i_)
        iw = jnp.exp(i_ - m_new)
        fw = jnp.exp(log_f + m_ - m_new)
        c_new = fw * c_ + iw * jnp.tanh(z_)
        n_new = fw * n_ + iw
        h = c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new), h

    if s == 1:
        state, h = step(state, (zt[:, 0].astype(jnp.float32),
                                it[:, 0], ft[:, 0]))
        h = h[:, None]
    else:
        # fused-kernel region (see mlstm_block): stream z,i,f once, state
        # stays in VMEM on the TPU target
        with jax.named_scope("slstm_kernel"):
            n_chunks = max(1, s // CHUNK)
            c = s // n_chunks

            def chunk_fn(st, xs):
                def inner(st_, xs_):
                    st2, hs = lax.scan(
                        step, st_,
                        tuple(jnp.moveaxis(t, 1, 0) for t in xs_))
                    return st2, jnp.moveaxis(hs, 0, 1)
                return jax.checkpoint(inner)(st, xs)

            def reshape(t):
                return jnp.moveaxis(
                    t.reshape(b, n_chunks, c, *t.shape[2:]), 1, 0)
            state, h = lax.scan(chunk_fn, state,
                                (reshape(zt.astype(jnp.float32)),
                                 reshape(it), reshape(ft)))
            h = jnp.moveaxis(h, 0, 1).reshape(b, s, d)

    h = h.astype(x.dtype) * jax.nn.sigmoid(ot)
    y = jnp.einsum("bse,ed->bsd", h, p["w_down"].astype(x.dtype))
    return x + y, state


# --------------------------------------------------------------------------
# model-level API
# --------------------------------------------------------------------------

def _period_fwd(p_period, x, cfg, m_states=None, s_state=None):
    """7 mLSTM (inner scan over stacked params) + 1 sLSTM."""
    x = L.shard_batch(x)
    out_m_states = []
    n_m = jax.tree.leaves(p_period["mlstm"])[0].shape[0]
    if m_states is None:
        for i in range(n_m):
            p_i = jax.tree.map(lambda t: t[i], p_period["mlstm"])
            x, st = mlstm_block(p_i, x, cfg)
            out_m_states.append(st)
    else:
        for i in range(n_m):
            p_i = jax.tree.map(lambda t: t[i], p_period["mlstm"])
            st_i = jax.tree.map(lambda t: t[i], m_states)
            x, st = mlstm_block(p_i, x, cfg, st_i)
            out_m_states.append(st)
    x, s_state = slstm_block(p_period["slstm"], x, cfg, s_state)
    m_stack = jax.tree.map(lambda *ts: jnp.stack(ts), *out_m_states)
    return x, m_stack, s_state


def forward(params, cfg: ModelConfig, tokens, *, chunk: int = 512):
    x = L.embed_tokens(params["embed"], tokens).astype(cfg.jnp_dtype)

    def body(x, p_period):
        x, _, _ = _period_fwd(p_period, x, cfg)
        return x, None

    x, _ = lax.scan(body, x, params["periods"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(x, params["lm_head"])


def loss_fn(params, cfg: ModelConfig, batch, *, chunk: int = 512):
    logits = forward(params, cfg, batch["tokens"])
    return L.ce_loss(logits, batch["labels"], cfg.vocab)


def cache_spec(cfg: ModelConfig, batch: int, seq: int):
    """Recurrent state 'cache' — O(1) in seq (this is the long_500k story)."""
    d_inner, dh, dqk = _dims(cfg)
    period = 8
    n_p, n_m, h, d = cfg.n_layers // period, period - 1, cfg.n_heads, cfg.d_model
    f32 = jnp.float32
    spec = {
        "mC": jax.ShapeDtypeStruct((n_p, n_m, batch, h, dqk, dh), f32),
        "mn": jax.ShapeDtypeStruct((n_p, n_m, batch, h, dqk), f32),
        "mm": jax.ShapeDtypeStruct((n_p, n_m, batch, h), f32),
        "sc": jax.ShapeDtypeStruct((n_p, batch, d), f32),
        "sn": jax.ShapeDtypeStruct((n_p, batch, d), f32),
        "sm": jax.ShapeDtypeStruct((n_p, batch, d), f32),
    }
    axes = {
        "mC": ("layers", "stack", "batch", "heads", None, "lru"),
        "mn": ("layers", "stack", "batch", "heads", None),
        "mm": ("layers", "stack", "batch", "heads"),
        "sc": ("layers", "batch", "mlp"),
        "sn": ("layers", "batch", "mlp"),
        "sm": ("layers", "batch", "mlp"),
    }
    return spec, axes


def init_cache(cfg: ModelConfig, batch: int, seq: int):
    spec, axes = cache_spec(cfg, batch, seq)
    z = {k: jnp.zeros(v.shape, v.dtype) for k, v in spec.items()}
    z["mm"] = jnp.full(z["mm"].shape, -1e30, jnp.float32)
    z["sm"] = jnp.full(z["sm"].shape, -1e30, jnp.float32)
    return z, axes


def _state_of(cache, kind):
    if kind == "m":
        return (cache["mC"], cache["mn"], cache["mm"])
    return (cache["sc"], cache["sn"], cache["sm"])


def prefill(params, cfg: ModelConfig, tokens, *, chunk: int = 512,
            cache_len: int | None = None, length=None):
    """Run the prompt through the recurrence, collecting final states.
    ``cache_len`` is irrelevant: the state is O(1) in sequence length.
    ``length`` must be None (PAD_PREFILL is False — exact-length prompts)."""
    assert length is None, "xlstm prefill does not support padded prompts"
    b, s = tokens.shape
    x = L.embed_tokens(params["embed"], tokens).astype(cfg.jnp_dtype)

    def body(x, p_period):
        x, m_stack, s_state = _period_fwd(p_period, x, cfg)
        return x, (m_stack, s_state)

    x, (m_all, s_all) = lax.scan(body, x, params["periods"])
    cache = {"mC": m_all[0], "mn": m_all[1], "mm": m_all[2],
             "sc": s_all[0], "sn": s_all[1], "sm": s_all[2]}
    x = L.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return L.unembed(x[:, 0], params["lm_head"]), cache


def decode_step(params, cfg: ModelConfig, cache, token, pos, *,
                seq_shard_axis=None):
    x = L.embed_tokens(params["embed"], token[:, None]).astype(cfg.jnp_dtype)

    def body(x, inp):
        p_period, mC, mn, mm, sc, sn, sm = inp
        x, m_stack, s_state = _period_fwd(p_period, x, cfg,
                                          (mC, mn, mm), (sc, sn, sm))
        return x, (m_stack, s_state)

    x, (m_all, s_all) = lax.scan(
        body, x, (params["periods"], cache["mC"], cache["mn"], cache["mm"],
                  cache["sc"], cache["sn"], cache["sm"]))
    new_cache = {"mC": m_all[0], "mn": m_all[1], "mm": m_all[2],
                 "sc": s_all[0], "sn": s_all[1], "sm": s_all[2]}
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(x[:, 0], params["lm_head"]), new_cache
