"""Synthetic, deterministic, restartable data pipeline.

Every batch is a pure function of (seed, step, shard) — so a restarted job
resumes mid-epoch from the checkpointed cursor with NO data loss or
duplication, and elastic re-sharding (different host count after restart)
re-partitions the same global stream. A background prefetch thread keeps
`prefetch` batches ready (host-side overlap with device compute).
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class Cursor:
    """Checkpointable pipeline position."""
    seed: int
    step: int

    def to_dict(self):
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_dict(cls, d):
        return cls(int(d["seed"]), int(d["step"]))


def _batch_np(cfg: ModelConfig, batch: int, seq: int, seed: int, step: int,
              shard: int = 0, n_shards: int = 1):
    """Deterministic synthetic batch for (seed, step, shard)."""
    rng = np.random.default_rng(np.uint64(seed * 1_000_003 + step) * 131
                                + np.uint64(shard))
    local = batch // n_shards
    tokens = rng.integers(0, cfg.vocab, (local, seq), dtype=np.int32)
    out = {"tokens": tokens, "labels": np.roll(tokens, -1, axis=1)}
    if cfg.frontend == "frames":
        out["frames"] = rng.standard_normal(
            (local, seq, cfg.d_model)).astype(np.float32)
    return out


class Pipeline:
    """Sharded, prefetching, restartable loader."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, *,
                 seed: int = 0, start_step: int = 0, shard: int = 0,
                 n_shards: int = 1, prefetch: int = 2):
        self.cfg, self.batch, self.seq = cfg, batch, seq
        self.cursor = Cursor(seed, start_step)
        self.shard, self.n_shards = shard, n_shards
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        step = self.cursor.step
        while not self._stop.is_set():
            b = _batch_np(self.cfg, self.batch, self.seq, self.cursor.seed,
                          step, self.shard, self.n_shards)
            while not self._stop.is_set():
                try:
                    self._q.put((step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self):
        step, b = self._q.get()
        self.cursor.step = step + 1
        return {k: jnp.asarray(v) for k, v in b.items()}

    def close(self):
        self._stop.set()

    # restart support ------------------------------------------------------
    def state_dict(self):
        return self.cursor.to_dict()

    @classmethod
    def restore(cls, cfg, batch, seq, state, **kw):
        c = Cursor.from_dict(state)
        return cls(cfg, batch, seq, seed=c.seed, start_step=c.step, **kw)
