"""Logical-axis sharding rules (MaxText-style).

Every parameter/activation is annotated with a tuple of *logical* axis
names; this module maps logical axes to physical mesh axes. The same model
code runs on the single-pod ``(data, model)`` mesh, the multi-pod
``(pod, data, model)`` mesh, or one CPU device (all rules become None).

Physical strategy:
  * FSDP/ZeRO-3: parameter "embed"-like axes shard over ``data`` (and
    ``pod`` composes with ``data`` for batch / FSDP at multi-pod scale).
  * TP: head / mlp / vocab / expert axes shard over ``model``.
  * SP (decode): the KV-cache sequence axis shards over ``model`` —
    consumed by the split-KV merge path in ``kernels/flash_decode.py``
    (the contiguous-cache alternative; the paged serving plan in
    ``repro.sharding.tp`` shards heads instead, which keeps streams
    bit-identical).

A rule is skipped (axis replicated) when the dim is not divisible by the
mesh axis size — e.g. qwen2's 14 heads or yi's 56 heads on a 16-way model
axis; the MLP/vocab axes still shard. Per-arch divisibility notes live in
``docs/ARCHITECTURE.md`` (Sharded serving).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred physical mesh axes, tried in order.
RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod+data", "data"),
    "embed": ("data",),          # FSDP
    "vocab": ("model",),
    "embed_vocab": (),           # embedding table vocab axis: replicated so
                                 # the token gather stays device-local
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),       # EP
    "expert_mlp": (),
    "kv_seq": ("model",),        # SP decode (split-KV + merge kernel)
    "seq": (),
    "layers": (),
    "head_dim": (),
    "lru": ("model",),
    "conv": (),
    "stack": (),
}


def _resolve(logical: str | None, dim: int, mesh: Mesh):
    if logical is None:
        return None
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for cand in RULES.get(logical, ()):
        if cand == "pod+data":
            names = tuple(n for n in ("pod", "data") if n in axis_sizes)
            if not names:
                continue
            total = int(np.prod([axis_sizes[n] for n in names]))
            if dim % total == 0:
                return names if len(names) > 1 else names[0]
        elif cand in axis_sizes and dim % axis_sizes[cand] == 0:
            return cand
    return None


def spec_for(logical_axes: tuple, shape: tuple, mesh: Mesh) -> P:
    """PartitionSpec for an array with the given logical axes and shape."""
    assert len(logical_axes) == len(shape), (logical_axes, shape)
    used: set[str] = set()
    out = []
    for logical, dim in zip(logical_axes, shape):
        r = _resolve(logical, dim, mesh)
        flat = r if isinstance(r, tuple) else ((r,) if r else ())
        if any(a in used for a in flat):
            r = None                      # a mesh axis can appear only once
        used.update(flat)
        out.append(r)
    return P(*out)


def sharding_for(logical_axes: tuple, shape: tuple, mesh: Mesh):
    """NamedSharding for ``shape`` under the resolved logical-axis spec."""
    return NamedSharding(mesh, spec_for(logical_axes, shape, mesh))


def tree_shardings(params, axes_tree, mesh: Mesh):
    """NamedSharding tree matching ``params`` from a logical-axes tree.

    Works on both concrete arrays and ShapeDtypeStruct stand-ins. The axes
    tree has the same dict structure as ``params`` with tuple-of-logical-
    axis-names leaves (tuples are themselves pytrees, hence flatten_up_to).
    """
    flat_p, treedef = jax.tree.flatten(params)
    flat_ax = treedef.flatten_up_to(axes_tree)
    flat_s = [sharding_for(ax, p.shape, mesh)
              for p, ax in zip(flat_p, flat_ax)]
    return jax.tree.unflatten(treedef, flat_s)


def batch_spec(mesh: Mesh, *trailing) -> P:
    """PartitionSpec for [batch, ...] activations: batch over pod+data."""
    names = tuple(n for n in ("pod", "data") if n in mesh.axis_names)
    lead = names if len(names) > 1 else (names[0] if names else None)
    return P(lead, *trailing)
