"""Tensor-parallel serving plan over the ``(data, model)`` mesh.

The serving engine's donated programs (decode step, prefill admission,
swap-restore, CoW page copy) are wrapped in ``shard_map`` over the mesh
from ``launch/mesh.py``; this module holds everything those wrapped
bodies need:

* ``Plan`` / ``make_plan`` — which logical axes actually shard, resolved
  through the divisibility-gated rules in :mod:`repro.sharding.rules`
  (``heads``/``kv_heads``/``mlp``/``vocab`` over ``model``; the slot
  batch over ``data``). Non-divisible head counts fall back to
  replicated heads with the MLP/vocab axes still sharded — the rules'
  documented fallback, exercised by qwen2's 2 smoke / 14 full heads.
* ``shard_params`` / ``param_specs`` / ``kv_spec`` — physical placement
  of the dense-family weight tree and the paged KV pool. The fused
  gate/up projection is column-pre-permuted so each model shard holds
  its own ``(gate_m, up_m)`` pair and ``silu_and_mul`` splits locally.
* ``active`` / ``current`` — a trace-time context: the engine enters
  the plan inside the ``shard_map`` body, so the *unchanged* model code
  in :mod:`repro.models` sees it while tracing and routes through the
  gather helpers below. With no active plan every helper is the
  identity, so single-device jaxprs are byte-identical to before.
* ``gather_heads`` / ``gather_mlp`` / ``gather_vocab`` /
  ``gather_data`` / ``data_shard`` — the collective hooks. Every
  cross-device exchange is an **all-gather** (never a psum): partial
  results are concatenated, not summed, so the sharded computation is
  bitwise identical to the single-device one in the engine's bf16
  compute dtype (asserted end-to-end by ``tools/sharded_check.py``).
  The split-KV LSE-merge path in ``kernels/flash_decode.py`` stays the
  contiguous-cache ``shard_map``/pmap alternative; its psum combiner is
  not bit-exact, which is why the paged serving plan shards heads, not
  ``kv_seq``.

See ``docs/ARCHITECTURE.md`` (Sharded serving) for the full design,
including the per-arch divisibility table.
"""

from __future__ import annotations

import contextlib
import dataclasses

import jax
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.rules import _resolve


@dataclasses.dataclass(frozen=True)
class Plan:
    """Resolved sharding plan for one engine instance.

    ``heads``/``mlp``/``vocab`` say whether that logical axis shards
    over ``model``; ``batch`` whether the slot axis shards over
    ``data``. All False degenerates to fully replicated execution
    (still correct, still bit-identical)."""

    mesh: Mesh
    data: int
    model: int
    heads: bool
    mlp: bool
    vocab: bool
    batch: bool

    def describe(self) -> dict:
        """Stats-friendly summary (surfaced by ``Engine.stats()``)."""
        return {"data": self.data, "model": self.model,
                "heads_tp": self.heads, "mlp_tp": self.mlp,
                "vocab_tp": self.vocab, "batch_dp": self.batch}


def make_plan(cfg, mesh: Mesh, slots: int) -> Plan:
    """Resolve ``cfg``'s logical axes against ``mesh`` via the rules.

    Heads shard only when *both* ``n_heads`` and ``n_kv_heads`` divide
    the model axis: the GQA query groups are kv-major, so a contiguous
    query-head shard lines up with its kv-head shard — one without the
    other would split groups. MLP/vocab resolve independently (the
    documented replicated-heads fallback). The slot batch shards over
    ``data`` when it divides; weights and the KV pool stay replicated
    over ``data`` — serving has no gradient reduce, so FSDP's
    ``embed``→``data`` rule is deliberately not applied here.
    """
    if cfg.family != "dense":
        raise ValueError(
            f"mesh serving supports the dense family only (got "
            f"{cfg.family!r}: per-slot-coupled or stateful decode)")
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if "model" not in axis_sizes or "data" not in axis_sizes:
        raise ValueError(f"mesh must carry ('data', 'model') axes, got "
                         f"{mesh.axis_names}")
    data, model = axis_sizes["data"], axis_sizes["model"]
    heads = (_resolve("heads", cfg.n_heads, mesh) == "model"
             and _resolve("kv_heads", cfg.n_kv_heads, mesh) == "model")
    mlp = _resolve("mlp", cfg.d_ff, mesh) == "model"
    vocab = _resolve("vocab", cfg.padded_vocab, mesh) == "model"
    batch = data > 1 and slots % data == 0
    return Plan(mesh=mesh, data=data, model=model, heads=heads,
                mlp=mlp, vocab=vocab, batch=batch)


# ---------------------------------------------------------------------------
# physical placement
# ---------------------------------------------------------------------------

def param_specs(params: dict, plan: Plan) -> dict:
    """PartitionSpec tree for the dense-family weight layout.

    Matches the serving collectives: sharded projections produce local
    partials that are all-gathered *before* the replicated consumer
    (``wo``, ``w_down``), so those stay replicated. The embedding is
    replicated too — the token gather must be device-local.
    """
    h = "model" if plan.heads else None
    attn = {"wq": P(None, None, h, None), "wk": P(None, None, h, None),
            "wv": P(None, None, h, None), "wo": P()}
    attn_tree = params["layers"]["attn"]
    if "bq" in attn_tree:
        attn["bq"] = P(None, h, None)
        attn["bk"] = P(None, h, None)
        attn["bv"] = P(None, h, None)
    if "q_norm" in attn_tree:
        attn["q_norm"] = P()
        attn["k_norm"] = P()
    mlp = {"w_gateup": P(None, None, "model" if plan.mlp else None),
           "w_down": P()}
    return {"embed": P(),
            "layers": {"attn": attn, "mlp": mlp,
                       "attn_norm": P(), "mlp_norm": P()},
            "final_norm": P(),
            "lm_head": P(None, "model" if plan.vocab else None)}


def kv_spec(plan: Plan) -> P:
    """Spec for any KV tensor whose axis 3 is ``kv_heads`` — the paged
    pool ``[L, pages, page, Hkv, dh]``, gathered page reads, and the
    contiguous swap payload ``[L, B, S, Hkv, dh]`` all share it.
    (``rules.spec_for`` can't be used for the contiguous layout: its
    one-axis-per-mesh-axis dedup would hand ``model`` to ``kv_seq``
    first; the serving plan shards heads, never ``kv_seq``.) Trailing
    ``None`` entries are dropped — shard_map outputs carry the
    normalized spec, and the initial ``device_put`` must produce the
    *same* sharding object or donated round-trips retrace."""
    return P(None, None, None, "model") if plan.heads else P()


def kv_specs(plan: Plan) -> dict:
    """``{"k", "v"}`` spec tree matching the cache pytrees."""
    s = kv_spec(plan)
    return {"k": s, "v": s}


def _put(tree, specs, mesh: Mesh):
    """device_put ``tree`` with a matching PartitionSpec tree (specs are
    tuples, hence the flatten_up_to dance — same as rules.tree_shardings)."""
    flat, treedef = jax.tree.flatten(tree)
    flat_s = treedef.flatten_up_to(specs)
    out = [jax.device_put(x, NamedSharding(mesh, s))
           for x, s in zip(flat, flat_s)]
    return jax.tree.unflatten(treedef, out)


def gateup_permutation(d_ff: int, model: int) -> np.ndarray:
    """Column permutation putting ``(gate_m, up_m)`` on model shard m.

    ``w_gateup [D, 2F]`` fuses gate columns ``[0, F)`` and up columns
    ``[F, 2F)``; naive column sharding would hand shard 0 gate-only
    columns. The permuted order is pure column movement, so gathering
    the per-shard ``silu_and_mul`` outputs restores the original column
    order bitwise (``gather_mlp``)."""
    fl = d_ff // model
    return np.concatenate([
        np.r_[m * fl:(m + 1) * fl, d_ff + m * fl:d_ff + (m + 1) * fl]
        for m in range(model)])


def shard_params(params: dict, cfg, plan: Plan) -> dict:
    """Place the weight tree on the mesh per ``param_specs`` (permuting
    the fused gate/up columns when the MLP axis shards)."""
    if plan.mlp:
        perm = gateup_permutation(cfg.d_ff, plan.model)
        wg = jax.numpy.take(params["layers"]["mlp"]["w_gateup"],
                            jax.numpy.asarray(perm), axis=-1)
        layers = dict(params["layers"])
        layers["mlp"] = dict(layers["mlp"], w_gateup=wg)
        params = dict(params, layers=layers)
    return _put(params, param_specs(params, plan), plan.mesh)


def put_cache(cache, plan: Plan):
    """Place a (freshly built) KV cache pytree on the mesh."""
    return _put(cache, kv_specs(plan), plan.mesh)


def replicate(x, plan: Plan):
    """Place a carry buffer fully replicated on the mesh (required so
    donated carries round-trip with a consistent committed sharding)."""
    return jax.device_put(x, NamedSharding(plan.mesh, P()))


# ---------------------------------------------------------------------------
# trace-time plan context + collective hooks
# ---------------------------------------------------------------------------

_ACTIVE: Plan | None = None


@contextlib.contextmanager
def active(plan: Plan):
    """Make ``plan`` visible to the model code being traced. Entered
    *inside* the shard_map body (i.e. during jit tracing), so the hooks
    below run with the mesh axes in scope."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, plan
    try:
        yield
    finally:
        _ACTIVE = prev


def current() -> Plan | None:
    """The plan being traced under, or None (single-device paths)."""
    return _ACTIVE


def gather_heads(o):
    """All-gather attention outputs ``[B, S, H_local, dh]`` over
    ``model`` before the replicated ``wo`` contraction. Identity when
    heads are replicated (fallback) or no plan is active."""
    p = _ACTIVE
    if p is None or not p.heads:
        return o
    return lax.all_gather(o, "model", axis=2, tiled=True)


def gather_mlp(h):
    """All-gather ``silu_and_mul`` outputs ``[..., F_local]`` over
    ``model`` before the replicated down projection. The gate/up column
    pre-permutation makes this concatenation restore the original
    column order exactly."""
    p = _ACTIVE
    if p is None or not p.mlp:
        return h
    return lax.all_gather(h, "model", axis=h.ndim - 1, tiled=True)


def gather_vocab(logits):
    """All-gather vocab-sharded logits ``[..., V_local]`` over
    ``model`` — argmax/sampling and the ``[:, :vocab]`` slice need the
    full (padded) vocabulary in original order."""
    p = _ACTIVE
    if p is None or not p.vocab:
        return logits
    return lax.all_gather(logits, "model", axis=logits.ndim - 1,
                          tiled=True)


def data_shard(x, axis: int = 0):
    """Slice the slot-batch axis down to this data shard's rows.
    Identity when the batch is replicated over ``data`` (non-divisible
    slot count, data=1, prefill's batch of one, or no plan)."""
    p = _ACTIVE
    if p is None or not p.batch or x.shape[axis] % p.data != 0:
        return x
    shard = x.shape[axis] // p.data
    return lax.dynamic_slice_in_dim(
        x, lax.axis_index("data") * shard, shard, axis=axis)


def gather_data(x, axis: int = 0):
    """All-gather ``data``-sharded per-slot values back to the full
    slot axis (the decode step's single cross-``data`` exchange: the
    new KV rows for the replicated pool write, and the per-slot token).
    Identity when the batch is replicated over ``data``."""
    p = _ACTIVE
    if p is None or not p.batch:
        return x
    return lax.all_gather(x, "data", axis=axis, tiled=True)


def wrap(plan: Plan, fn, in_specs, out_specs, donate_argnums=()):
    """``jit(shard_map(fn))`` with the plan entered inside the body.

    ``check_rep=False`` everywhere: replicated ``P()`` outputs are
    genuinely identical on every device (they are all-gather results or
    elementwise functions of replicated inputs), but shard_map's
    replication checker cannot see through the gather pattern."""
    def body(*args):
        with active(plan):
            return fn(*args)

    sm = shard_map(body, mesh=plan.mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    return jax.jit(sm, donate_argnums=donate_argnums)
