"""Logical-axis partitioning rules (DP/FSDP/TP/EP/SP) and the serving
tensor-parallel plan (``repro.sharding.tp``)."""
from repro.sharding import tp
from repro.sharding.rules import (batch_spec, sharding_for, spec_for,
                                  tree_shardings)
