"""Logical-axis partitioning rules (DP/FSDP/TP/EP/SP)."""
from repro.sharding.rules import (batch_spec, sharding_for, spec_for,
                                  tree_shardings)
