"""Sharded, restartable, mesh-shape-agnostic checkpointing.

Format: one directory per step —
    step_000123.tmp/         (written first)
        manifest.json        tree structure, shapes, dtypes, step, cursor
        arrays.npz           logically-GLOBAL arrays, one entry per leaf
    step_000123/             (atomic rename = commit)

Properties needed at 1000+-node scale, kept here in single-host form:
  * atomic commit (rename) — a crash mid-save never corrupts the latest
    checkpoint; restore always picks the newest COMMITTED step;
  * mesh-shape agnostic — arrays are stored global, restore re-shards to
    whatever mesh the restarted job has (elastic scaling);
  * async save — device->host gather + file write run on a background
    thread, training continues (`wait()` joins before the next save);
  * data-pipeline cursor saved with the model so restarts are exactly-once.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading

import jax
import numpy as np

_SEP = "/"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        named[key] = leaf
    return named, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, extra: dict | None = None, *,
             blocking: bool = False):
        """Snapshot ``tree`` (+ json-serializable ``extra``) at ``step``."""
        named, _ = _flatten(tree)
        # gather to host NOW (cheap np views for committed arrays) so the
        # background thread sees a consistent snapshot
        host = {k: np.asarray(v) for k, v in named.items()}
        self.wait()

        def _write():
            tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
            final = os.path.join(self.dir, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **host)
            manifest = {
                "step": step,
                "extra": extra or {},
                "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                           for k, v in host.items()},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)           # atomic commit
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.committed_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def committed_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def restore(self, template_tree, step: int | None = None,
                shardings=None):
        """Restore into the structure of ``template_tree``. Arrays are
        device_put with ``shardings`` (same tree structure) when given —
        this is where elastic re-meshing happens. Returns (tree, extra,
        step)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        named, treedef = _flatten(template_tree)
        flat_shard = (None if shardings is None
                      else _flatten(shardings)[0])
        leaves = {}
        for key in named:
            arr = data[key]
            if flat_shard is not None:
                arr = jax.device_put(arr, flat_shard[key])
            leaves[key] = arr
        restored = jax.tree_util.tree_unflatten(
            treedef, [leaves[k] for k in named])
        return restored, manifest["extra"], step
