"""The jit-able training step: microbatched grad accumulation, optional
gradient compression with error feedback, AdamW update.

Microbatches run as a ``lax.scan`` so live activation memory is one
microbatch deep regardless of global batch (the remat policy inside the
models keeps each layer's activations transient too).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import registry
from repro.training import compression, optimizer as opt


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    compress_grads: bool = False
    adamw: opt.AdamWConfig = dataclasses.field(default_factory=opt.AdamWConfig)
    # mesh axes carrying the batch dim (e.g. ("pod", "data")). The reshape
    # [B, ...] -> [M, B/M, ...] makes GSPMD drop the batch sharding and
    # replicate every activation; constraining the split tensor keeps the
    # microbatch scan data-parallel. (§Perf iteration 2.)
    batch_axes: tuple = ()
    # Cast >=2-D params once per step BEFORE the microbatch scan: the
    # per-layer FSDP all-gathers then move bf16 instead of fp32 master
    # weights — halves the dominant collective payload AND the gathered-
    # weight working set. Grads accumulate in fp32. (§Perf hillclimb B.)
    cast_params: str | None = "bfloat16"


def init_state(cfg: ModelConfig, tcfg: TrainConfig, params):
    state = {"opt": opt.init(params)}
    if tcfg.compress_grads:
        state["err_fb"] = jax.tree.map(
            lambda p: jnp.zeros_like(p, jnp.float32), params)
    return state


def state_logical_axes(tcfg: TrainConfig, param_axes):
    st = {"opt": opt.state_axes(param_axes)}
    if tcfg.compress_grads:
        st["err_fb"] = param_axes
    return st


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    param_shardings=None):
    """Returns train_step(params, state, batch) -> (params, state, metrics).

    ``param_shardings`` (a NamedSharding tree matching params) pins the
    bf16 compute copy and the fp32 gradient accumulator to the FSDP param
    layout: without the pin GSPMD replicates the accumulator, turning each
    microbatch's gradient sync into a full fp32 all-reduce instead of a
    sharded reduce-scatter (~32x the bytes on the wire; §Perf hillclimb B).
    """

    def pin(tree):
        if param_shardings is None:
            return tree
        return jax.tree.map(lax.with_sharding_constraint, tree,
                            param_shardings)

    def grads_of(params, batch):
        if tcfg.cast_params:
            dt = jnp.dtype(tcfg.cast_params)
            params = pin(jax.tree.map(
                lambda p: p.astype(dt)
                if p.dtype == jnp.float32 and p.ndim >= 2 else p, params))
        m = tcfg.microbatches
        if m <= 1:
            loss, grads = jax.value_and_grad(registry.loss_fn)(
                params, cfg, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            return loss, pin(grads)

        def split(x):
            x = x.reshape(m, x.shape[0] // m, *x.shape[1:])
            if tcfg.batch_axes:
                spec = jax.sharding.PartitionSpec(
                    None, tcfg.batch_axes, *([None] * (x.ndim - 2)))
                x = lax.with_sharding_constraint(x, spec)
            return x

        micro = jax.tree.map(split, batch)
        zeros = pin(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))

        def body(carry, mb):
            gsum, lsum = carry
            loss, g = jax.value_and_grad(registry.loss_fn)(params, cfg, mb)
            gsum = pin(jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), gsum, g))
            return (gsum, lsum + loss), None

        (gsum, lsum), _ = lax.scan(body, (zeros, 0.0), micro)
        grads = jax.tree.map(lambda g: g / m, gsum)
        return lsum / m, grads

    def train_step(params, state, batch):
        loss, grads = grads_of(params, batch)
        if tcfg.compress_grads:
            grads, err = compression.compress_grads(grads, state["err_fb"])
        new_params, new_opt, metrics = opt.update(
            tcfg.adamw, params, grads, state["opt"])
        metrics["loss"] = loss
        new_state = {"opt": new_opt}
        if tcfg.compress_grads:
            new_state["err_fb"] = err
        return new_params, new_state, metrics

    return train_step
