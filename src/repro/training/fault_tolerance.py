"""Fault tolerance: restart-on-failure, straggler watchdog, heartbeats,
failure injection for tests.

The control plane is deliberately simple and file-based (what actually
survives at cluster scale): a committed-checkpoint directory is the only
source of truth; any worker can die at any point and the relaunched job
reconstructs (params, optimizer, data cursor) from the last commit and
re-shards to the CURRENT mesh (elastic scaling — see
``checkpoint.Checkpointer.restore``).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

from repro.reliability import Fault, FaultSchedule


class FailureInjector:
    """Deterministic fault injection for tests/drills: raises at a chosen
    step, once. Thin wrapper over the shared ``repro.reliability``
    schedule that the serving chaos harness also builds on."""

    def __init__(self, fail_at_step: int | None = None):
        self.fail_at_step = fail_at_step
        faults = ([] if fail_at_step is None
                  else [Fault(kind="raise", step=fail_at_step)])
        self._schedule = FaultSchedule(faults)

    @property
    def fired(self) -> bool:
        return self._schedule.fired > 0

    def maybe_fail(self, step: int):
        if self._schedule.due(step):
            raise RuntimeError(f"injected failure at step {step}")


@dataclasses.dataclass
class StragglerWatchdog:
    """Flags steps slower than `threshold` x the running median.

    At scale the mitigation hooks here are: re-shard around the slow host
    (elastic restart) or skip its contribution for the step; single-host we
    record + report, and the training loop can trigger a checkpoint+restart
    when `consecutive_limit` is hit.
    """
    threshold: float = 3.0
    consecutive_limit: int = 5
    history: list = dataclasses.field(default_factory=list)
    consecutive: int = 0
    flagged_steps: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, seconds: float) -> bool:
        self.history.append(seconds)
        window = sorted(self.history[-64:])
        median = window[len(window) // 2]
        slow = len(self.history) > 4 and seconds > self.threshold * median
        if slow:
            self.flagged_steps.append(step)
            self.consecutive += 1
        else:
            self.consecutive = 0
        return slow

    @property
    def should_restart(self) -> bool:
        return self.consecutive >= self.consecutive_limit


class Heartbeat:
    """Liveness file a cluster supervisor would watch."""

    def __init__(self, path: str):
        self.path = path

    def beat(self, step: int):
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "t": time.time()}, f)
        os.replace(tmp, self.path)

    def last(self):
        try:
            with open(self.path) as f:
                return json.load(f)
        except FileNotFoundError:
            return None


def run_with_restarts(make_fn, *, max_restarts: int = 3, on_restart=None):
    """Run ``make_fn()`` (a full training run that may raise); on failure,
    call it again — it is expected to resume from the latest committed
    checkpoint. Returns the run's result."""
    attempt = 0
    while True:
        try:
            return make_fn()
        except Exception as e:  # noqa: BLE001 — any worker death
            attempt += 1
            if attempt > max_restarts:
                raise
            if on_restart is not None:
                on_restart(attempt, e)
