"""AdamW in pure JAX with FSDP-sharded state.

Optimizer moments inherit the parameter sharding (ZeRO-style: whatever a
param shards to, its m/v shard to), so optimizer memory scales down with
the mesh. fp32 master weights; the model casts to compute dtype per-use.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return OptState(jnp.zeros((), jnp.int32), zeros,
                    jax.tree.map(jnp.copy, zeros))


def state_axes(param_axes) -> OptState:
    """Logical axes for the optimizer state (mirrors the params)."""
    return OptState((), param_axes, jax.tree.map(
        lambda ax: ax, param_axes, is_leaf=lambda x: isinstance(x, tuple)))


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_frac."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def clip_by_global_norm(grads, max_norm):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def update(cfg: AdamWConfig, params, grads, state: OptState):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        p32 = p.astype(jnp.float32)
        new_p = p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                            + cfg.weight_decay * p32)
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_params, OptState(step, new_m, new_v), \
        {"lr": lr, "grad_norm": gnorm}
