"""Gradient compression for the cross-pod (DCN) all-reduce.

At multi-pod scale the inter-pod links are the slow hop; int8 block-scaled
quantization cuts the cross-pod gradient payload 4x. Under GSPMD the
all-reduce is implicit, so the compression is applied as a
quantize-dequantize stage on the gradients inside train_step (numerically
identical to compressing the wire format of the pod-level reduce: values
round-trip through int8 + per-block fp32 scales). Error feedback keeps the
quantization bias from accumulating across steps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize(x: jax.Array):
    """x -> (int8 payload, per-block scales). Pads the flat view to BLOCK."""
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    q = jnp.round(flat / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale, n


def dequantize(q, scale, n, shape):
    out = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    return out.reshape(shape)


def compress_grads(grads, error=None):
    """Quantize-dequantize every gradient leaf with error feedback.

    Returns (grads_after_wire, new_error). ``error`` carries the residual
    e_t = g_t - Q(g_t + e_{t-1}) to the next step.
    """
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error) if error is not None \
        else [jnp.zeros_like(g, dtype=jnp.float32) for g in flat_g]
    outs, errs = [], []
    for g, e in zip(flat_g, flat_e):
        target = g.astype(jnp.float32) + e
        q, s, n = quantize(target)
        deq = dequantize(q, s, n, g.shape)
        outs.append(deq.astype(g.dtype))
        errs.append(target - deq)
    return jax.tree.unflatten(tdef, outs), jax.tree.unflatten(tdef, errs)


def wire_bytes(grads) -> int:
    """Payload size of the compressed format (int8 + fp32 scales)."""
    total = 0
    for g in jax.tree.leaves(grads):
        n = g.size
        blocks = -(-n // BLOCK)
        total += n + 4 * blocks
    return total
