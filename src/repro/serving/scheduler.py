"""Scheduling layer of the serving API: pluggable admission policies.

The engine consults a ``Scheduler`` for *which waiting request to admit
next*; everything else (slot residency, preemption mechanics, the fused
decode step) stays in the engine. The protocol is deliberately small:

    push(req)      new submission
    requeue(req)   a preempted victim comes back with precedence
    peek()         the next request to admit (None when empty) — admission
                   is head-of-line: if the cache manager cannot hold
                   ``peek()`` yet, the engine waits rather than skipping it
    pop()          commit the admission of ``peek()``
    remove(req)    pull one waiting request out of line (abort/deadline);
                   identity comparison, True when found
    waiting()      snapshot list of waiting requests (no order guarantee)
    __len__        waiting-request count
    stats()        {"scheduler", "sched_admitted", "sched_reorders"}

``sched_reorders`` counts pops that were NOT the oldest waiting request —
0 under FCFS by construction, and an exact, deterministic counter the
bench-regression gate pins for the priority scenario.

``FCFSScheduler`` reproduces the historical engine's deque byte-for-byte
(append / appendleft / popleft), so greedy FCFS streams stay bit-identical
to the committed goldens. ``PriorityScheduler`` and ``SJFScheduler`` sort
waiting requests (higher ``Request.priority`` first / shortest estimated
job first), with requeued victims keeping precedence in the same
most-recent-first order the FCFS deque gives them.

``PreemptionPolicy`` is the companion protocol for *who* gets evicted when
the paged pool runs dry and *what happens to their KV*: the historical
youngest-victim swap and recompute modes are its two implementations.
"""

from __future__ import annotations

from collections import deque
from typing import Protocol, runtime_checkable


@runtime_checkable
class Scheduler(Protocol):
    """Admission-order policy surface consumed by the engine."""

    name: str

    def push(self, req) -> None: ...
    def requeue(self, req) -> None: ...
    def peek(self): ...
    def pop(self): ...
    def remove(self, req) -> bool: ...
    def waiting(self) -> list: ...
    def __len__(self) -> int: ...
    def stats(self) -> dict: ...


class _BaseScheduler:
    name = "base"

    def __init__(self):
        self.admitted = 0
        self.reorders = 0

    def _note_pop(self, req, waiting) -> None:
        self.admitted += 1
        oldest = min(r.arrival for r in waiting)
        if req.arrival != oldest:
            self.reorders += 1

    def waiting(self) -> list:
        """Snapshot of the waiting set (both backends keep it in _q)."""
        return list(self._q)

    def remove(self, req) -> bool:
        """Pull ``req`` out of line by IDENTITY (see ``pop`` for why
        equality comparison is off the table); True when found. Used by
        abort/deadline expiry — does not count as an admission."""
        for i, r in enumerate(self._q):
            if r is req:
                del self._q[i]
                if getattr(req, "_requeue_seq", None) is not None:
                    req._requeue_seq = None
                return True
        return False

    def stats(self) -> dict:
        return {"scheduler": self.name, "sched_admitted": self.admitted,
                "sched_reorders": self.reorders}


class FCFSScheduler(_BaseScheduler):
    """First-come-first-served — the historical deque, bit-for-bit:
    submissions append, preempted victims go back to the FRONT (they keep
    their rank), admission pops the head."""
    name = "fcfs"

    def __init__(self):
        super().__init__()
        self._q: deque = deque()

    def push(self, req) -> None:
        self._q.append(req)

    def requeue(self, req) -> None:
        self._q.appendleft(req)

    def peek(self):
        return self._q[0] if self._q else None

    def pop(self):
        self._note_pop(self._q[0], self._q)
        return self._q.popleft()

    def __len__(self) -> int:
        return len(self._q)


class _SortedScheduler(_BaseScheduler):
    """Sorts waiting requests by ``_key``; ties broken by arrival.
    Requeued (preempted) requests sort before everything else, most recent
    requeue first — the same precedence the FCFS deque's ``appendleft``
    gives them, so swap-state victims re-enter promptly under any policy."""

    def __init__(self):
        super().__init__()
        self._q: list = []
        self._requeues = 0

    def _key(self, req) -> tuple:
        raise NotImplementedError

    def _full_key(self, req) -> tuple:
        seq = getattr(req, "_requeue_seq", None)
        if seq is not None:
            return (0, -seq)
        return (1,) + self._key(req) + (req.arrival,)

    def push(self, req) -> None:
        self._q.append(req)

    def requeue(self, req) -> None:
        self._requeues += 1
        req._requeue_seq = self._requeues
        self._q.append(req)

    def peek(self):
        return min(self._q, key=self._full_key) if self._q else None

    def pop(self):
        req = min(self._q, key=self._full_key)
        self._note_pop(req, self._q)
        # remove by IDENTITY: list.remove would compare Request dataclasses
        # field-by-field, and `ndarray == ndarray` on the prompt raises
        # (ambiguous truth value) as soon as two waiting requests share rid
        for i, r in enumerate(self._q):
            if r is req:
                del self._q[i]
                break
        if getattr(req, "_requeue_seq", None) is not None:
            req._requeue_seq = None
        return req

    def __len__(self) -> int:
        return len(self._q)


class PriorityScheduler(_SortedScheduler):
    """Highest ``Request.priority`` first; FCFS within a priority level."""
    name = "priority"

    def _key(self, req) -> tuple:
        return (-getattr(req, "priority", 0),)


class SJFScheduler(_SortedScheduler):
    """Shortest estimated job first: prompt length + requested new tokens
    (a static proxy for total pool residency); FCFS on ties."""
    name = "sjf"

    def _key(self, req) -> tuple:
        return (len(req.prompt) + req.max_new_tokens,)


SCHEDULERS = {"fcfs": FCFSScheduler, "priority": PriorityScheduler,
              "sjf": SJFScheduler}


def make_scheduler(policy) -> Scheduler:
    """Resolve a policy name or pass an instance through."""
    if policy is None:
        return FCFSScheduler()
    if isinstance(policy, str):
        try:
            return SCHEDULERS[policy]()
        except KeyError:
            raise ValueError(f"unknown scheduler {policy!r}; "
                             f"have {sorted(SCHEDULERS)}") from None
    return policy


# ---------------------------------------------------------------------------
# preemption policy
# ---------------------------------------------------------------------------

@runtime_checkable
class PreemptionPolicy(Protocol):
    """Who loses their pages when the pool runs dry, and what eviction
    does with their KV. ``mode`` is consumed by the engine's eviction
    mechanics: "swap" saves the victim's pages + device state to host for
    a byte-exact restore; "recompute" drops them and re-prefills
    prompt + generated prefix on re-admission (greedy-stable only)."""
    mode: str

    def select_victim(self, occupants) -> int: ...


class _YoungestVictim:
    """FCFS-fair eviction: the most recently admitted occupant loses.
    ``occupants`` is a list of ``(slot_index, request)`` pairs."""

    def select_victim(self, occupants) -> int:
        return max(occupants, key=lambda t: t[1].arrival)[0]


class SwapPreemption(_YoungestVictim):
    """Youngest victim, pages + device state swapped to host and restored
    byte-for-byte on re-admission — streams provably unchanged."""
    mode = "swap"


class RecomputePreemption(_YoungestVictim):
    """Youngest victim, pages dropped; re-admission re-prefills prompt +
    generated prefix (vLLM's recompute mode — cheaper in host memory, but
    only greedy-stable: a near-tied argmax can flip many steps later)."""
    mode = "recompute"


PREEMPTION_POLICIES = {"swap": SwapPreemption, "recompute":
                       RecomputePreemption}


def make_preemption(policy) -> PreemptionPolicy:
    """Resolve a policy name or pass an instance through."""
    if policy is None:
        return SwapPreemption()
    if isinstance(policy, str):
        try:
            return PREEMPTION_POLICIES[policy]()
        except KeyError:
            raise ValueError(f"unknown preemption policy {policy!r}; "
                             f"have {sorted(PREEMPTION_POLICIES)}") from None
    return policy
