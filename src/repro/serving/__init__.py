"""Layered continuous-batching serving stack.

Four public layers, vLLM/SGLang-style, over one device-resident core:

* ``SamplingParams`` (``repro.serving.sampling``) — greedy / temperature /
  top-k / top-p with a per-request seed, fused into the donated decode
  step.
* ``Scheduler`` / ``PreemptionPolicy`` (``repro.serving.scheduler``) —
  pluggable admission order (FCFS / priority / SJF) and eviction policy
  (youngest-victim swap / recompute).
* ``CacheManager`` (``repro.serving.cache_manager``) — the contiguous and
  paged KV layouts behind one alloc/write/grow/evict/restore surface.
* ``LLMEngine`` (``repro.serving.api``) — ``generate()`` / ``stream()``
  facade over the engine.
* ``ChaosInjector`` (``repro.serving.chaos``) — deterministic fault
  injection (device faults, pool exhaustion, corrupt readbacks, stalls,
  aborts) for exercising the request-lifecycle robustness layer.
* ``SpecConfig`` (``repro.serving.spec``) — speculative decoding fused
  into the device-resident step: an n-gram or small-draft-model drafter
  proposes ``k`` tokens, the target verifies all ``k + 1`` positions in
  one program, rejected KV writes land on the trap page; greedy streams
  stay bit-identical to target-only decoding.

``Engine`` is the execution core; ``ReferenceEngine`` is the host-driven
loop it is proven bit-identical against (greedy FCFS).
"""

from repro.serving.api import LLMEngine, RequestOutput, TokenEvent
from repro.serving.cache_manager import (CacheConfig, CacheManager,
                                         ContiguousCacheManager,
                                         PagedCacheManager)
from repro.serving.chaos import ChaosInjector, InjectedDeviceFault
from repro.serving.engine import Engine, Request
from repro.serving.reference import ReferenceEngine
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import (FCFSScheduler, PreemptionPolicy,
                                     PriorityScheduler, RecomputePreemption,
                                     Scheduler, SJFScheduler,
                                     SwapPreemption, make_preemption,
                                     make_scheduler)
from repro.serving.spec import (DraftModelDrafter, Drafter, NGramDrafter,
                                SpecConfig)

__all__ = [
    "CacheConfig", "CacheManager", "ChaosInjector",
    "ContiguousCacheManager", "DraftModelDrafter", "Drafter", "Engine",
    "FCFSScheduler", "InjectedDeviceFault", "LLMEngine",
    "NGramDrafter", "PagedCacheManager", "PreemptionPolicy",
    "PriorityScheduler", "RecomputePreemption", "ReferenceEngine",
    "Request", "RequestOutput", "SJFScheduler", "SamplingParams",
    "Scheduler", "SpecConfig", "SwapPreemption", "TokenEvent",
    "make_preemption", "make_scheduler",
]
