"""Continuous-batching serving: the device-resident engine and the
host-driven reference implementation it is proven bit-identical against."""

from repro.serving.engine import Engine, Request
from repro.serving.reference import ReferenceEngine

__all__ = ["Engine", "Request", "ReferenceEngine"]
