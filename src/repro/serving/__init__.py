"""Layered continuous-batching serving stack.

Four public layers, vLLM/SGLang-style, over one device-resident core:

* ``SamplingParams`` (``repro.serving.sampling``) — greedy / temperature /
  top-k / top-p with a per-request seed, fused into the donated decode
  step.
* ``Scheduler`` / ``PreemptionPolicy`` (``repro.serving.scheduler``) —
  pluggable admission order (FCFS / priority / SJF) and eviction policy
  (youngest-victim swap / recompute).
* ``CacheManager`` (``repro.serving.cache_manager``) — the contiguous and
  paged KV layouts behind one alloc/write/grow/evict/restore surface.
* ``LLMEngine`` (``repro.serving.api``) — ``generate()`` / ``stream()``
  facade over the engine.
* ``ChaosInjector`` (``repro.serving.chaos``) — deterministic fault
  injection (device faults, pool exhaustion, corrupt readbacks, stalls,
  aborts) for exercising the request-lifecycle robustness layer.

``Engine`` is the execution core; ``ReferenceEngine`` is the host-driven
loop it is proven bit-identical against (greedy FCFS).
"""

from repro.serving.api import LLMEngine, RequestOutput, TokenEvent
from repro.serving.cache_manager import (CacheConfig, CacheManager,
                                         ContiguousCacheManager,
                                         PagedCacheManager)
from repro.serving.chaos import ChaosInjector, InjectedDeviceFault
from repro.serving.engine import Engine, Request
from repro.serving.reference import ReferenceEngine
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import (FCFSScheduler, PreemptionPolicy,
                                     PriorityScheduler, RecomputePreemption,
                                     Scheduler, SJFScheduler,
                                     SwapPreemption, make_preemption,
                                     make_scheduler)

__all__ = [
    "CacheConfig", "CacheManager", "ChaosInjector",
    "ContiguousCacheManager", "Engine", "FCFSScheduler",
    "InjectedDeviceFault", "LLMEngine", "PagedCacheManager",
    "PreemptionPolicy", "PriorityScheduler", "RecomputePreemption",
    "ReferenceEngine", "Request", "RequestOutput", "SJFScheduler",
    "SamplingParams", "Scheduler", "SwapPreemption", "TokenEvent",
    "make_preemption", "make_scheduler",
]
