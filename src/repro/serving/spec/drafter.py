"""Drafting layer of the speculative-decoding subsystem.

A ``Drafter`` proposes ``k`` candidate next tokens per slot per decode
step; the engine's fused verify program scores all ``k + 1`` positions
with the target model in ONE donated dispatch, commits the longest
accepted prefix on-device, and routes every rejected position's KV
write to the paged pool's trap page. Two implementations:

``NGramDrafter`` — prompt-lookup self-drafting (no extra model): the
request's own prompt + emitted history is searched for the most recent
recurrence of its trailing n-gram, and the ``k`` tokens that followed
it are proposed. Stateless — nothing to snapshot, restore, or roll
back; host-side and exact under preemption by construction.

``DraftModelDrafter`` — a small dense draft model (e.g. qwen2-0.5b
drafting for qwen3-8b) with its own contiguous ``slots x max_seq`` KV
cache. Each step it runs ``k + 1`` greedy decode steps in one jitted,
donated scan (the extra step writes the last draft's KV row so an
all-accept verify leaves the draft cache complete), feeding the
target's committed carry token first, so its state mirrors the target
stream exactly on every accepted position. Rejection rollback is free:
stale rows past the committed position are masked by ``kv_len`` and
overwritten by the next scan before they can be read. Swap preemption
snapshots the victim's draft rows to host and restores them
byte-for-byte on re-admission; crash recovery resets the cache and
replays survivors from their snapshots.

Both proposers return target-vocab token ids; a wrong proposal is
never wrong *output* — the verify program only commits draft positions
whose token equals the target model's own argmax, so greedy spec
streams stay bitwise identical to target-only decoding.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.serving.spec.config import SpecConfig


class Drafter:
    """Proposal + state-management surface the engine drives.

    ``propose`` runs once per spec decode step and returns ``[slots, k]``
    candidate token ids (numpy or device array — the engine ships either
    to the verify program). The state hooks are no-ops for stateless
    drafters; ``stateful`` gates the engine's snapshot/restore plumbing
    so swap payloads don't grow a ``None`` tree per request.
    """

    stateful = False

    def propose(self, slots, token, pos):
        """``[slots, k]`` i32 proposals for the current decode carry."""
        raise NotImplementedError

    def prefill(self, slot, tokens) -> None:
        """Admission hook: process ``tokens`` (the full prompt, generated
        prefix included on recompute re-admission) into ``slot``'s
        drafter state."""

    def snapshot_slot(self, slot):
        """Host copy of ``slot``'s drafter state (swap-out), or None."""
        return None

    def restore_slot(self, slot, saved) -> None:
        """Write a ``snapshot_slot`` payload back (swap-in)."""

    def reset(self) -> None:
        """Drop all drafter state (device-fault crash recovery)."""


class NGramDrafter(Drafter):
    """Prompt-lookup self-drafting: propose the continuation of the most
    recent earlier occurrence of the stream's trailing n-gram.

    Matching backs off from ``ngram`` down to 1 token; no recurrence
    anywhere proposes zeros (a valid token id — the verify program just
    rejects it). Host-side and stateless: exact under preemption, abort,
    and crash recovery with nothing to roll back.
    """

    def __init__(self, k: int, ngram: int):
        """``k`` tokens proposed per step, matching up to ``ngram``."""
        self.k, self.ngram = k, ngram

    def propose(self, slots, token, pos):
        """Per-slot lookup over prompt + emitted history (host arrays)."""
        out = np.zeros((len(slots), self.k), np.int32)
        for i, s in enumerate(slots):
            if s.req is None or not s.dactive:
                continue
            prompt = np.asarray(s.req.prompt)
            ctx = np.concatenate(
                [prompt, np.asarray(s.req.out_tokens, prompt.dtype)])
            out[i] = self._lookup(ctx.astype(np.int64))
        return out

    def _lookup(self, ctx: np.ndarray) -> np.ndarray:
        """Continuation of the last recurrence of the trailing n-gram."""
        n_ctx = len(ctx)
        out = np.zeros((self.k,), np.int32)
        for n in range(min(self.ngram, n_ctx - 1), 0, -1):
            tail = ctx[n_ctx - n:]
            # every length-n window that still has a continuation token
            win = np.lib.stride_tricks.sliding_window_view(
                ctx[:n_ctx - 1], n)
            hits = np.flatnonzero((win == tail).all(axis=1))
            if hits.size:
                j = int(hits[-1]) + n
                cand = ctx[j:j + self.k]
                out[:len(cand)] = cand
                break
        return out


class DraftModelDrafter(Drafter):
    """Small-draft-model drafting with a contiguous per-slot KV mirror.

    The draft cache keeps the engine's carry invariant — positions
    ``0..pos-1`` processed, the carry token's row unwritten at ``pos`` —
    and both tokenizer and vocab ids are shared with the target (the
    config validates ``draft_vocab >= target_vocab``). All device work
    is jitted and donated; the proposal scan costs one extra small-model
    dispatch per step and zero extra host readbacks (drafts stay on
    device for the verify program).
    """

    stateful = True

    def __init__(self, params, cfg, k: int, slots: int, max_seq: int,
                 dev=None):
        """``dev`` places arrays for the engine's mesh (identity off)."""
        self.cfg, self.k = cfg, k
        self.slots, self.max_seq = slots, max_seq
        self._dev = dev if dev is not None else (lambda x: x)
        self.params = jax.tree.map(self._dev, params)
        _, self._axes = registry.cache_spec(cfg, slots, max_seq)
        self.cache = self._fresh_cache()
        self._scan_fn = self._jit_scan()
        self._prefill_fn = self._jit_prefill()
        self._restore_fn = self._jit_restore()

    def _fresh_cache(self):
        """Zeroed draft KV pool, placed wherever the engine's mesh is."""
        cache, _ = registry.init_cache(self.cfg, self.slots, self.max_seq)
        return jax.tree.map(self._dev, cache)

    def _jit_scan(self):
        """``k + 1`` greedy draft decode steps fused into one donated jit.

        Step ``j`` writes its input's KV row at ``pos + j`` and produces
        draft ``j + 1``; the final step's output is discarded but its
        write covers the all-accept case, so the mirror is complete
        however many drafts the verify program commits. Stale rows past
        the committed position are dead: ``kv_len`` masks them and the
        next scan overwrites them before any read — rollback is free.
        """
        cfg, k, vocab = self.cfg, self.k, self.cfg.vocab

        def scan(params, cache, token, pos):
            x, drafts = token, []
            for j in range(k + 1):
                logits, cache = registry.decode_step(params, cfg, cache,
                                                     x, pos + j)
                x = jnp.argmax(logits[:, :vocab], axis=-1) \
                    .astype(jnp.int32)
                if j < k:
                    drafts.append(x)
            return cache, jnp.stack(drafts, axis=1)

        return jax.jit(scan, donate_argnums=(1,))

    def _jit_prefill(self):
        """Fused draft prefill + slot scatter, keyed by the pow2 bucket
        (compile count mirrors the target's bucketed admission; the
        engine's ``prefill_compiles`` stat never sees these)."""
        cfg, max_seq = self.cfg, self.max_seq

        def prefill(params, cache, prompt, length, slot):
            _, kv = registry.prefill(params, cfg, prompt[None],
                                     length=length)
            return registry.write_slot(cfg, cache, kv, slot, max_seq)

        return jax.jit(prefill, donate_argnums=(1,))

    def _jit_restore(self):
        """Jitted swap-in scatter of a snapshot back into its slot."""
        cfg, max_seq = self.cfg, self.max_seq

        def restore(cache, saved, slot):
            return registry.write_slot(cfg, cache, saved, slot, max_seq)

        return jax.jit(restore, donate_argnums=(0,))

    def propose(self, slots, token, pos):
        """One donated scan dispatch; drafts stay on device."""
        self.cache, drafts = self._scan_fn(self.params, self.cache,
                                           token, pos)
        return drafts

    def prefill(self, slot, tokens) -> None:
        """Process the full prompt into ``slot``'s draft cache (pow2
        bucketed + right-padded; exact under padding — dense family)."""
        tokens = np.asarray(tokens)
        n = len(tokens)
        b = 1
        while b < n:
            b *= 2
        b = min(b, self.max_seq)
        if b > n:
            tokens = np.concatenate(
                [tokens, np.zeros((b - n,), tokens.dtype)])
        self.cache = self._prefill_fn(self.params, self.cache,
                                      jnp.asarray(tokens), jnp.int32(n),
                                      jnp.int32(slot))

    def snapshot_slot(self, slot):
        """Host copy of ``slot``'s rows on every cache leaf (swap-out)."""
        def cut(leaf, ax):
            idx = [slice(None)] * leaf.ndim
            idx[ax.index("batch")] = slice(slot, slot + 1)
            return np.asarray(leaf[tuple(idx)])

        is_ax = lambda x: isinstance(x, tuple)
        leaves, treedef = jax.tree.flatten(self.cache)
        axes = jax.tree.leaves(self._axes, is_leaf=is_ax)
        return jax.tree.unflatten(
            treedef, [cut(p, ax) for p, ax in zip(leaves, axes)])

    def restore_slot(self, slot, saved) -> None:
        """Byte-for-byte swap-in of a ``snapshot_slot`` payload."""
        self.cache = self._restore_fn(
            self.cache, jax.tree.map(jnp.asarray, saved),
            jnp.int32(slot))

    def reset(self) -> None:
        """Fresh zeroed cache (same shapes/placement: no retrace)."""
        self.cache = self._fresh_cache()


def make_drafter(spec: SpecConfig, cfg, slots: int, max_seq: int,
                 dev=None) -> Drafter:
    """Resolve a ``SpecConfig`` into a ready drafter for this engine.

    Validates the draft model against the target: token frontend, exact
    right-padded prefill (the drafter buckets prompts like the engine),
    and a vocab covering every target token id (proposals and the
    target's committed carries cross between the two models verbatim).
    """
    if spec.drafter == "ngram":
        return NGramDrafter(spec.k, spec.ngram)
    dcfg = spec.draft_cfg
    if getattr(dcfg, "frontend", "tokens") == "frames":
        raise ValueError("draft models take token prompts; a frames "
                         "frontend cannot draft")
    if not registry.pad_prefill_ok(dcfg):
        raise ValueError(
            f"draft family {dcfg.family!r} has no exact right-padded "
            "prefill; use a dense draft model (or drafter='ngram')")
    if dcfg.vocab < cfg.vocab:
        raise ValueError(
            f"draft vocab {dcfg.vocab} cannot cover target vocab "
            f"{cfg.vocab}: proposals are target token ids")
    return DraftModelDrafter(spec.draft_params, dcfg, spec.k, slots,
                             max_seq, dev=dev)
