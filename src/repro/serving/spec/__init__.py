"""Speculative decoding fused into the device-resident decode step.

``SpecConfig`` names a drafter (``"ngram"`` prompt-lookup self-drafting
or ``"draft_model"``) and a draft length ``k``; the serving engine
resolves it through ``make_drafter`` and verifies all ``k + 1``
positions inside its single donated step program — per-slot variable
acceptance on-device, rejected positions' KV writes routed to the trap
page, still exactly one batched host readback per step. Greedy spec
streams are bitwise identical to target-only decoding. ``space``
registers (drafter, k) as a search space ``benchmarks/run.py`` can
autotune against serve_bench tokens/s.
"""

from repro.serving.spec.config import DRAFTERS, SpecConfig
from repro.serving.spec.drafter import (Drafter, DraftModelDrafter,
                                        NGramDrafter, make_drafter)

__all__ = ["DRAFTERS", "SpecConfig", "Drafter", "DraftModelDrafter",
           "NGramDrafter", "make_drafter"]
