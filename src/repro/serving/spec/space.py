"""Search space over speculative-decoding knobs: (drafter, k).

The Astra loop treats optimization moves as a searchable strategy set;
this module gives it the serving-level analogue for speculative
decoding. A ``SpecVariant`` names one point of the space, ``evaluate``
scores it by *end-to-end serving throughput* — an in-process mini
serve_bench run over a fixed request mix — and ``autotune`` sweeps the
space and returns the best valid variant. Validity is the subsystem's
acceptance oracle: a variant only counts if its greedy streams are
bitwise identical to the target-only baseline (a drafter can be slow,
never wrong). ``benchmarks/run.py --autotune-spec`` drives this.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

from repro.serving.spec.config import DRAFTERS, SpecConfig


@dataclasses.dataclass(frozen=True)
class SpecVariant:
    """One point of the spec search space: drafter choice + draft len."""

    drafter: str
    k: int

    def to_config(self, draft_params=None, draft_cfg=None) -> SpecConfig:
        """The ``SpecConfig`` this variant resolves to (draft model
        weights attached for the ``"draft_model"`` drafter)."""
        if self.drafter == "draft_model":
            return SpecConfig(drafter=self.drafter, k=self.k,
                              draft_params=draft_params,
                              draft_cfg=draft_cfg)
        return SpecConfig(drafter=self.drafter, k=self.k)


def enumerate_variants(ks: Sequence[int] = (1, 2, 3, 4, 6),
                       drafters: Sequence[str] = DRAFTERS,
                       *, with_draft_model: bool = True):
    """The swept (drafter, k) grid; drop draft-model points when no
    draft weights are available."""
    out = []
    for d in drafters:
        if d == "draft_model" and not with_draft_model:
            continue
        for k in ks:
            out.append(SpecVariant(drafter=d, k=k))
    return out


def _serve(params, cfg, prompts, spec, *, slots, max_seq, max_new,
           page_size, seed_streams=None):
    """One in-process serving run; returns (streams, wall_s, stats)."""
    from repro.serving.api import LLMEngine
    eng = LLMEngine(params, cfg, slots=slots, max_seq=max_seq,
                    page_size=page_size, spec=spec)
    t0 = time.perf_counter()
    outs = eng.generate(prompts, max_new_tokens=max_new)
    wall = time.perf_counter() - t0
    return [o.tokens for o in outs], wall, eng.stats()


def evaluate(params, cfg, variant: SpecVariant, prompts, *,
             draft_params=None, draft_cfg=None, slots: int = 4,
             max_seq: int = 128, max_new: int = 16, page_size: int = 16,
             baseline: Optional[tuple] = None) -> dict:
    """Score one variant against the target-only baseline.

    Returns a row with ``tok_per_s``, the spec counters, and ``valid``
    (greedy streams bitwise identical to target-only). Pass
    ``baseline=(streams, wall_s)`` to share one target-only run across
    a sweep; omitted, the baseline is run here.
    """
    if baseline is None:
        baseline = _serve(params, cfg, prompts, None, slots=slots,
                          max_seq=max_seq, max_new=max_new,
                          page_size=page_size)[:2]
    base_streams, base_wall = baseline
    spec = variant.to_config(draft_params=draft_params,
                             draft_cfg=draft_cfg)
    streams, wall, stats = _serve(params, cfg, prompts, spec,
                                  slots=slots, max_seq=max_seq,
                                  max_new=max_new, page_size=page_size)
    toks = sum(len(s) for s in streams)
    return {
        "drafter": variant.drafter,
        "k": variant.k,
        "tok_per_s": toks / max(wall, 1e-9),
        "base_tok_per_s": sum(len(s) for s in base_streams)
        / max(base_wall, 1e-9),
        "wall_s": wall,
        "steps": stats["steps"],
        "accepted_per_step": stats.get("accepted_per_step", 0.0),
        "accept_rate": stats.get("accept_rate", 0.0),
        "draft_tokens": stats.get("draft_tokens", 0),
        "valid": streams == base_streams,
    }


def autotune(params, cfg, prompts, *, draft_params=None, draft_cfg=None,
             ks: Sequence[int] = (1, 2, 3, 4, 6),
             slots: int = 4, max_seq: int = 128, max_new: int = 16,
             page_size: int = 16) -> dict:
    """Sweep the (drafter, k) grid against serve tokens/s.

    Returns ``{"rows": [...], "best": row | None}`` — ``best`` is the
    highest-throughput *valid* variant (bit-identical streams), or None
    when every variant is invalid (which is itself a red flag the
    caller should surface).
    """
    base = _serve(params, cfg, prompts, None, slots=slots,
                  max_seq=max_seq, max_new=max_new,
                  page_size=page_size)[:2]
    rows = []
    for v in enumerate_variants(ks=ks,
                                with_draft_model=draft_params is not None):
        rows.append(evaluate(params, cfg, v, prompts,
                             draft_params=draft_params,
                             draft_cfg=draft_cfg, slots=slots,
                             max_seq=max_seq, max_new=max_new,
                             page_size=page_size, baseline=base))
    valid = [r for r in rows if r["valid"]]
    best = max(valid, key=lambda r: r["tok_per_s"]) if valid else None
    return {"rows": rows, "best": best}
