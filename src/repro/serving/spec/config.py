"""``SpecConfig`` — the declarative speculative-decoding knob set.

``SamplingParams``-adjacent: a frozen config the caller hands to
``LLMEngine(spec=...)`` / ``Engine(spec=...)`` (or builds from
``launch/serve.py --spec/--spec-k``). It names the drafter
(``"ngram"`` self-drafting or ``"draft_model"`` with a small dense
draft model), the draft length ``k``, and the drafter's own knobs; the
engine resolves it into a ``repro.serving.spec.drafter.Drafter`` via
``make_drafter`` and fuses verify/accept/rollback into the donated
decode step. Like ``CacheConfig.prefix_cache``, the config is silently
inert where the subsystem cannot run (contiguous cache managers, frame
frontends): the engine then serves target-only with zero spec counters.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

DRAFTERS = ("ngram", "draft_model")


@dataclasses.dataclass(frozen=True, eq=False)
class SpecConfig:
    """Speculative-decoding configuration for the serving engine.

    drafter: ``"ngram"`` (prompt-lookup self-drafting from the request's
        own prompt + emitted history — no extra model, no device state)
        or ``"draft_model"`` (a small dense draft model with its own
        contiguous KV state, rolled back on rejection).
    k: draft length — tokens proposed per decode step; the fused verify
        program scores all ``k + 1`` positions at once, so each step
        commits between 1 and ``k + 1`` tokens.
    ngram: maximum match length the n-gram drafter looks up (it backs
        off toward 1 until the trailing n-gram recurs).
    draft_params / draft_cfg: the draft model's weights and
        ``ModelConfig`` (``drafter="draft_model"`` only). The draft
        vocab must cover the target vocab — proposals are target-vocab
        token ids.
    """

    drafter: str = "ngram"
    k: int = 4
    ngram: int = 3
    draft_params: Optional[Any] = dataclasses.field(
        default=None, repr=False)
    draft_cfg: Optional[Any] = None

    def __post_init__(self):
        """Reject unusable configurations up front (typed, not traced)."""
        if self.drafter not in DRAFTERS:
            raise ValueError(f"drafter={self.drafter!r} must be one of "
                             f"{DRAFTERS}")
        if self.k < 1:
            raise ValueError(f"k={self.k} must be >= 1 (propose at least "
                             "one draft token)")
        if self.ngram < 1:
            raise ValueError(f"ngram={self.ngram} must be >= 1")
        if self.drafter == "draft_model" and (
                self.draft_params is None or self.draft_cfg is None):
            raise ValueError("drafter='draft_model' needs draft_params= "
                             "and draft_cfg= (the small draft model)")
