"""Host-side page allocator for the paged KV pool.

The device holds one global ``[num_pages + 1, page_size, ...]`` block pool
per cache leaf; this class owns the host bookkeeping: which physical pages
are free, which slot owns which pages, and the per-slot page tables the
jitted decode step reads each dispatch.

Physical page 0 is a reserved **trap page**: it is never allocated, and
every unassigned page-table entry points at it. The fused decode step
writes the new token's K/V for *every* pool slot (masked slots included —
exactly like the contiguous engine's unconditional scatter), so a slot
whose request finished or was preempted keeps scribbling somewhere until
it is re-admitted; routing those writes into the trap page is what makes
freeing + reusing a victim's pages safe while the victim's slot is still
being dispatched. Trap contents are garbage by design and are only ever
reachable through masked (``>= kv_len``) positions.

Allocation is a LIFO free stack (deterministic: benchmark streams and
goldens must not depend on allocator ordering noise). ``check()`` asserts
the structural invariants — no page owned twice, free/owned partition the
pool, trap never owned — and is called from the allocator unit tests.
"""

from __future__ import annotations

import numpy as np

TRAP_PAGE = 0


class PagePool:
    def __init__(self, num_pages: int, page_size: int, slots: int,
                 pages_per_slot: int):
        if num_pages < pages_per_slot:
            raise ValueError(
                f"num_pages={num_pages} cannot hold even one full-length "
                f"request ({pages_per_slot} pages of {page_size}); the "
                f"engine could deadlock on an empty pool")
        self.num_pages = num_pages          # usable (excludes the trap page)
        self.page_size = page_size
        self.pages_per_slot = pages_per_slot
        # physical ids are 1..num_pages; pop() hands out ascending ids first
        self._free = list(range(num_pages, 0, -1))
        self.owned: list[list[int]] = [[] for _ in range(slots)]
        # device-facing tables; row = slot, entry = physical page (0 = trap)
        self.table = np.full((slots, pages_per_slot), TRAP_PAGE, np.int32)

    # -- allocation ---------------------------------------------------------

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    def alloc(self, slot: int) -> bool:
        """Grow ``slot`` by one page; False when the pool is exhausted."""
        if not self._free:
            return False
        i = len(self.owned[slot])
        if i >= self.pages_per_slot:
            raise RuntimeError(f"slot {slot} already holds its max "
                               f"{self.pages_per_slot} pages")
        page = self._free.pop()
        self.owned[slot].append(page)
        self.table[slot, i] = page
        return True

    def alloc_n(self, slot: int, n: int) -> bool:
        """All-or-nothing: grow ``slot`` by ``n`` pages or change nothing."""
        if n > len(self._free) or len(self.owned[slot]) + n \
                > self.pages_per_slot:
            return False
        for _ in range(n):
            self.alloc(slot)
        return True

    def release(self, slot: int) -> None:
        """Free every page ``slot`` owns; its table row reverts to trap."""
        while self.owned[slot]:
            self._free.append(self.owned[slot].pop())
        self.table[slot, :] = TRAP_PAGE

    def stats(self) -> dict:
        """Occupancy snapshot (consumed by the paged ``CacheManager``)."""
        return {"num_pages": self.num_pages,
                "pages_in_use": self.pages_in_use,
                "num_free": self.num_free}

    # -- invariants ---------------------------------------------------------

    def check(self) -> None:
        """Structural invariants; raises AssertionError on violation."""
        all_owned = [p for pages in self.owned for p in pages]
        assert TRAP_PAGE not in all_owned, "trap page allocated"
        assert len(all_owned) == len(set(all_owned)), \
            "page owned by two live slots"
        assert not set(all_owned) & set(self._free), "owned page in free list"
        assert len(all_owned) + len(self._free) == self.num_pages, \
            "pages leaked or duplicated"
        for slot, pages in enumerate(self.owned):
            row = self.table[slot]
            assert list(row[:len(pages)]) == pages, "table/owned mismatch"
            assert (row[len(pages):] == TRAP_PAGE).all(), \
                "stale table entry past owned prefix"
