"""Host-side page allocator for the paged KV pool.

The device holds one global ``[num_pages + 1, page_size, ...]`` block pool
per cache leaf; this class owns the host bookkeeping: which physical pages
are free, which slot owns which pages, and the per-slot page tables the
jitted decode step reads each dispatch.

Physical page 0 is a reserved **trap page**: it is never allocated, and
every unassigned page-table entry points at it. The fused decode step
writes the new token's K/V for *every* pool slot (masked slots included —
exactly like the contiguous engine's unconditional scatter), so a slot
whose request finished or was preempted keeps scribbling somewhere until
it is re-admitted; routing those writes into the trap page is what makes
freeing + reusing a victim's pages safe while the victim's slot is still
being dispatched. Trap contents are garbage by design and are only ever
reachable through masked (``>= kv_len``) positions.

Pages are **refcounted** so the radix prefix cache can share them: a page's
refcount is the number of slot page-table entries mapping it plus its
external (radix-tree) references. ``alloc``/``alloc_n`` hand out private
pages (refcount 1); ``map_shared`` maps already-live pages read-only into
another slot's table; ``retain``/``drop`` manage the tree's external refs;
``cow`` repoints one table entry at a fresh private copy (the device-side
page copy is the caller's job). A page returns to the free list exactly
when its refcount hits zero, so ``release`` doubles as rollback for a
partially built mapping.

Allocation is a LIFO free stack (deterministic: benchmark streams and
goldens must not depend on allocator ordering noise). ``check()`` asserts
the structural invariants — refcounts equal mapping + external counts,
free pages have refcount zero, trap never referenced — and is called from
the allocator unit tests and the hypothesis state machine.
"""

from __future__ import annotations

import numpy as np

TRAP_PAGE = 0


class PagePool:
    """Refcounted physical-page allocator behind the paged KV cache."""

    def __init__(self, num_pages: int, page_size: int, slots: int,
                 pages_per_slot: int):
        if num_pages < pages_per_slot:
            raise ValueError(
                f"num_pages={num_pages} cannot hold even one full-length "
                f"request ({pages_per_slot} pages of {page_size}); the "
                f"engine could deadlock on an empty pool")
        self.num_pages = num_pages          # usable (excludes the trap page)
        self.page_size = page_size
        self.pages_per_slot = pages_per_slot
        # physical ids are 1..num_pages; pop() hands out ascending ids first
        self._free = list(range(num_pages, 0, -1))
        self.owned: list[list[int]] = [[] for _ in range(slots)]
        # physical ids of pages this slot maps but does not exclusively own
        # (read-only prefix pages); decode must never write these in place
        self.shared: list[set[int]] = [set() for _ in range(slots)]
        # refcnt[p] = (# table entries mapping p) + ext[p]; index 0 = trap
        self.refcnt = [0] * (num_pages + 1)
        self._ext = [0] * (num_pages + 1)   # radix-tree references
        # device-facing tables; row = slot, entry = physical page (0 = trap)
        self.table = np.full((slots, pages_per_slot), TRAP_PAGE, np.int32)

    # -- allocation ---------------------------------------------------------

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    def alloc(self, slot: int) -> bool:
        """Grow ``slot`` by one private page; False when exhausted."""
        if not self._free:
            return False
        i = len(self.owned[slot])
        if i >= self.pages_per_slot:
            raise RuntimeError(f"slot {slot} already holds its max "
                               f"{self.pages_per_slot} pages")
        page = self._free.pop()
        self.refcnt[page] = 1
        self.owned[slot].append(page)
        self.table[slot, i] = page
        return True

    def alloc_n(self, slot: int, n: int) -> bool:
        """All-or-nothing: grow ``slot`` by ``n`` pages or change nothing."""
        if n > len(self._free) or len(self.owned[slot]) + n \
                > self.pages_per_slot:
            return False
        for _ in range(n):
            self.alloc(slot)
        return True

    def map_shared(self, slot: int, pages: list[int]) -> None:
        """Append already-live ``pages`` read-only to ``slot``'s table.

        The pages keep their existing owners (the radix tree and possibly
        other slots); this only adds mapping refs. Capacity overflow is a
        caller bug (admission sizes the mapping), hence RuntimeError."""
        if len(self.owned[slot]) + len(pages) > self.pages_per_slot:
            raise RuntimeError(f"slot {slot} cannot map {len(pages)} more "
                               f"pages (max {self.pages_per_slot})")
        for page in pages:
            assert page != TRAP_PAGE and self.refcnt[page] >= 1, \
                f"map_shared of dead page {page}"
            i = len(self.owned[slot])
            self.refcnt[page] += 1
            self.owned[slot].append(page)
            self.shared[slot].add(page)
            self.table[slot, i] = page

    def retain(self, page: int) -> None:
        """Add one external (radix-tree) reference to a live page."""
        assert page != TRAP_PAGE and self.refcnt[page] >= 1, \
            f"retain of dead page {page}"
        self._ext[page] += 1
        self.refcnt[page] += 1

    def drop(self, page: int) -> None:
        """Drop one external reference; frees the page at refcount zero."""
        assert self._ext[page] >= 1, f"drop of unretained page {page}"
        self._ext[page] -= 1
        self.refcnt[page] -= 1
        if self.refcnt[page] == 0:
            self._free.append(page)

    def cow(self, slot: int, idx: int) -> tuple[int, int]:
        """Copy-on-write: repoint ``slot``'s table entry ``idx`` (currently
        a shared page) at a fresh private page. Returns ``(src, dst)`` so
        the caller can issue the device page copy. The caller must ensure
        a free page exists (evicting the tree if necessary)."""
        old = self.owned[slot][idx]
        assert old in self.shared[slot], f"cow of private page {old}"
        assert self._free, "cow with no free page (caller must evict first)"
        new = self._free.pop()
        self.refcnt[new] = 1
        self.owned[slot][idx] = new
        self.table[slot, idx] = new
        self.shared[slot].discard(old)
        self.refcnt[old] -= 1
        if self.refcnt[old] == 0:
            self._free.append(old)
        return old, new

    def release(self, slot: int) -> None:
        """Drop every mapping ``slot`` holds; pages whose refcount hits
        zero return to the free list (shared prefix pages survive through
        their tree refs). The table row reverts to trap."""
        while self.owned[slot]:
            page = self.owned[slot].pop()
            self.refcnt[page] -= 1
            if self.refcnt[page] == 0:
                self._free.append(page)
        self.shared[slot].clear()
        self.table[slot, :] = TRAP_PAGE

    # -- chaos hooks --------------------------------------------------------

    def seize_free(self, n: int) -> list[int]:
        """Pull up to ``n`` pages off the free list and pin them with an
        external ref (the chaos harness's page-pool-exhaustion fault).
        Seized pages look exactly like tree-retained pages to every
        invariant, so ``check()`` keeps holding while the hold is live.
        Returns the seized page ids (possibly fewer than ``n``)."""
        pages = []
        for _ in range(min(n, len(self._free))):
            page = self._free.pop()
            self.refcnt[page] = 1
            self._ext[page] = 1
            pages.append(page)
        return pages

    def release_seized(self, pages: list[int]) -> None:
        """End a ``seize_free`` hold: drop the external pins."""
        for page in pages:
            self.drop(page)

    def stats(self) -> dict:
        """Occupancy snapshot (consumed by the paged ``CacheManager``)."""
        n_shared = sum(1 for p in range(1, self.num_pages + 1)
                       if self.refcnt[p] - self._ext[p] >= 2
                       or (self._ext[p] and self.refcnt[p] > self._ext[p]))
        return {"num_pages": self.num_pages,
                "pages_in_use": self.pages_in_use,
                "num_free": self.num_free,
                "pages_shared": n_shared,
                "tree_refs": sum(self._ext)}

    # -- invariants ---------------------------------------------------------

    def check(self) -> None:
        """Structural + refcount invariants; raises AssertionError."""
        all_owned = [p for pages in self.owned for p in pages]
        assert TRAP_PAGE not in all_owned, "trap page allocated"
        assert self.refcnt[TRAP_PAGE] == 0 and self._ext[TRAP_PAGE] == 0, \
            "trap page referenced"
        assert len(self._free) == len(set(self._free)), "free-list duplicate"
        maps = {}                      # page -> number of table mappings
        for slot, pages in enumerate(self.owned):
            assert len(pages) == len(set(pages)), \
                f"slot {slot} maps a page twice"
            assert self.shared[slot] <= set(pages), \
                f"slot {slot} shared set not within owned"
            for p in pages:
                maps[p] = maps.get(p, 0) + 1
            row = self.table[slot]
            assert list(row[:len(pages)]) == pages, "table/owned mismatch"
            assert (row[len(pages):] == TRAP_PAGE).all(), \
                "stale table entry past owned prefix"
        for p in range(1, self.num_pages + 1):
            assert self._ext[p] >= 0, f"negative ext count on page {p}"
            assert self.refcnt[p] == maps.get(p, 0) + self._ext[p], \
                f"refcnt mismatch on page {p}"
            assert (p in set(self._free)) == (self.refcnt[p] == 0), \
                f"free/refcnt disagreement on page {p}"
        for p, n in maps.items():
            if n >= 2:
                # the original allocator may keep the page "private" (it
                # wrote it once during prefill and never writes it again);
                # every later mapper must treat it read-only
                private = sum(1 for slot, pages in enumerate(self.owned)
                              if p in pages and p not in self.shared[slot])
                assert private <= 1, \
                    f"page {p} mapped writable by {private} slots"
        assert len(set(self._free)) \
            + sum(1 for p in range(1, self.num_pages + 1)
                  if self.refcnt[p] > 0) == self.num_pages, \
            "pages leaked or duplicated"
