"""Cache-management layer of the serving API: one ``alloc / write / grow /
evict / restore`` surface over both KV-cache layouts.

The engine used to special-case "contiguous ``slots x max_seq`` pool" vs
"``PagePool`` + page tables" inline at every call site; the two layouts now
sit behind one interface:

    alloc(slot, n_tokens)   all-or-nothing admission hold for a prompt
    write(cache, kv, slot)  traced prefill scatter (called inside jit)
    grow(slot)              back one more decode write (paged: one page)
    evict(slot)             release the slot's residency
    restore(slot, n_pages)  re-hold for a swap-preempted victim

plus the small queries the engine's dispatch loop needs (``backed``,
``has_free``, ``step_extra``, ``prefill_pages``, ``read``) and per-step
pool statistics. The traced paths dispatch through the registry's unified
``decode_cached`` / ``write_cached`` surface, so a manager works for any
family whose cache layout the registry describes.

``ContiguousCacheManager`` is the trivial implementation (every slot
permanently owns ``max_seq`` rows: alloc/grow always succeed, evict is a
no-op). ``PagedCacheManager`` owns the ``PagePool`` bookkeeping and the
trap-padded page vectors the jitted admission consumes. ``CacheConfig`` is
the declarative form (``paged=None`` auto-selects per family) that the
``Engine`` and the ``LLMEngine`` facade resolve with their own
cfg/slots/max_seq.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.models import registry
from repro.serving.paging import PagePool
from repro.serving.radix import RadixCache


class CacheManager:
    """Interface; see module docstring for the contract."""

    paged: bool = False
    prefix_cache: bool = False

    # -- residency (host side) ----------------------------------------------
    def alloc(self, slot: int, n_tokens: int) -> bool:
        raise NotImplementedError

    def grow(self, slot: int) -> bool:
        raise NotImplementedError

    def evict(self, slot: int) -> None:
        raise NotImplementedError

    def restore(self, slot: int, n_pages: int) -> bool:
        raise NotImplementedError

    def infeasible(self, n_tokens: int) -> Optional[str]:
        """Reason a request of ``n_tokens`` can NEVER be admitted by this
        manager (admission validation + the engine's deadlock watchdog),
        or None when it could fit an otherwise-empty pool."""
        return None

    def clear_tree(self) -> int:
        """Crash recovery: drop every radix-tree reference (the cached KV
        died with the device pool). Returns refs dropped; no-op without a
        prefix cache."""
        return 0

    # -- traced (called inside jit) -----------------------------------------
    def init(self):
        """Fresh device cache tree for this layout."""
        raise NotImplementedError

    def write(self, cache, kv, *, slot=None, pages=None):
        """Scatter one request's prefill cache into the pool."""
        raise NotImplementedError

    def decode(self, params, cache, token, pos, page_table=None,
               write_mask=None):
        """One fused decode step over the pool (traced). ``write_mask``
        (paged only) routes masked rows' K/V writes to the trap page —
        the speculative-decoding verify program rejects draft positions
        through it."""
        return registry.decode_cached(params, self.cfg, cache, token, pos,
                                      page_table=page_table,
                                      write_mask=write_mask)

    def read(self, cache, pages):
        """Gather whole pages back into prefill layout (swap-out)."""
        raise NotImplementedError

    # -- dispatch-loop queries ----------------------------------------------
    def backed(self, slot: int, write_pos: int) -> bool:
        """Is ``write_pos`` already storage-backed for ``slot``?"""
        return True

    @property
    def has_free(self) -> bool:
        return True

    def step_extra(self) -> tuple:
        """Per-dispatch host-owned args for the fused step (page table)."""
        return ()

    def prefill_pages(self, slot: int, n_tokens: int,
                      bucket_len: Optional[int]) -> Optional[np.ndarray]:
        """Physical destinations for a prompt's logical pages (trap-padded
        to the bucket so the jit compile key stays the bucket shape);
        None for the contiguous layout."""
        return None

    def pages_of(self, slot: int) -> Optional[np.ndarray]:
        return None

    def note_step(self, rows_by_slot: dict) -> None:
        """Record one dispatch's occupancy (``{slot: written rows}``) for
        utilization stats."""

    def stats(self) -> dict:
        return {"paged": self.paged}


class ContiguousCacheManager(CacheManager):
    """Every slot permanently owns a ``max_seq`` stripe of the pool — the
    historical layout. Residency management degenerates: admission always
    fits, growth never exhausts, eviction frees nothing."""

    paged = False

    def __init__(self, cfg, slots: int, max_seq: int):
        self.cfg, self.slots, self.max_seq = cfg, slots, max_seq

    def init(self):
        cache, _ = registry.init_cache(self.cfg, self.slots, self.max_seq)
        return cache

    def alloc(self, slot: int, n_tokens: int) -> bool:
        return True

    def grow(self, slot: int) -> bool:
        return True

    def evict(self, slot: int) -> None:
        pass

    def restore(self, slot: int, n_pages: int) -> bool:
        return True

    def write(self, cache, kv, *, slot=None, pages=None):
        return registry.write_cached(self.cfg, cache, kv, slot=slot,
                                     max_seq=self.max_seq)

    def read(self, cache, pages):
        raise NotImplementedError("contiguous slots are never swapped out")


class PagedCacheManager(CacheManager):
    """SGLang/vLLM-style paged layout: a global ``[num_pages + 1,
    page_size, ...]`` block pool (physical page 0 is the trap page) plus
    per-slot page tables. ``num_pages`` below ``slots * max_seq /
    page_size`` oversubscribes: ``alloc``/``grow`` then report exhaustion
    and the engine preempts."""

    paged = True

    def __init__(self, cfg, slots: int, max_seq: int, *,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 prefix_cache: bool = True):
        if not registry.paged_ok(cfg):
            raise ValueError(f"family {cfg.family!r} (window={cfg.window}) "
                             "cannot serve from a paged pool")
        if max_seq % page_size:
            raise ValueError(f"page_size={page_size} must divide "
                             f"max_seq={max_seq} (the gathered logical "
                             "cache must tile exactly)")
        self.cfg, self.slots, self.max_seq = cfg, slots, max_seq
        self.page_size = page_size
        self.pages_per_slot = max_seq // page_size
        if num_pages is None:
            num_pages = slots * self.pages_per_slot   # full subscription
        self.num_pages = num_pages
        self.pool = PagePool(num_pages, page_size, slots,
                             self.pages_per_slot)
        self.prefix_cache = bool(prefix_cache) \
            and registry.prefix_cache_ok(cfg)
        self.tree = RadixCache(page_size) if self.prefix_cache else None
        self._peak = 0
        self._util_sum = 0.0
        self._frag_sum = 0.0
        self._steps = 0
        self._hit_tokens = 0
        self._query_tokens = 0
        self._cow_copies = 0
        self._tree_evictions = 0

    def init(self):
        # +1: physical page 0 is the trap page (see repro.serving.paging)
        cache, _ = registry.init_paged_cache(self.cfg, self.num_pages + 1,
                                             self.page_size)
        return cache

    # -- residency ----------------------------------------------------------
    def _n_pages(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def _reserve(self, slot: int, n: int) -> bool:
        """``alloc_n`` that reclaims radix-tree pages on demand: the tree
        is a cache, so its unpinned leaves are logically free. Keeping
        this inside every allocation path preserves the capacity the
        engine saw before prefix caching existed."""
        if len(self.pool.owned[slot]) + n > self.pool.pages_per_slot:
            return False
        need = n - self.pool.num_free
        if need > 0:
            if self.tree is None:
                return False
            self._tree_evictions += self.tree.evict(need, self.pool)
            if self.pool.num_free < n:
                return False
        return self.pool.alloc_n(slot, n)

    def alloc(self, slot: int, n_tokens: int) -> bool:
        return self._reserve(slot, self._n_pages(n_tokens))

    def grow(self, slot: int) -> bool:
        return self._reserve(slot, 1)

    def evict(self, slot: int) -> None:
        self.pool.release(slot)

    def restore(self, slot: int, n_pages: int) -> bool:
        return self._reserve(slot, n_pages)

    def infeasible(self, n_tokens: int) -> Optional[str]:
        limit = min(self.pool.pages_per_slot, self.num_pages)
        n = self._n_pages(n_tokens)
        if n > limit:
            return (f"prompt needs {n} pages of {self.page_size} but the "
                    f"pool can hold at most {limit} per request")
        return None

    def clear_tree(self) -> int:
        if self.tree is None:
            return 0
        return self.tree.clear(self.pool)

    # -- radix prefix cache -------------------------------------------------
    def admit_prompt(self, slot: int, tokens) -> Optional[dict]:
        """Radix-aware admission hold for a token prompt: map the longest
        cached page-aligned prefix read-only into ``slot``, reserve
        private pages for the rest, and describe what the engine must
        prefill. Returns None (nothing changed) when the pool cannot hold
        the request; otherwise::

            {"n_cached": k,            # tree pages mapped read-only
             "suffix_start": s,        # prefill starts at this position
             "cow": (src, dst) | None} # device page copy to issue first

        A *full*-prompt match would leave the next decode write landing in
        the final shared page, so that page is copy-on-write duplicated up
        front and its tokens re-prefilled (``suffix_start`` backs up one
        page) — the fused decode step then never sees a shared write."""
        n = len(tokens)
        n_total = self._n_pages(n)
        if not self.prefix_cache:
            return {"n_cached": 0, "suffix_start": 0, "cow": None} \
                if self._reserve(slot, n_total) else None
        matched = self.tree.match(tokens)
        k = min(len(matched), n // self.page_size)
        if k == 0:
            if not self._reserve(slot, n_total):
                return None
            self._query_tokens += n
            return {"n_cached": 0, "suffix_start": 0, "cow": None}
        self.pool.map_shared(slot, matched[:k])
        if not self._reserve(slot, n_total - k):
            self.pool.release(slot)       # tree refs keep the pages alive
            return None
        cow = None
        suffix_start = k * self.page_size
        if suffix_start == n:             # whole prompt cached
            if not self.pool.num_free:
                if self.tree.evict(1, self.pool) < 1:
                    self.pool.release(slot)
                    return None
                self._tree_evictions += 1
            cow = self.pool.cow(slot, k - 1)
            self._cow_copies += 1
            suffix_start = (k - 1) * self.page_size
        self._hit_tokens += suffix_start
        self._query_tokens += n
        return {"n_cached": k, "suffix_start": suffix_start, "cow": cow}

    def insert_prompt(self, slot: int, tokens, coverage: int) -> None:
        """Record ``slot``'s already-written full pages in the tree.
        ``coverage`` caps how many positions hold valid KV (a request's
        final emitted token never writes its KV row, and overlap means
        later positions may hold garbage)."""
        if not self.prefix_cache:
            return
        n_full = coverage // self.page_size
        if n_full <= 0:
            return
        self.tree.insert(tokens[:n_full * self.page_size],
                         self.pool.owned[slot][:n_full], self.pool)

    def prefix_page_vec(self, slot: int, suffix_start: int) -> np.ndarray:
        """Fixed-size (pages_per_slot) trap-padded physical page vector of
        the mapped prefix — fixed shape so the suffix-prefill compile key
        stays the suffix bucket only."""
        pages = np.zeros((self.pages_per_slot,), np.int32)
        k0 = suffix_start // self.page_size
        pages[:k0] = self.pool.owned[slot][:k0]
        return pages

    def suffix_pages(self, slot: int, suffix_start: int, n_tokens: int,
                     bucket_len: Optional[int]) -> np.ndarray:
        """Physical destinations for the suffix's logical pages,
        trap-padded to the suffix bucket (cf. ``prefill_pages``)."""
        k0 = suffix_start // self.page_size
        n_real = self._n_pages(n_tokens) - k0
        plen = bucket_len if bucket_len is not None \
            else n_tokens - suffix_start
        pages = np.zeros((max(1, self._n_pages(plen)),), np.int32)
        pages[:n_real] = self.pool.owned[slot][k0:]
        return pages

    # -- traced -------------------------------------------------------------
    def write(self, cache, kv, *, slot=None, pages=None):
        return registry.write_cached(self.cfg, cache, kv, pages=pages,
                                     page_size=self.page_size)

    def read(self, cache, pages):
        return registry.read_pages(self.cfg, cache, pages, self.page_size)

    # -- dispatch-loop queries ----------------------------------------------
    def backed(self, slot: int, write_pos: int) -> bool:
        return write_pos // self.page_size < len(self.pool.owned[slot])

    @property
    def has_free(self) -> bool:
        if self.pool.num_free > 0:
            return True
        return self.tree is not None and self.tree.has_evictable(self.pool)

    def step_extra(self) -> tuple:
        return (self.pool.table,)

    def prefill_pages(self, slot: int, n_tokens: int,
                      bucket_len: Optional[int]) -> np.ndarray:
        n_real = self._n_pages(n_tokens)
        plen = bucket_len if bucket_len is not None else n_tokens
        b_pages = max(1, self._n_pages(plen))
        pages = np.zeros((b_pages,), np.int32)        # bucket tail -> trap
        pages[:n_real] = self.pool.owned[slot]
        return pages

    def pages_of(self, slot: int) -> np.ndarray:
        return np.asarray(self.pool.owned[slot], np.int32)

    def note_step(self, rows_by_slot: dict) -> None:
        in_use = self.pool.pages_in_use
        self._steps += 1
        self._peak = max(self._peak, in_use)
        self._util_sum += in_use / self.num_pages
        # internal fragmentation over *privately written* pages only:
        # read-only shared prefix pages are full by definition and would
        # skew the allocated-but-unwritten ratio low
        ps = self.page_size
        alloc_rows = used = 0
        for slot, rows in rows_by_slot.items():
            shared = self.pool.shared[slot]
            for idx, page in enumerate(self.pool.owned[slot]):
                if page in shared:
                    continue
                alloc_rows += ps
                used += max(0, min(rows - idx * ps, ps))
        if alloc_rows:
            self._frag_sum += 1.0 - min(used, alloc_rows) / alloc_rows

    def stats(self) -> dict:
        steps = max(self._steps, 1)
        out = {
            "paged": True,
            "page_size": self.page_size,
            "num_pages": self.num_pages,
            "peak_pages_in_use": self._peak,
            # time-averaged pool occupancy and internal fragmentation
            # (allocated-but-unwritten rows / allocated private rows)
            "page_util_mean": self._util_sum / steps,
            "page_frag_mean": self._frag_sum / steps,
            "prefix_cache": self.prefix_cache,
        }
        if self.prefix_cache:
            out.update({
                "prefix_hit_tokens": self._hit_tokens,
                "prefix_query_tokens": self._query_tokens,
                "prefix_hit_rate":
                    self._hit_tokens / max(self._query_tokens, 1),
                "cow_copies": self._cow_copies,
                "tree_evictions": self._tree_evictions,
                "tree_pages": self.tree.n_pages,
            })
        return out


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """Declarative cache-manager choice, resolved against the engine's
    (cfg, slots, max_seq). ``paged=None`` auto-selects: paged when the
    family supports it (``registry.paged_ok``), contiguous otherwise.
    ``num_pages=None`` fully subscribes; fewer oversubscribes.
    ``prefix_cache`` enables radix prefix caching on paged managers whose
    family supports it (``registry.prefix_cache_ok``); elsewhere it is
    silently inert."""
    paged: Optional[bool] = None
    page_size: int = 16
    num_pages: Optional[int] = None
    prefix_cache: bool = True

    def build(self, cfg, slots: int, max_seq: int) -> CacheManager:
        paged = registry.paged_ok(cfg) if self.paged is None else self.paged
        if self.paged and not registry.paged_ok(cfg):
            raise ValueError(f"family {cfg.family!r} (window={cfg.window}) "
                             "cannot serve from a paged pool")
        if paged:
            return PagedCacheManager(cfg, slots, max_seq,
                                     page_size=self.page_size,
                                     num_pages=self.num_pages,
                                     prefix_cache=self.prefix_cache)
        return ContiguousCacheManager(cfg, slots, max_seq)


def make_cache_manager(spec, cfg, slots: int, max_seq: int) -> CacheManager:
    """Resolve ``None`` (auto), a ``CacheConfig``, or a ready instance."""
    if spec is None:
        spec = CacheConfig()
    if isinstance(spec, CacheConfig):
        return spec.build(cfg, slots, max_seq)
    return spec
