"""Cache-management layer of the serving API: one ``alloc / write / grow /
evict / restore`` surface over both KV-cache layouts.

The engine used to special-case "contiguous ``slots x max_seq`` pool" vs
"``PagePool`` + page tables" inline at every call site; the two layouts now
sit behind one interface:

    alloc(slot, n_tokens)   all-or-nothing admission hold for a prompt
    write(cache, kv, slot)  traced prefill scatter (called inside jit)
    grow(slot)              back one more decode write (paged: one page)
    evict(slot)             release the slot's residency
    restore(slot, n_pages)  re-hold for a swap-preempted victim

plus the small queries the engine's dispatch loop needs (``backed``,
``has_free``, ``step_extra``, ``prefill_pages``, ``read``) and per-step
pool statistics. The traced paths dispatch through the registry's unified
``decode_cached`` / ``write_cached`` surface, so a manager works for any
family whose cache layout the registry describes.

``ContiguousCacheManager`` is the trivial implementation (every slot
permanently owns ``max_seq`` rows: alloc/grow always succeed, evict is a
no-op). ``PagedCacheManager`` owns the ``PagePool`` bookkeeping and the
trap-padded page vectors the jitted admission consumes. ``CacheConfig`` is
the declarative form (``paged=None`` auto-selects per family) that the
``Engine`` and the ``LLMEngine`` facade resolve with their own
cfg/slots/max_seq.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.models import registry
from repro.serving.paging import PagePool


class CacheManager:
    """Interface; see module docstring for the contract."""

    paged: bool = False

    # -- residency (host side) ----------------------------------------------
    def alloc(self, slot: int, n_tokens: int) -> bool:
        raise NotImplementedError

    def grow(self, slot: int) -> bool:
        raise NotImplementedError

    def evict(self, slot: int) -> None:
        raise NotImplementedError

    def restore(self, slot: int, n_pages: int) -> bool:
        raise NotImplementedError

    # -- traced (called inside jit) -----------------------------------------
    def init(self):
        """Fresh device cache tree for this layout."""
        raise NotImplementedError

    def write(self, cache, kv, *, slot=None, pages=None):
        """Scatter one request's prefill cache into the pool."""
        raise NotImplementedError

    def decode(self, params, cache, token, pos, page_table=None):
        """One fused decode step over the pool (traced)."""
        return registry.decode_cached(params, self.cfg, cache, token, pos,
                                      page_table=page_table)

    def read(self, cache, pages):
        """Gather whole pages back into prefill layout (swap-out)."""
        raise NotImplementedError

    # -- dispatch-loop queries ----------------------------------------------
    def backed(self, slot: int, write_pos: int) -> bool:
        """Is ``write_pos`` already storage-backed for ``slot``?"""
        return True

    @property
    def has_free(self) -> bool:
        return True

    def step_extra(self) -> tuple:
        """Per-dispatch host-owned args for the fused step (page table)."""
        return ()

    def prefill_pages(self, slot: int, n_tokens: int,
                      bucket_len: Optional[int]) -> Optional[np.ndarray]:
        """Physical destinations for a prompt's logical pages (trap-padded
        to the bucket so the jit compile key stays the bucket shape);
        None for the contiguous layout."""
        return None

    def pages_of(self, slot: int) -> Optional[np.ndarray]:
        return None

    def note_step(self, used_rows: int) -> None:
        """Record one dispatch's occupancy for utilization stats."""

    def stats(self) -> dict:
        return {"paged": self.paged}


class ContiguousCacheManager(CacheManager):
    """Every slot permanently owns a ``max_seq`` stripe of the pool — the
    historical layout. Residency management degenerates: admission always
    fits, growth never exhausts, eviction frees nothing."""

    paged = False

    def __init__(self, cfg, slots: int, max_seq: int):
        self.cfg, self.slots, self.max_seq = cfg, slots, max_seq

    def init(self):
        cache, _ = registry.init_cache(self.cfg, self.slots, self.max_seq)
        return cache

    def alloc(self, slot: int, n_tokens: int) -> bool:
        return True

    def grow(self, slot: int) -> bool:
        return True

    def evict(self, slot: int) -> None:
        pass

    def restore(self, slot: int, n_pages: int) -> bool:
        return True

    def write(self, cache, kv, *, slot=None, pages=None):
        return registry.write_cached(self.cfg, cache, kv, slot=slot,
                                     max_seq=self.max_seq)

    def read(self, cache, pages):
        raise NotImplementedError("contiguous slots are never swapped out")


class PagedCacheManager(CacheManager):
    """SGLang/vLLM-style paged layout: a global ``[num_pages + 1,
    page_size, ...]`` block pool (physical page 0 is the trap page) plus
    per-slot page tables. ``num_pages`` below ``slots * max_seq /
    page_size`` oversubscribes: ``alloc``/``grow`` then report exhaustion
    and the engine preempts."""

    paged = True

    def __init__(self, cfg, slots: int, max_seq: int, *,
                 page_size: int = 16, num_pages: Optional[int] = None):
        if not registry.paged_ok(cfg):
            raise ValueError(f"family {cfg.family!r} (window={cfg.window}) "
                             "cannot serve from a paged pool")
        if max_seq % page_size:
            raise ValueError(f"page_size={page_size} must divide "
                             f"max_seq={max_seq} (the gathered logical "
                             "cache must tile exactly)")
        self.cfg, self.slots, self.max_seq = cfg, slots, max_seq
        self.page_size = page_size
        self.pages_per_slot = max_seq // page_size
        if num_pages is None:
            num_pages = slots * self.pages_per_slot   # full subscription
        self.num_pages = num_pages
        self.pool = PagePool(num_pages, page_size, slots,
                             self.pages_per_slot)
        self._peak = 0
        self._util_sum = 0.0
        self._frag_sum = 0.0
        self._steps = 0

    def init(self):
        # +1: physical page 0 is the trap page (see repro.serving.paging)
        cache, _ = registry.init_paged_cache(self.cfg, self.num_pages + 1,
                                             self.page_size)
        return cache

    # -- residency ----------------------------------------------------------
    def _n_pages(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def alloc(self, slot: int, n_tokens: int) -> bool:
        return self.pool.alloc_n(slot, self._n_pages(n_tokens))

    def grow(self, slot: int) -> bool:
        return self.pool.alloc(slot)

    def evict(self, slot: int) -> None:
        self.pool.release(slot)

    def restore(self, slot: int, n_pages: int) -> bool:
        return self.pool.alloc_n(slot, n_pages)

    # -- traced -------------------------------------------------------------
    def write(self, cache, kv, *, slot=None, pages=None):
        return registry.write_cached(self.cfg, cache, kv, pages=pages,
                                     page_size=self.page_size)

    def read(self, cache, pages):
        return registry.read_pages(self.cfg, cache, pages, self.page_size)

    # -- dispatch-loop queries ----------------------------------------------
    def backed(self, slot: int, write_pos: int) -> bool:
        return write_pos // self.page_size < len(self.pool.owned[slot])

    @property
    def has_free(self) -> bool:
        return self.pool.num_free > 0

    def step_extra(self) -> tuple:
        return (self.pool.table,)

    def prefill_pages(self, slot: int, n_tokens: int,
                      bucket_len: Optional[int]) -> np.ndarray:
        n_real = self._n_pages(n_tokens)
        plen = bucket_len if bucket_len is not None else n_tokens
        b_pages = max(1, self._n_pages(plen))
        pages = np.zeros((b_pages,), np.int32)        # bucket tail -> trap
        pages[:n_real] = self.pool.owned[slot]
        return pages

    def pages_of(self, slot: int) -> np.ndarray:
        return np.asarray(self.pool.owned[slot], np.int32)

    def note_step(self, used_rows: int) -> None:
        in_use = self.pool.pages_in_use
        self._steps += 1
        self._peak = max(self._peak, in_use)
        self._util_sum += in_use / self.num_pages
        alloc_rows = in_use * self.page_size
        if alloc_rows:
            self._frag_sum += 1.0 - min(used_rows, alloc_rows) / alloc_rows

    def stats(self) -> dict:
        steps = max(self._steps, 1)
        return {
            "paged": True,
            "page_size": self.page_size,
            "num_pages": self.num_pages,
            "peak_pages_in_use": self._peak,
            # time-averaged pool occupancy and internal fragmentation
            # (allocated-but-unwritten rows / allocated rows)
            "page_util_mean": self._util_sum / steps,
            "page_frag_mean": self._frag_sum / steps,
        }


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """Declarative cache-manager choice, resolved against the engine's
    (cfg, slots, max_seq). ``paged=None`` auto-selects: paged when the
    family supports it (``registry.paged_ok``), contiguous otherwise.
    ``num_pages=None`` fully subscribes; fewer oversubscribes."""
    paged: Optional[bool] = None
    page_size: int = 16
    num_pages: Optional[int] = None

    def build(self, cfg, slots: int, max_seq: int) -> CacheManager:
        paged = registry.paged_ok(cfg) if self.paged is None else self.paged
        if self.paged and not registry.paged_ok(cfg):
            raise ValueError(f"family {cfg.family!r} (window={cfg.window}) "
                             "cannot serve from a paged pool")
        if paged:
            return PagedCacheManager(cfg, slots, max_seq,
                                     page_size=self.page_size,
                                     num_pages=self.num_pages)
        return ContiguousCacheManager(cfg, slots, max_seq)


def make_cache_manager(spec, cfg, slots: int, max_seq: int) -> CacheManager:
    """Resolve ``None`` (auto), a ``CacheConfig``, or a ready instance."""
    if spec is None:
        spec = CacheConfig()
    if isinstance(spec, CacheConfig):
        return spec.build(cfg, slots, max_seq)
    return spec
