"""Deterministic chaos-injection harness for the serving engine.

Serving is the substrate the Astra agent loop iterates against, so it
must degrade gracefully rather than crash wholesale — and "gracefully"
has to be *testable*. ``ChaosInjector`` wires a step-indexed
``repro.reliability.FaultSchedule`` into the engine's decode loop and
injects the failure modes the robustness layer claims to survive:

    device_fault      raise ``InjectedDeviceFault`` in place of the fused
                      step dispatch — exercises quarantine + swap-restore
                      crash recovery (survivor streams must stay
                      bit-identical to an undisturbed run)
    pool_exhaustion   ``PagePool.seize_free`` a page hold for a step
                      window — exercises preemption under externally
                      induced pressure; released automatically, or early
                      via ``relent`` if the hold alone blocks progress
    corrupt_readback  mangle one slot's token in the batched host
                      readback — exercises per-request quarantine without
                      disturbing the other slots
    stall             sleep inside ``step()`` — exercises deadline expiry
                      and wall-clock robustness (never used in goldens)
    abort             call ``engine.abort(rid)`` at a chosen step —
                      deterministic cancellation for goldens

Everything is keyed on the engine's step counter, never wall-clock, so a
chaos run against a fixed request mix is exactly reproducible — the
``chaos_mix`` serve_bench scenario pins survivor streams and
abort/reject/recovery counters in golden files.
"""

from __future__ import annotations

import time
from typing import Iterable

import numpy as np

from repro.reliability import Fault, FaultSchedule

KINDS = frozenset({"device_fault", "pool_exhaustion", "corrupt_readback",
                   "stall", "abort"})

# token value planted by corrupt_readback: far outside any vocab, and not
# the -1 "masked" sentinel, so the engine's emit validation must catch it
CORRUPT_TOKEN = np.iinfo(np.int32).max


class InjectedDeviceFault(RuntimeError):
    """Stands in for a device/runtime failure of the fused step (XLA
    raises ``XlaRuntimeError``, itself a ``RuntimeError``). ``slot``
    optionally names the pool slot whose request the recovery path must
    quarantine; None lets the engine's preemption policy choose."""

    def __init__(self, message: str, slot=None):
        super().__init__(message)
        self.slot = slot


class ChaosInjector:
    """Deterministic step-indexed fault injector for the serving engine."""

    def __init__(self, faults: Iterable[Fault]):
        faults = list(faults)
        for f in faults:
            if f.kind not in KINDS:
                raise ValueError(f"unknown chaos fault kind {f.kind!r}; "
                                 f"have {sorted(KINDS)}")
        self.schedule = FaultSchedule(faults)
        self._seized: list[tuple[int, list[int]]] = []  # (release_at, pages)
        self.injected = {k: 0 for k in sorted(KINDS)}
        self.relents = 0

    # -- engine hooks -------------------------------------------------------

    def on_step(self, engine, step: int) -> None:
        """Host-side faults, applied at the top of ``Engine.step()``."""
        for rel, pages in list(self._seized):
            if step >= rel:
                engine.cm.pool.release_seized(pages)
                self._seized.remove((rel, pages))
        for f in self.schedule.due(step, kinds=("pool_exhaustion", "stall",
                                                "abort")):
            if f.kind == "pool_exhaustion" and engine.paged:
                pages = engine.cm.pool.seize_free(f.pages)
                if pages:
                    self.injected["pool_exhaustion"] += 1
                    self._seized.append((step + max(1, f.steps), pages))
            elif f.kind == "stall":
                self.injected["stall"] += 1
                time.sleep(f.seconds)
            elif f.kind == "abort":
                self.injected["abort"] += 1
                engine.abort(f.rid)

    def pre_dispatch(self, engine, step: int) -> None:
        """Raises in place of the fused decode dispatch — the engine's
        ``except RuntimeError`` recovery path takes it from here. Fired
        *before* the dispatch, so carry buffers and cache still hold the
        valid pre-step state (exactly the guarantee a failed XLA launch
        gives: the donated outputs never materialized)."""
        for f in self.schedule.due(step, kinds=("device_fault",)):
            self.injected["device_fault"] += 1
            raise InjectedDeviceFault(
                f"injected device fault at step {step}", slot=f.slot)

    def filter_emit(self, step: int, emit):
        """Corrupt one slot's token in a step's host readback."""
        due = self.schedule.due(step, kinds=("corrupt_readback",))
        if not due:
            return emit
        tok, done = (np.array(np.asarray(x)) for x in emit)
        for f in due:
            self.injected["corrupt_readback"] += 1
            tok[f.slot if f.slot is not None else 0] = CORRUPT_TOKEN
        return tok, done

    def relent(self, engine) -> bool:
        """The engine is quiescent and cannot admit: if a seize hold is
        live it may be the only thing blocking progress — end every hold
        early (chaos must induce preemption, not permanent deadlock).
        True when anything was released."""
        if not self._seized:
            return False
        for _, pages in self._seized:
            engine.cm.pool.release_seized(pages)
        self._seized.clear()
        self.relents += 1
        return True

    # -- reporting ----------------------------------------------------------

    @property
    def exhausted(self) -> bool:
        """Did every scheduled fault fire? (Asserted by chaos tests so a
        plan that silently never triggers fails loudly.)"""
        return self.schedule.exhausted

    def stats(self) -> dict:
        return {"chaos_injected": dict(self.injected),
                "chaos_relents": self.relents}
