"""Host-driven reference engine — the historical (pre-device-resident)
continuous-batching loop, kept verbatim as the equivalence oracle.

``Engine`` (repro.serving.engine) must produce bit-identical per-request
token streams to this implementation on any request mix; that invariant is
asserted by ``tests/test_serving.py`` and by
``benchmarks/serve_bench.py --check``. Every per-token pathology the new
engine removes is still here on purpose: un-jitted host argmax, one
blocking ``int(next_tok[i])`` readback per slot per step, an eager
per-request cache scatter, and one prefill compile per unique prompt
length.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import registry
from repro.serving.engine import Request, _Slot


class ReferenceEngine:
    """Host-driven greedy oracle pinning the pre-refactor token streams."""

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 max_seq: int = 512, greedy: bool = True, sampling=None,
                 spec=None):
        # the oracle is greedy-only BY DESIGN: it pins the pre-refactor
        # argmax streams. ``sampling`` is accepted for signature parity
        # with Engine but must describe greedy decoding.
        if not greedy or (sampling is not None and not sampling.greedy):
            raise ValueError("ReferenceEngine is the greedy (argmax) "
                             "oracle; non-greedy streams have no "
                             "host-driven reference")
        # ``spec`` is likewise signature parity only: the oracle IS the
        # target-only stream speculative decoding must reproduce, so a
        # drafter has nothing to add and plenty to confuse
        if spec is not None:
            raise ValueError("ReferenceEngine is the target-only oracle "
                             "speculative streams are checked against; "
                             "SpecConfig has no host-driven reference "
                             "(pass spec=None)")
        self.params, self.cfg = params, cfg
        self.n_slots, self.max_seq = slots, max_seq
        self.slots = [_Slot() for _ in range(slots)]
        self._pos_host = [0] * slots
        self.cache, _ = registry.init_cache(cfg, slots, max_seq)
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._decode = jax.jit(
            lambda p, c, t, pos: registry.decode_step(p, cfg, c, t, pos))
        self._token = jnp.zeros((slots,), jnp.int32)
        self._pos = jnp.zeros((slots,), jnp.int32)

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot.req is None and self.queue:
                req = self.queue.pop(0)
                logits, kv = registry.prefill(
                    self.params, self.cfg, jnp.asarray(req.prompt)[None])
                # scatter this request's prefill KV into pool slot i
                self.cache = jax.tree.map(
                    lambda pool, new: _write_slot(pool, new, i, self.max_seq),
                    self.cache, kv)
                tok = int(jnp.argmax(logits[0, :self.cfg.vocab]))
                req.out_tokens.append(tok)
                slot.req = req
                self._pos_host[i] = len(req.prompt) \
                    if self.cfg.family != "encdec" else 1
                self._token = self._token.at[i].set(tok)
                self._pos = self._pos.at[i].set(self._pos_host[i])

    def step(self):
        self._admit()
        if not any(s.req for s in self.slots):
            return False
        logits, self.cache = self._decode(self.params, self.cache,
                                          self._token, self._pos)
        next_tok = jnp.argmax(logits[:, :self.cfg.vocab], axis=-1) \
            .astype(jnp.int32)
        self._token = next_tok
        self._pos = self._pos + 1
        for i, slot in enumerate(self.slots):
            if slot.req is None:
                continue
            self._pos_host[i] += 1
            tok = int(next_tok[i])
            slot.req.out_tokens.append(tok)
            if (len(slot.req.out_tokens) >= slot.req.max_new_tokens
                    or self._pos_host[i] >= self.max_seq - 1):
                slot.req.done = True
                self.finished.append(slot.req)
                slot.req = None
        return True

    def run(self, max_steps: int = 10_000):
        while (self.queue or any(s.req for s in self.slots)) \
                and max_steps > 0:
            self.step()
            max_steps -= 1
        return self.finished


def _write_slot(pool, new, i, max_seq):
    """Insert one request's prefill cache [L, 1, S, ...] into pool slot i.

    Correct for families whose cache batch axis is axis 1 (dense / MoE /
    enc-dec); the device-resident engine replaces this with the axes-aware
    ``registry.write_slot``.
    """
    if pool.ndim != new.ndim or pool.shape[0] != new.shape[0]:
        return pool  # non-KV leaves (recurrent states share layout below)
    s = min(new.shape[2], max_seq) if new.ndim >= 3 else None
    if new.ndim >= 3 and pool.shape[2] >= new.shape[2]:
        return jax.lax.dynamic_update_slice_in_dim(
            pool, new[:, :1, :s].astype(pool.dtype), i, axis=1)
    if new.ndim >= 3:
        return jax.lax.dynamic_update_slice_in_dim(
            pool, new[:, :1, -pool.shape[2]:].astype(pool.dtype), i, axis=1)
    return pool
