"""Sampling layer of the serving API: ``SamplingParams`` + the fused
on-device draw.

``SamplingParams`` is the per-request knob set (vLLM/SGLang-style):
``temperature == 0`` is greedy argmax — bit-identical to the historical
``Engine(greedy=True)`` path — and ``temperature > 0`` is a categorical
draw over the (optionally top-k / top-p truncated) softmax.

The draw itself, ``sample_tokens``, runs INSIDE the engine's donated fused
decode step: one vmapped per-slot draw over the whole pool, keyed by a
``jax.random`` key buffer that lives in the donated carry. Non-greedy
decode therefore costs the same one batched host readback per step as
greedy decode — no extra syncs.

Reproducibility: the key for a request's *t*-th output token is
``fold_in(PRNGKey(seed), t)`` — a pure function of ``(seed, t)``, not a
split chain threaded through dispatches. Streams are therefore
bit-identical across engine restarts, across the contiguous and paged
cache managers, and across swap preemption/restore (which replays the same
``(seed, t)`` pairs). When ``seed`` is None the engine derives it from the
request id, so concurrent requests diverge by default but every run of the
same request list is reproducible.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration.

    temperature: 0.0 => greedy argmax (the default); > 0 scales logits
        before the categorical draw.
    top_k: keep only the k highest-logit tokens (0 => disabled).
    top_p: keep the smallest prefix of the sorted distribution whose
        cumulative probability reaches p (1.0 => disabled). Applied after
        top_k, per the usual convention.
    seed: per-request PRNG seed. None => the engine uses the request id,
        so distinct requests draw distinct noise but runs stay
        deterministic.
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: Optional[int] = None

    def __post_init__(self):
        if self.temperature < 0.0:
            raise ValueError(f"temperature={self.temperature} must be >= 0")
        if self.top_k < 0:
            raise ValueError(f"top_k={self.top_k} must be >= 0 (0 disables)")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p={self.top_p} must be in (0, 1]")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0

    def resolve_seed(self, rid: int) -> int:
        """The effective per-request seed (request id when unset)."""
        return int(self.seed) if self.seed is not None else int(rid)


GREEDY = SamplingParams()


def sample_tokens(logits, keys, index, temperature, top_k, top_p):
    """Vmapped per-slot token draw, traced inside the fused decode step.

    logits: ``[B, V]`` over the REAL vocab (caller slices off padding).
    keys: ``[B, 2]`` uint32 per-request base keys (``PRNGKey(seed)``),
        part of the donated device carry.
    index: ``[B]`` i32 — the output-stream index of this draw (the
        engine's ``emitted`` counter), folded into the base key so token
        *t* of a request always sees the same noise.
    temperature/top_k/top_p: ``[B]`` per-slot parameter buffers.

    Rows with ``temperature <= 0`` take the plain ``argmax`` — the exact
    computation of the historical greedy engine, so greedy streams stay
    bit-identical. Non-greedy rows apply top-k then top-p truncation and
    draw via the Gumbel-argmax trick (an exact categorical sample).
    """
    vocab = logits.shape[-1]
    # Materialize the logits ONCE before they fan out to the argmax and
    # sort consumers. Without the barrier XLA may duplicate the fused
    # logits computation per consumer with different last-bit rounding, so
    # two exactly-tied bf16 logits can sort one way and argmax the other —
    # the greedy branch then disagrees with a top_k=1 draw, and tie-breaks
    # stop being reproducible across program variants.
    logits = jax.lax.optimization_barrier(logits)

    def one(lg, key, idx, temp, k, p):
        greedy_tok = jnp.argmax(lg).astype(jnp.int32)
        scaled = lg.astype(jnp.float32) / jnp.maximum(temp, 1e-6)
        order = jnp.argsort(-scaled)           # descending logit order
        ranks = jnp.argsort(order)             # rank of each vocab entry
        k_eff = jnp.where(k > 0, k, vocab)
        keep_k = ranks < k_eff
        probs = jax.nn.softmax(jnp.where(keep_k, scaled, -jnp.inf))
        sorted_probs = probs[order]
        cum = jnp.cumsum(sorted_probs)
        # keep tokens whose PRECEDING cumulative mass is < p: the top token
        # always survives, and the token that crosses p is included
        keep_p = ((cum - sorted_probs) < p)[ranks]
        final = jnp.where(keep_k & keep_p, scaled, -jnp.inf)
        g = jax.random.gumbel(jax.random.fold_in(key, idx), (vocab,))
        sampled = jnp.argmax(final + g).astype(jnp.int32)
        return jnp.where(temp <= 0.0, greedy_tok, sampled)

    return jax.vmap(one)(logits, keys, index, temperature, top_k, top_p)
