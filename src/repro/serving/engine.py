"""Device-resident continuous-batching serving engine.

A fixed pool of decode slots; requests join as slots free up (continuous
batching à la SGLang/vLLM). The engine is the execution core of a layered
serving API:

* **Sampling** (``repro.serving.sampling.SamplingParams``) — greedy /
  temperature / top-k / top-p with a per-request seed. The draw is fused
  into the donated decode step: a per-slot categorical draw keyed by a
  ``jax.random`` key buffer living in the donated carry, so non-greedy
  decode still costs ONE batched host readback per step and token *t* of a
  request is a pure function of ``(seed, t)`` — bit-reproducible across
  restarts, cache managers, and preemption.
* **Scheduling** (``repro.serving.scheduler``) — admission order is a
  pluggable ``Scheduler`` (FCFS default — bit-identical to the historical
  deque — plus priority and shortest-job-first); victim choice and
  eviction semantics are a ``PreemptionPolicy`` (youngest-victim swap /
  recompute).
* **Cache management** (``repro.serving.cache_manager``) — the contiguous
  ``slots x max_seq`` pool and the paged ``PagePool`` + page-table layout
  sit behind one ``CacheManager`` ``alloc/write/grow/evict/restore``
  surface; ``CacheConfig(paged=None)`` auto-selects per family and
  ``num_pages`` below full subscription oversubscribes (admission waits
  for pages, decode growth preempts when the pool runs dry).
* **Facade** (``repro.serving.api.LLMEngine``) — ``generate()`` /
  ``stream()`` over this engine for callers who don't want to manage
  ``Request`` objects.

The decode hot path never leaves the device: one donated jitted program
per step (model decode + fused sampling + stop conditions + slot masking,
``donate_argnums`` on the KV/state pool and the token/pos/active/emitted/
key buffers), one batched ``(token-or-minus-one, done)`` host readback per
step with step *k*'s readback overlapped against step *k+1*'s dispatch,
and bucketed jitted prefill admission (pow2 prompt buckets for
``PAD_PREFILL`` families, exact length for stateful ones).

Greedy FCFS token streams are bit-identical to the historical host-driven
engine (``repro.serving.reference.ReferenceEngine``) — paged or not,
preempted or not; asserted end-to-end in ``tests/test_serving.py`` and by
the CI golden-stream check. The old constructor kwargs (``greedy=``,
``preempt=``, ``paged=``/``page_size=``/``num_pages=``) keep working
through deprecation shims that forward to the new layers.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import registry
from repro.serving.cache_manager import CacheConfig, make_cache_manager
from repro.serving.chaos import ChaosInjector
from repro.serving.sampling import SamplingParams, sample_tokens
from repro.serving.scheduler import make_preemption, make_scheduler
from repro.serving.spec import make_drafter
from repro.sharding import tp


@contextlib.contextmanager
def _quiet_donation():
    """Donation is a TPU/GPU in-place-update optimization; the CPU backend
    ignores it and warns once per compile. Scoped to the engine's dispatch
    sites so importing this module doesn't mutate the global filter."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        yield


def _jit_cache_size(fn) -> Optional[int]:
    """Compiled-program count of a jitted callable via jax's private
    ``_cache_size`` API; None when the API is absent (jax version drift).
    Typed narrowly on purpose: the engine's failure-isolation layer
    swallows per-request faults, never introspection errors — anything
    other than the known drift modes here must surface."""
    size = getattr(fn, "_cache_size", None)
    if size is None:
        return None
    try:
        return int(size())
    except TypeError:    # drift: no longer a nullary callable / not an int
        return None


@dataclasses.dataclass
class Request:
    """One generation request: prompt, budget, sampling, and its stream."""

    rid: int
    prompt: np.ndarray                  # token ids [S] (or frames [S, D])
    max_new_tokens: int = 16
    sampling: Optional[SamplingParams] = None   # None -> engine default
    priority: int = 0                   # consumed by PriorityScheduler
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0               # set by Engine.submit
    t_first: float = 0.0                # wall time of the first token (TTFT)
    preemptions: int = 0                # paged engine: times evicted+requeued
    arrival: int = -1                   # submission rank, stamped by submit
    prefix_hit_tokens: int = 0          # prompt tokens served from the radix
                                        # cache instead of prefill
    deadline_s: Optional[float] = None  # wall-clock budget from t_submit;
                                        # expiry finishes as "deadline"
    # lifecycle outcome: None while live, then one of
    # done | aborted | rejected | failed | deadline
    finish_reason: Optional[str] = None
    error: Optional[str] = None         # human-readable failure detail
    accepted_tokens: int = 0            # draft tokens the spec verify
                                        # committed (0 with spec off)
    # swap-preemption payload: (host KV pages, token, pos, emitted,
    # n_pages, drafter snapshot-or-None) — the victim's exact device
    # state, restored verbatim on re-admission
    swap_state: Optional[tuple] = dataclasses.field(default=None, repr=False)


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    # exact host mirror of the device's per-slot decode state — the device
    # stop conditions are deterministic, so the host can track position,
    # emit count, and active-ness without waiting for the (overlapped)
    # readback. The paged allocator predicts each step's write page from
    # ``dpos``; the drain heuristic reads ``dactive``.
    dpos: int = 0                       # device pos (next write position)
    demitted: int = 0                   # device emitted count
    dactive: bool = False               # device active flag


class Engine:
    """Device-resident continuous-batching core: one donated jitted program
    and one batched host readback per decode step."""

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 max_seq: int = 512,
                 sampling: Optional[SamplingParams] = None,
                 scheduler=None, preemption=None, cache_manager=None,
                 chaos=None, mesh=None, spec=None,
                 greedy: Optional[bool] = None,
                 preempt: Optional[str] = None,
                 paged: Optional[bool] = None,
                 page_size: Optional[int] = None,
                 num_pages: Optional[int] = None):
        """``sampling`` is the default ``SamplingParams`` for requests that
        don't carry their own (greedy when omitted). ``scheduler`` /
        ``preemption`` / ``cache_manager`` take a policy name, a config,
        or a ready instance — see ``repro.serving.scheduler`` and
        ``repro.serving.cache_manager``. ``chaos`` takes a
        ``serving.chaos.ChaosInjector`` (or a plain ``reliability.Fault``
        list) whose scheduled faults are injected into the decode loop.
        ``mesh`` takes a ``(data, model)`` ``jax.sharding.Mesh`` (see
        ``launch/mesh.py``): the donated programs run under ``shard_map``
        with weights, the paged KV pool, and the slot batch sharded per
        the plan ``repro.sharding.tp`` resolves from the logical-axis
        rules — token streams stay bit-identical to the single-device
        engine (all collectives are all-gathers). ``spec`` takes a
        ``repro.serving.spec.SpecConfig``: the drafter proposes ``k``
        tokens per step and the donated step verifies all ``k + 1``
        positions at once, committing the longest accepted prefix
        on-device (rejected positions write to the trap page) — still
        one batched host readback per step, and greedy streams bitwise
        identical to target-only decoding. Like
        ``CacheConfig.prefix_cache`` it is silently inert where it
        cannot run (contiguous cache managers, frame frontends).

        ``greedy=``, ``preempt=``, and ``paged=``/``page_size=``/
        ``num_pages=`` are the pre-layered kwargs, kept as deprecation
        shims that forward to the new layers."""
        if greedy is not None:
            warnings.warn(
                "Engine(greedy=...) is deprecated; pass "
                "sampling=SamplingParams(...) instead", DeprecationWarning,
                stacklevel=2)
            if sampling is None:
                sampling = SamplingParams() if greedy \
                    else SamplingParams(temperature=1.0)
        if preempt is not None:
            warnings.warn(
                "Engine(preempt=...) is deprecated; pass preemption= a "
                "repro.serving.scheduler.PreemptionPolicy (or its name)",
                DeprecationWarning, stacklevel=2)
            if preemption is None:
                preemption = preempt
        if paged is not None or page_size is not None \
                or num_pages is not None:
            warnings.warn(
                "Engine(paged=/page_size=/num_pages=) is deprecated; pass "
                "cache_manager=CacheConfig(...) instead",
                DeprecationWarning, stacklevel=2)
            if cache_manager is None:
                cache_manager = CacheConfig(paged=paged,
                                            page_size=page_size or 16,
                                            num_pages=num_pages)
        self.params, self.cfg = params, cfg
        self.n_slots, self.max_seq = slots, max_seq
        self.slots = [_Slot() for _ in range(slots)]
        self.default_sampling = sampling if sampling is not None \
            else SamplingParams()
        self.scheduler = make_scheduler(scheduler)
        self.preemption = make_preemption(preemption)
        self.preempt_mode = self.preemption.mode
        self.cm = make_cache_manager(cache_manager, cfg, slots, max_seq)
        self.paged = self.cm.paged
        self.page_size = getattr(self.cm, "page_size", None)
        self.num_pages = getattr(self.cm, "num_pages", None)
        self._plan = None
        if mesh is not None:
            if not self.paged:
                raise ValueError(
                    "mesh serving requires the paged cache manager (the "
                    "contiguous cache keeps the split-KV shard_map path)")
            self._plan = tp.make_plan(cfg, mesh, slots)
            # weights move to the mesh here (gate/up columns permuted per
            # shard when the MLP axis shards); carries and the pool get
            # replicated / heads-sharded placements below so donation
            # round-trips a consistent committed sharding
            self.params = tp.shard_params(params, cfg, self._plan)
            self._pspecs = tp.param_specs(self.params, self._plan)
        self.cache = self._put_cache(self.cm.init())
        self.chaos = None
        if chaos is not None:
            self.chaos = chaos if hasattr(chaos, "on_step") \
                else ChaosInjector(chaos)
        self.finished: list[Request] = []
        self.preemptions = 0
        self.recoveries = 0
        self._lifecycle = {"done": 0, "aborted": 0, "rejected": 0,
                           "failed": 0, "deadline": 0}
        self._has_deadlines = False
        self._arrivals = 0
        self._pad_ok = registry.pad_prefill_ok(cfg)
        # device-resident per-slot decode state (+ per-slot sampling
        # parameters and the per-request base PRNG keys — the key buffer
        # rides in the donated carry with the rest)
        self._fresh_carries()
        # the decode step specializes on "has any resident request ever
        # been non-greedy": the all-greedy program is the historical bare
        # argmax; admitting the first sampling request rebuilds it once
        self._greedy_only = self.default_sampling.greedy
        self._step_fn = self._jit_step(self._greedy_only)
        # Admission (prefill + pool scatter + slot state reset) is ONE
        # jitted program keyed by the (padded) prompt shape: bucketed
        # families compile at most log2(max_seq)+1 of them; exact-length
        # families (MoE capacity routing, recurrences, bidirectional
        # encoders) compile per unique length — the historical engine's
        # behavior, minus its eager scatter and host argmax.
        self._admit_fn = self._jit_admit(self._greedy_only)
        # prefill compiles accumulated by admit programs replaced on the
        # greedy->sampling flip (stats() adds the live program's count)
        self._compiles_base = 0
        if self.paged:
            # swap-in restore; compile key = saved page count (<= n_pt)
            self._restore_fn = self._jit_restore()
        # speculative decoding: active only where the paged pool (trap
        # page for rejected writes) and token prompts exist — silently
        # inert elsewhere, mirroring CacheConfig.prefix_cache. ``spec``
        # is the EFFECTIVE config (None when inert); ``spec_config`` the
        # requested one, kept so stats always surface the spec counters.
        self.spec_config = spec
        self.spec = None
        self._drafter = None
        if spec is not None and self.paged and cfg.frontend != "frames":
            self.spec = spec
            self._drafter = make_drafter(spec, cfg, slots, max_seq,
                                         dev=self._dev)
            # the fused draft-verify step: k+1 sequential inner decode
            # steps in ONE donated program (greedy-only by construction
            # — non-greedy requests are rejected at admission)
            self._spec_step_fn = self._jit_step_spec()
        self._spec_slot_steps = 0   # active-slot spec dispatches
        self._spec_emitted = 0      # tokens committed by spec steps
        self._prefix_cache = self.paged and self.cm.prefix_cache \
            and self._pad_ok
        if self._prefix_cache:
            # radix-hit admission: gather prefix pages + prefill the
            # suffix only; compile key = the suffix bucket shape
            self._admit_suffix_fn = self._jit_admit_suffix(
                self._greedy_only)
            # whole-page device copy for copy-on-write
            self._cow_fn = self._jit_cow()
        # (emit arrays, request snapshot) of the last dispatched step, not
        # yet read back — drained after the NEXT dispatch (overlap)
        self._pending = None
        self._steps = 0
        self._readbacks = 0
        self._prefill_shapes: set[tuple] = set()
        self._suffix_shapes: set[int] = set()

    # -- device placement (mesh) ---------------------------------------------

    def _dev(self, x):
        """Replicate a carry buffer on the mesh (identity off-mesh)."""
        return x if self._plan is None else tp.replicate(x, self._plan)

    def _put_cache(self, cache):
        """Place a fresh KV pool on the mesh (kv_heads over ``model``
        when the plan shards heads; identity off-mesh)."""
        return cache if self._plan is None \
            else tp.put_cache(cache, self._plan)

    def _fresh_carries(self) -> None:
        """(Re)build the nine per-slot carry buffers as zeros — shared by
        ``__init__`` and the device-fault recovery (same shapes, so the
        step program never retraces)."""
        slots = self.n_slots
        self._token = self._dev(jnp.zeros((slots,), jnp.int32))
        self._pos = self._dev(jnp.zeros((slots,), jnp.int32))
        self._active = self._dev(jnp.zeros((slots,), jnp.bool_))
        self._emitted = self._dev(jnp.zeros((slots,), jnp.int32))
        self._max_new = self._dev(jnp.zeros((slots,), jnp.int32))
        self._keys = self._dev(jnp.zeros((slots, 2), jnp.uint32))
        self._temp = self._dev(jnp.zeros((slots,), jnp.float32))
        self._topk = self._dev(jnp.zeros((slots,), jnp.int32))
        self._topp = self._dev(jnp.ones((slots,), jnp.float32))

    # -- jitted programs -----------------------------------------------------

    def _jit_step(self, greedy_only: bool):
        """jit (single-device) or jit(shard_map) (mesh) of the step body.
        Carries ride replicated (``P()``); the paged pool is heads-
        sharded; the page table is ``data``-sharded when the slot batch
        is. Donation tuples match the historical single-device jits."""
        fn = self._make_step(greedy_only)
        donate = (1, 2, 3, 4, 5, 7)
        if self._plan is None:
            return jax.jit(fn, donate_argnums=donate)
        rep, kv = P(), tp.kv_specs(self._plan)
        pt = P("data", None) if self._plan.batch else rep
        in_specs = (self._pspecs, kv) + (rep,) * 9 + (pt,)
        out_specs = (kv, rep, rep, rep, rep, rep, (rep, rep))
        return tp.wrap(self._plan, fn, in_specs, out_specs, donate)

    def _jit_admit(self, greedy_only: bool):
        fn = self._make_admit(greedy_only)
        donate = (1, 2, 3, 4, 5, 6, 7, 8, 9, 10)
        if self._plan is None:
            return jax.jit(fn, donate_argnums=donate)
        rep, kv = P(), tp.kv_specs(self._plan)
        # prompt/scalars/pages are all replicated: prefill's batch of one
        # never splits over ``data``; weights shard it over ``model``
        in_specs = (self._pspecs, kv) + (rep,) * (9 + 10)
        out_specs = (kv,) + (rep,) * 10
        return tp.wrap(self._plan, fn, in_specs, out_specs, donate)

    def _jit_admit_suffix(self, greedy_only: bool):
        fn = self._make_admit_suffix(greedy_only)
        donate = (1, 2, 3, 4, 5, 6, 7, 8, 9, 10)
        if self._plan is None:
            return jax.jit(fn, donate_argnums=donate)
        rep, kv = P(), tp.kv_specs(self._plan)
        in_specs = (self._pspecs, kv) + (rep,) * (9 + 12)
        out_specs = (kv,) + (rep,) * 10
        return tp.wrap(self._plan, fn, in_specs, out_specs, donate)

    def _jit_restore(self):
        fn = self._make_restore()
        donate = (0, 1, 2, 3, 4, 5, 6, 7, 8, 9)
        if self._plan is None:
            return jax.jit(fn, donate_argnums=donate)
        rep, kv = P(), tp.kv_specs(self._plan)
        # ``saved`` (the host swap payload) shares the pool's kv_heads
        # axis 3, so each shard writes back only its own head slice
        in_specs = (kv,) + (rep,) * 9 + (kv,) + (rep,) * 10
        out_specs = (kv,) + (rep,) * 9
        return tp.wrap(self._plan, fn, in_specs, out_specs, donate)

    def _jit_cow(self):
        def cow(cache, src, dst):
            return registry.copy_pages(self.cfg, cache, src, dst,
                                       self.page_size)

        if self._plan is None:
            return jax.jit(cow, donate_argnums=(0,))
        rep, kv = P(), tp.kv_specs(self._plan)
        # per-shard page copy: each model shard copies its head slice
        return tp.wrap(self._plan, cow, (kv, rep, rep), kv, (0,))

    def _make_step(self, greedy_only: bool):
        vocab, max_seq = self.cfg.vocab, self.max_seq
        cm, paged = self.cm, self.paged

        def body(params, cache, token, pos, active, emitted, max_new,
                 keys, temp, topk, topp, page_table=None):
            logits, cache = cm.decode(params, cache, token, pos, page_table)
            if greedy_only:
                # all-greedy specialization: no resident request can draw,
                # so the step is the historical bare argmax — the sampling
                # machinery (sorts, softmax, per-slot Gumbel over the
                # vocab) never enters the hot path. The engine retraces
                # once with greedy_only=False if a non-greedy request is
                # ever admitted.
                nxt = jnp.argmax(logits[:, :vocab], axis=-1) \
                    .astype(jnp.int32)
            else:
                # fused per-slot sampling over the whole pool (masked
                # slots draw a token too — exactly like the host engine's
                # unconditional argmax — so families whose decode couples
                # slots, e.g. MoE capacity routing, see an identical pool
                # state). temperature==0 rows are the historical argmax;
                # ``emitted`` is the stream index folded into the key.
                # Under a data-sharded mesh plan the logits rows are this
                # shard's slots only, so the key/param carries slice down
                # to match — the draw itself stays per-slot.
                nxt = sample_tokens(logits[:, :vocab], tp.data_shard(keys),
                                    tp.data_shard(emitted),
                                    tp.data_shard(temp),
                                    tp.data_shard(topk),
                                    tp.data_shard(topp))
            # the decode step's single cross-``data`` exchange: gather the
            # per-slot token back to the full slot axis (identity off-mesh)
            # — stop conditions and the emit pair then stay replicated
            nxt = tp.gather_data(nxt)
            new_pos = pos + 1
            new_emitted = emitted + active.astype(jnp.int32)
            done = active & ((new_emitted >= max_new)
                             | (new_pos >= max_seq - 1))
            new_active = active & ~done
            # the emit pair is computed DIFFERENTLY from the state outputs
            # so its buffers never alias state buffers donated into the
            # next dispatch while the host still holds the emit
            emit_tok = jnp.where(active, nxt, -1)
            return (cache, nxt, new_pos, new_active, new_emitted, keys,
                    (emit_tok, done))

        if paged:
            # the page table is a host-owned np array re-sent each dispatch
            # (tiny: slots * pages_per_slot i32) — NOT donated
            def fused(params, cache, token, pos, active, emitted, max_new,
                      keys, temp, topk, topp, page_table):
                return body(params, cache, token, pos, active, emitted,
                            max_new, keys, temp, topk, topp, page_table)
        else:
            def fused(params, cache, token, pos, active, emitted, max_new,
                      keys, temp, topk, topp):
                return body(params, cache, token, pos, active, emitted,
                            max_new, keys, temp, topk, topp)
        return fused

    def _jit_step_spec(self):
        """jit (or jit(shard_map)) of the fused draft-verify step. Same
        donation tuple and carry layout as the plain step; ``drafts``
        rides replicated like the sampling-parameter buffers, and the
        emit pair widens to ``([B, k+1] tokens, [B] done)``."""
        fn = self._make_step_spec()
        donate = (1, 2, 3, 4, 5, 7)
        if self._plan is None:
            return jax.jit(fn, donate_argnums=donate)
        rep, kv = P(), tp.kv_specs(self._plan)
        pt = P("data", None) if self._plan.batch else rep
        in_specs = (self._pspecs, kv) + (rep,) * 9 + (pt, rep)
        out_specs = (kv, rep, rep, rep, rep, rep, (rep, rep))
        return tp.wrap(self._plan, fn, in_specs, out_specs, donate)

    def _make_step_spec(self):
        """Draft-k-verify-once fused into one program: ``k + 1``
        sequential inner decode steps score the carry token and every
        draft position, acceptance is computed on-device, and each inner
        step's KV write is masked by its own commit flag — rejected
        positions land on the trap page, so the paged pool never sees a
        rejected token.

        Inner step ``j`` feeds ``x_j`` (``x_0`` = the carry token,
        ``x_j`` = draft ``j``) at position ``pos + j`` — the exact
        computation the plain step would run at that point — and emits
        ``t_j = argmax``. Draft ``j`` is accepted while every earlier
        draft was and it equals ``t_{j-1}`` (the token the target just
        emitted), so commit flags are prefix-contiguous and the
        committed stream is bitwise identical to target-only decoding.
        ``j < budget`` caps commits at the request's remaining token /
        sequence budget, mirroring the host's page lookahead. The new
        carry is the last committed ``t_j``; pos/emitted advance by the
        per-slot acceptance count ``e`` in [1, k+1]."""
        vocab, max_seq = self.cfg.vocab, self.max_seq
        cm, k = self.cm, self.spec.k

        def spec_step(params, cache, token, pos, active, emitted,
                      max_new, keys, temp, topk, topp, page_table,
                      drafts):
            budget = jnp.minimum(max_new - emitted, (max_seq - 1) - pos)
            flag = active               # flag_0: the carry always commits
            x = carry = token
            prev_t = token
            emits, commits = [], []
            for j in range(k + 1):
                if j > 0:
                    d_j = drafts[:, j - 1]
                    flag = flag & (d_j == prev_t) & (j < budget)
                    x = d_j
                logits, cache = cm.decode(params, cache, x, pos + j,
                                          page_table, write_mask=flag)
                t_j = jnp.argmax(logits[:, :vocab], axis=-1) \
                    .astype(jnp.int32)
                t_j = tp.gather_data(t_j)
                carry = jnp.where(flag, t_j, carry)
                emits.append(jnp.where(flag, t_j, -1))
                commits.append(flag)
                prev_t = t_j
            e = sum(c.astype(jnp.int32) for c in commits)
            new_pos = pos + e
            new_emitted = emitted + e
            done = active & ((new_emitted >= max_new)
                             | (new_pos >= max_seq - 1))
            new_active = active & ~done
            emit_tok = jnp.stack(emits, axis=1)          # [B, k+1]
            return (cache, carry, new_pos, new_active, new_emitted,
                    keys, (emit_tok, done))

        return spec_step

    def _make_admit(self, greedy_only: bool):
        cfg, vocab = self.cfg, self.cfg.vocab
        encdec = cfg.family == "encdec"
        pad_ok = self._pad_ok
        cm, paged = self.cm, self.paged

        def body(params, cache, token, pos, active, emitted, max_new,
                 keys, temp, topk, topp, prompt, length, slot, req_max_new,
                 req_emitted, seed, s_temp, s_topk, s_topp, pages=None):
            # req_emitted carries the cumulative emit count across requeues
            # (recompute preemption: the generated prefix is already in the
            # prompt and in out_tokens) — it is also the sampling index of
            # the token this prefill emits, minus one. ``pages`` (paged
            # only) is the physical destination of each logical prompt
            # page, trap-padded to the bucket, so the compile key stays
            # (bucket shape).
            logits, kv = registry.prefill(
                params, cfg, prompt[None],
                length=length if pad_ok else None)
            cache = cm.write(cache, kv, slot=slot, pages=pages)
            key = jax.random.PRNGKey(seed)
            if greedy_only:
                # all-greedy specialization, mirroring _make_step: tok0 is
                # the historical bare argmax; the key/param buffers are
                # still written so a later greedy_only=False retrace sees
                # a consistent carry
                tok0 = jnp.argmax(logits[0, :vocab]).astype(jnp.int32)
            else:
                tok0 = sample_tokens(logits[:, :vocab], key[None],
                                     (req_emitted - 1)[None], s_temp[None],
                                     s_topk[None], s_topp[None])[0]
            start = jnp.int32(1) if encdec else length
            token = token.at[slot].set(tok0)
            pos = pos.at[slot].set(start)
            active = active.at[slot].set(True)
            emitted = emitted.at[slot].set(req_emitted)
            max_new = max_new.at[slot].set(req_max_new)
            keys = keys.at[slot].set(key)
            temp = temp.at[slot].set(s_temp)
            topk = topk.at[slot].set(s_topk)
            topp = topp.at[slot].set(s_topp)
            return (cache, token, pos, active, emitted, max_new, keys,
                    temp, topk, topp, tok0)

        if paged:
            def admit(params, cache, token, pos, active, emitted, max_new,
                      keys, temp, topk, topp, prompt, length, slot,
                      req_max_new, req_emitted, seed, s_temp, s_topk,
                      s_topp, pages):
                return body(params, cache, token, pos, active, emitted,
                            max_new, keys, temp, topk, topp, prompt,
                            length, slot, req_max_new, req_emitted, seed,
                            s_temp, s_topk, s_topp, pages)
        else:
            def admit(params, cache, token, pos, active, emitted, max_new,
                      keys, temp, topk, topp, prompt, length, slot,
                      req_max_new, req_emitted, seed, s_temp, s_topk,
                      s_topp):
                return body(params, cache, token, pos, active, emitted,
                            max_new, keys, temp, topk, topp, prompt,
                            length, slot, req_max_new, req_emitted, seed,
                            s_temp, s_topk, s_topp)
        return admit

    def _make_admit_suffix(self, greedy_only: bool):
        """Radix-hit admission: the prompt's first ``prefix_len`` positions
        are already resident (tree pages mapped read-only into the slot's
        table), so only the suffix is prefilled — against prefix rows
        gathered from the pool. ``prefix_pages`` is trap-padded to the
        full ``pages_per_slot`` and ``prefix_len``/``s_len`` are traced,
        so the compile key is the suffix bucket shape alone."""
        cfg, vocab = self.cfg, self.cfg.vocab
        cm = self.cm

        def admit(params, cache, token, pos, active, emitted, max_new,
                  keys, temp, topk, topp, suffix, s_len, prefix_len,
                  prefix_pages, suffix_pages, slot, req_max_new,
                  req_emitted, seed, s_temp, s_topk, s_topp):
            prefix = cm.read(cache, prefix_pages)
            logits, kv = registry.prefill_suffix(
                params, cfg, suffix[None], prefix,
                prefix_len=prefix_len, length=s_len)
            cache = cm.write(cache, kv, pages=suffix_pages)
            key = jax.random.PRNGKey(seed)
            if greedy_only:
                tok0 = jnp.argmax(logits[0, :vocab]).astype(jnp.int32)
            else:
                tok0 = sample_tokens(logits[:, :vocab], key[None],
                                     (req_emitted - 1)[None], s_temp[None],
                                     s_topk[None], s_topp[None])[0]
            start = prefix_len + s_len        # true prompt length
            token = token.at[slot].set(tok0)
            pos = pos.at[slot].set(start)
            active = active.at[slot].set(True)
            emitted = emitted.at[slot].set(req_emitted)
            max_new = max_new.at[slot].set(req_max_new)
            keys = keys.at[slot].set(key)
            temp = temp.at[slot].set(s_temp)
            topk = topk.at[slot].set(s_topk)
            topp = topp.at[slot].set(s_topp)
            return (cache, token, pos, active, emitted, max_new, keys,
                    temp, topk, topp, tok0)

        return admit

    def _make_restore(self):
        """Jitted swap-in: write a victim's saved pages back into (new)
        physical pages and restore its device slot state verbatim (the
        sampling key is rebuilt from the seed — it is a pure function of
        it, so the restored stream replays the same (seed, index) draws)."""
        cm = self.cm

        def restore(cache, token, pos, active, emitted, max_new, keys,
                    temp, topk, topp, saved, tok, dpos, demitted,
                    req_max_new, seed, s_temp, s_topk, s_topp, slot, pages):
            cache = cm.write(cache, saved, pages=pages)
            token = token.at[slot].set(tok)
            pos = pos.at[slot].set(dpos)
            active = active.at[slot].set(True)
            emitted = emitted.at[slot].set(demitted)
            max_new = max_new.at[slot].set(req_max_new)
            keys = keys.at[slot].set(jax.random.PRNGKey(seed))
            temp = temp.at[slot].set(s_temp)
            topk = topk.at[slot].set(s_topk)
            topp = topp.at[slot].set(s_topp)
            return (cache, token, pos, active, emitted, max_new, keys,
                    temp, topk, topp)

        return restore

    # -- request lifecycle ---------------------------------------------------

    @property
    def queue(self):
        """Back-compat view of the waiting queue (the scheduler; truthy
        while requests wait, len() = waiting count)."""
        return self.scheduler

    @property
    def _pool(self):
        """Back-compat handle to the paged allocator (None if contiguous)."""
        return self.cm.pool if self.paged else None

    def submit(self, req: Request):
        req.t_submit = time.perf_counter()
        req.arrival = self._arrivals
        self._arrivals += 1
        if req.deadline_s is not None:
            self._has_deadlines = True
        msg = self._admission_error(req)
        if msg is not None:
            self._finish(req, "rejected", msg)
            return
        self.scheduler.push(req)

    def _admission_error(self, req: Request) -> Optional[str]:
        """Admission validation: the reason ``req`` can never be served
        (rejected up front, instead of wedging the FIFO head or blowing
        up inside a jitted prefill), or None when it is admissible."""
        prompt = np.asarray(req.prompt)
        n = len(prompt)
        if n == 0:
            return "empty prompt"
        if prompt.ndim == 1:           # token frontend
            if not np.issubdtype(prompt.dtype, np.integer):
                return ("token prompt must be integer-typed, got "
                        f"{prompt.dtype}")
            lo, hi = int(prompt.min()), int(prompt.max())
            if lo < 0 or hi >= self.cfg.vocab:
                return (f"token id {lo if lo < 0 else hi} outside "
                        f"[0, {self.cfg.vocab})")
        else:                          # frames frontend [S, D]
            if not np.all(np.isfinite(prompt)):
                return "non-finite values in frame prompt"
        if n > self.max_seq - 1:
            return (f"prompt length {n} cannot fit max_seq={self.max_seq} "
                    "(no room to emit a token)")
        if self.spec is not None:
            sp = req.sampling if req.sampling is not None \
                else self.default_sampling
            if not sp.greedy:
                return ("speculative decoding verifies drafts against "
                        "the greedy (argmax) target stream; non-greedy "
                        "sampling cannot serve with spec enabled")
        return self.cm.infeasible(n)

    def _finish(self, req: Request, reason: str,
                error: Optional[str] = None) -> None:
        """Terminal bookkeeping for every lifecycle outcome."""
        req.done = True
        req.finish_reason = reason
        req.error = error
        self.finished.append(req)
        if reason in self._lifecycle:
            self._lifecycle[reason] += 1

    def _cancel_resident(self, i: int, reason: str,
                         error: Optional[str] = None) -> None:
        """Pull slot ``i``'s occupant out of residency and finish it:
        deactivate the device slot (later dispatches route its masked
        writes to the trap page) and release its pages through the normal
        ``CacheManager.evict`` path — private pages free, tree-shared
        prefix pages survive through their radix refs. The caller must
        have drained the pending emit first (the overlapped readback
        snapshot must not resurrect the request)."""
        assert self._pending is None
        slot = self.slots[i]
        req = slot.req
        slot.req = None
        slot.dactive = False
        slot.dpos = slot.demitted = 0
        self._active = self._active.at[i].set(False)
        self.cm.evict(i)
        self._finish(req, reason, error)

    def abort(self, rid: int, *, reason: str = "aborted",
              error: Optional[str] = None) -> bool:
        """Cancel the live request named ``rid`` wherever it currently
        lives — waiting (including swapped-out preemption victims) or
        resident mid-decode. True when a live request was found; the
        request is finished (usually ``finish_reason="aborted"``) when
        the call returns. A resident target is settled through a drain
        first, so an abort that races the natural finish resolves to
        whichever happened first."""
        for req in self.scheduler.waiting():
            if req.rid == rid and not req.done:
                self.scheduler.remove(req)
                req.swap_state = None    # swapped victim: pages were freed
                self._finish(req, reason, error)
                return True
        for i, slot in enumerate(self.slots):
            if slot.req is not None and slot.req.rid == rid:
                self._drain()
                if self.slots[i].req is not None \
                        and self.slots[i].req.rid == rid:
                    self._cancel_resident(i, reason, error)
                return True
        return False

    def cancel_request(self, req: Request, reason: str = "aborted",
                       error: Optional[str] = None) -> bool:
        """``abort`` by identity instead of rid (the facade's handle)."""
        if req.done:
            return False
        if self.scheduler.remove(req):
            req.swap_state = None
            self._finish(req, reason, error)
            return True
        for i, slot in enumerate(self.slots):
            if slot.req is req:
                self._drain()
                if self.slots[i].req is req:
                    self._cancel_resident(i, reason, error)
                return True
        return False

    def _expire_deadlines(self) -> None:
        """Finish every request whose wall-clock budget ran out — waiting
        requests leave the queue, resident ones are cancelled through the
        same rollback path as ``abort``."""
        now = time.perf_counter()

        def expired(req):
            return (req.deadline_s is not None
                    and now - req.t_submit >= req.deadline_s)

        for req in self.scheduler.waiting():
            if expired(req):
                self.scheduler.remove(req)
                req.swap_state = None
                self._finish(req, "deadline")
        if any(s.req is not None and expired(s.req) for s in self.slots):
            self._drain()
            for i, slot in enumerate(self.slots):
                if slot.req is not None and expired(slot.req):
                    self._cancel_resident(i, "deadline")

    def _sampling_of(self, req: Request) -> SamplingParams:
        sp = req.sampling if req.sampling is not None \
            else self.default_sampling
        if self._greedy_only and not sp.greedy:
            # first non-greedy admission: swap the all-greedy specialized
            # step/admit programs for the sampling ones (one retrace per
            # program + bucket; the carry layout is identical, so
            # in-flight state is unaffected)
            self._greedy_only = False
            self._step_fn = self._jit_step(False)
            n = _jit_cache_size(self._admit_fn)
            if n is not None:
                self._compiles_base += n
            self._admit_fn = self._jit_admit(False)
            if self._prefix_cache:
                n = _jit_cache_size(self._admit_suffix_fn)
                if n is not None:
                    self._compiles_base += n
                self._admit_suffix_fn = self._jit_admit_suffix(False)
        return sp

    def _bucket_len(self, n: int) -> Optional[int]:
        """Padded prompt length, or None for an exact-length prefill."""
        if not self._pad_ok:
            return None
        cap = min(self.max_seq, self.cfg.window or self.max_seq)
        if n > cap:
            return None            # longer than the paddable window: exact
        b = 1
        while b < n:
            b *= 2
        return min(b, cap)

    def _suffix_bucket(self, s_len: int) -> int:
        """Suffix-prefill bucket: pow2 like ``_bucket_len`` but floored at
        one page, so tiny suffixes (the common radix-hit case) all share
        one compiled program instead of one per pow2 below page_size."""
        b = self._bucket_len(s_len)
        return max(self.page_size, b if b is not None else s_len)

    def _readmit_swapped(self, i: int, slot: _Slot, req: Request) -> bool:
        """Swap-in re-admission: restore the victim's saved pages + device
        state byte-for-byte (no prefill, no token emitted). False when the
        pool cannot hold the pages yet (head-of-line waits)."""
        saved, tok, dpos, demitted, n_real, draft_saved = req.swap_state
        if not self.cm.restore(i, n_real):
            return False
        self.scheduler.pop()
        pages = jnp.asarray(self.cm.pages_of(i))
        sp = self._sampling_of(req)
        try:
            out = self._dispatch_restore(i, req, sp, pages)
        except RuntimeError as e:
            # failure isolation: a faulted swap-in fails this request
            # alone (the hold rolls back; the slot refills next step)
            self.cm.evict(i)
            req.swap_state = None
            self._finish(req, "failed", f"swap-restore fault: {e}")
            return True
        (self.cache, self._token, self._pos, self._active, self._emitted,
         self._max_new, self._keys, self._temp, self._topk,
         self._topp) = out
        if draft_saved is not None and self._drafter is not None:
            # drafter state comes back byte-for-byte with the target's
            # pages, so the restored stream's draft proposals replay
            # exactly as an undisturbed run's would
            self._drafter.restore_slot(i, draft_saved)
        req.swap_state = None
        slot.req = req
        slot.dpos = dpos
        slot.demitted = demitted
        slot.dactive = True
        return True

    def _dispatch_restore(self, i: int, req: Request, sp, pages):
        saved, tok, dpos, demitted = req.swap_state[:4]
        with _quiet_donation():
            return self._restore_fn(
                self.cache, self._token, self._pos, self._active,
                self._emitted, self._max_new, self._keys, self._temp,
                self._topk, self._topp,
                jax.tree.map(jnp.asarray, saved), jnp.int32(tok),
                jnp.int32(dpos), jnp.int32(demitted),
                jnp.int32(req.max_new_tokens),
                jnp.int32(sp.resolve_seed(req.rid)),
                jnp.float32(sp.temperature), jnp.int32(sp.top_k),
                jnp.float32(sp.top_p), jnp.int32(i), pages)

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot.req is None and len(self.scheduler):
                req = self.scheduler.peek()
                if self.paged and req.swap_state is not None:
                    if not self._readmit_swapped(i, slot, req):
                        return     # head-of-line: admission waits for pages
                    continue
                prompt = np.asarray(req.prompt)
                if req.out_tokens:
                    # recompute re-admission after preemption: the generated
                    # prefix joins the prompt, so prefill rebuilds the exact
                    # logical cache the victim lost
                    prompt = np.concatenate(
                        [prompt, np.asarray(req.out_tokens, prompt.dtype)])
                n = len(prompt)
                b = self._bucket_len(n)
                if self._prefix_cache:
                    # radix-aware hold: maps the longest cached prefix
                    # read-only + reserves private pages for the rest
                    plan = self.cm.admit_prompt(i, prompt)
                    if plan is None:
                        return     # head-of-line: admission waits for pages
                else:
                    plan = None
                    if not self.cm.alloc(i, n):
                        return     # head-of-line: admission waits for pages
                self.scheduler.pop()
                sp = self._sampling_of(req)
                try:
                    if plan is not None and plan["suffix_start"] > 0:
                        tok0 = self._dispatch_suffix(i, req, prompt, n,
                                                     plan, sp)
                        req.prefix_hit_tokens += plan["suffix_start"]
                    else:
                        pages_arg = None
                        if self.paged:
                            pages_arg = jnp.asarray(
                                self.cm.prefill_pages(i, n, b))
                        if b is not None and b > n:
                            pad = np.zeros((b - n,) + prompt.shape[1:],
                                           prompt.dtype)
                            prompt = np.concatenate([prompt, pad])
                        self._prefill_shapes.add(prompt.shape)
                        args = (self.params, self.cache, self._token,
                                self._pos, self._active, self._emitted,
                                self._max_new, self._keys, self._temp,
                                self._topk, self._topp, jnp.asarray(prompt),
                                jnp.int32(n), jnp.int32(i),
                                jnp.int32(req.max_new_tokens),
                                jnp.int32(len(req.out_tokens) + 1),
                                jnp.int32(sp.resolve_seed(req.rid)),
                                jnp.float32(sp.temperature),
                                jnp.int32(sp.top_k), jnp.float32(sp.top_p))
                        if self.paged:
                            args += (pages_arg,)
                        with _quiet_donation():
                            out = self._admit_fn(*args)
                        (self.cache, self._token, self._pos, self._active,
                         self._emitted, self._max_new, self._keys,
                         self._temp, self._topk, self._topp, tok0) = out
                    if self._drafter is not None:
                        # the drafter mirrors the FULL prompt (generated
                        # prefix included on recompute re-admission, the
                        # radix-served prefix included on suffix hits —
                        # the draft cache has no page sharing), so its
                        # carry invariant matches the target's exactly
                        self._drafter.prefill(i, prompt[:n])
                except RuntimeError as e:
                    # failure isolation: a faulted prefill (XLA launch /
                    # runtime error) fails this request alone — its
                    # admission hold rolls back and the slot refills on
                    # the next step (deactivated in case the fault hit
                    # after the target admit already marked it active)
                    self._active = self._active.at[i].set(False)
                    self.cm.evict(i)
                    self._finish(req, "failed", f"prefill fault: {e}")
                    continue
                if self.paged:
                    # the prompt's full pages are now written (prefill
                    # covers 0..n-1) — publish them to the radix tree so
                    # later admissions can share them (no-op when disabled)
                    self.cm.insert_prompt(i, prompt[:n], n)
                was_requeued = bool(req.out_tokens)
                req.out_tokens.append(int(tok0))
                if not req.t_first:
                    req.t_first = time.perf_counter()
                if self.paged and was_requeued \
                        and (len(req.out_tokens) >= req.max_new_tokens
                             or n >= self.max_seq - 1):
                    # Recompute re-admission delivered the request's FINAL
                    # token: in the straight-through run this token came
                    # from the decode step that fired the stop condition,
                    # so it must not decode again. (A fresh admission never
                    # checks — the reference engine always decodes at least
                    # one step after prefill.)
                    self._finish(req, "done")
                    self._active = self._active.at[i].set(False)
                    self.cm.evict(i)
                    continue
                slot.req = req
                slot.dpos = 1 if self.cfg.family == "encdec" else n
                slot.demitted = len(req.out_tokens)
                slot.dactive = True

    def _dispatch_suffix(self, i: int, req: Request, prompt: np.ndarray,
                         n: int, plan: dict, sp) -> int:
        """Dispatch a radix-hit admission: optional copy-on-write page
        duplication, then the suffix-only prefill program."""
        ss = plan["suffix_start"]
        s_len = n - ss
        sb = self._suffix_bucket(s_len)
        suffix = prompt[ss:]
        if sb > s_len:
            pad = np.zeros((sb - s_len,) + suffix.shape[1:], suffix.dtype)
            suffix = np.concatenate([suffix, pad])
        if plan["cow"] is not None:
            # a full-prompt match re-prefills its final page into a fresh
            # private copy; duplicate the shared page's bytes first so the
            # copy also holds rows the suffix program won't rewrite
            src, dst = plan["cow"]
            with _quiet_donation():
                self.cache = self._cow_fn(self.cache, jnp.int32(src),
                                          jnp.int32(dst))
        self._suffix_shapes.add(sb)
        args = (self.params, self.cache, self._token, self._pos,
                self._active, self._emitted, self._max_new,
                self._keys, self._temp, self._topk, self._topp,
                jnp.asarray(suffix), jnp.int32(s_len), jnp.int32(ss),
                jnp.asarray(self.cm.prefix_page_vec(i, ss)),
                jnp.asarray(self.cm.suffix_pages(i, ss, n, sb)),
                jnp.int32(i), jnp.int32(req.max_new_tokens),
                jnp.int32(len(req.out_tokens) + 1),
                jnp.int32(sp.resolve_seed(req.rid)),
                jnp.float32(sp.temperature), jnp.int32(sp.top_k),
                jnp.float32(sp.top_p))
        with _quiet_donation():
            out = self._admit_suffix_fn(*args)
        (self.cache, self._token, self._pos, self._active,
         self._emitted, self._max_new, self._keys, self._temp,
         self._topk, self._topp, tok0) = out
        return tok0

    # -- paged pool growth / preemption --------------------------------------

    def _preempt(self, victim: int) -> None:
        """Evict the occupant of ``victim``: free its residency, deactivate
        the device slot, and hand the request back to the scheduler with
        requeue precedence. The ``PreemptionPolicy`` decides what happens
        to the KV: ``"swap"`` first copies the victim's pages and device
        state to host for a byte-exact swap-in later; ``"recompute"``
        drops them — re-admission folds the generated prefix into the
        prompt and re-prefills. Caller must have drained the pending emit
        (the victim's stream must be settled before its pages are
        reused)."""
        assert self._pending is None
        slot = self.slots[victim]
        req = slot.req
        if self.preemption.mode == "swap":
            owned = self.cm.pages_of(victim)
            saved = self.cm.read(self.cache, jnp.asarray(owned))
            draft_saved = self._drafter.snapshot_slot(victim) \
                if self._drafter is not None else None
            req.swap_state = (
                jax.tree.map(np.asarray, saved),      # host copy (swap out)
                int(np.asarray(self._token)[victim]),
                slot.dpos, slot.demitted, len(owned), draft_saved)
        self.cm.evict(victim)
        slot.req = None
        slot.dactive = False
        self._active = self._active.at[victim].set(False)
        req.preemptions += 1
        self.preemptions += 1
        self.scheduler.requeue(req)

    def _ensure_pages(self) -> None:
        """Before a dispatch, make every device-active slot's next write
        position storage-backed. On pool exhaustion: settle the in-flight
        step (finished slots free pages), then let the preemption policy
        pick a victim (youngest occupant by default) until the write
        fits. Under speculative decoding a step may commit up to ``k+1``
        positions, so the lookahead covers the slot's worst-case commit
        (capped by its remaining token/sequence budget — the device's
        ``j < budget`` commit gate mirrors exactly this bound, so no
        committed write can ever land on an unbacked page)."""
        for i in range(self.n_slots):
            slot = self.slots[i]
            if slot.req is None or not slot.dactive:
                continue
            need = 1
            if self.spec is not None:
                budget = min(slot.req.max_new_tokens - slot.demitted,
                             (self.max_seq - 1) - slot.dpos)
                need = max(1, min(self.spec.k + 1, budget))
            while not self.cm.backed(i, slot.dpos + need - 1):
                if self.cm.grow(i):
                    continue
                self._drain()
                if self.slots[i].req is None or not self.slots[i].dactive:
                    break              # the drain settled this very slot
                if self.cm.has_free:
                    continue           # the drain freed finished slots
                occ = [(j, self.slots[j].req) for j in range(self.n_slots)
                       if self.slots[j].req is not None]
                victim = self.preemption.select_victim(occ)
                self._preempt(victim)
                if victim == i:
                    break              # preempted ourselves; requeued

    # -- failure isolation / crash recovery ----------------------------------

    def _reject_unadmittable_head(self) -> bool:
        """Infeasibility watchdog: the engine is quiescent (no resident
        slot, nothing in flight) yet the head of line was not admitted.
        If the head can NEVER fit — page demand exceeding the whole pool
        or the sequence budget — reject it instead of deadlocking every
        request behind it. Transient causes (chaos page holds, custom
        managers withholding capacity) return False and leave the head
        queued."""
        req = self.scheduler.peek()
        if req is None or req.swap_state is not None:
            return False               # swapped victims always fit again
        n = len(req.prompt) + len(req.out_tokens)
        if n > self.max_seq - 1:
            msg = (f"sequence length {n} cannot fit max_seq="
                   f"{self.max_seq} (no room to emit a token)")
        else:
            msg = self.cm.infeasible(n)
        if msg is None:
            return False
        self.scheduler.remove(req)      # not an admission: no pop stats
        self._finish(req, "rejected", msg)
        return True

    def _recover_step_fault(self, exc: BaseException) -> None:
        """Crash-consistent rollback after a faulted decode dispatch.

        The fault surfaced *in place of* the dispatch (a failed XLA
        launch — or the chaos harness's stand-in for one — leaves its
        donated inputs unconsumed), so carry buffers and cache still hold
        the valid pre-step state. Sequence: settle the overlapped emit
        (it predates the fault), quarantine the faulting slot's request
        (``exc.slot`` when the fault names one, else the preemption
        policy's victim), swap every surviving occupant's pages + device
        state to host byte-for-byte, reset the device pool and carry
        outright, and requeue the survivors — their restored streams
        finish bit-identical to an undisturbed run. If the carry WAS lost
        with the fault (mid-kernel device failure), the byte-exact read
        raises and survivors fall back to recompute (token frontends) or
        fail (frames)."""
        self._drain()
        bad = getattr(exc, "slot", None)
        if bad is not None and not (0 <= bad < self.n_slots
                                    and self.slots[bad].req is not None):
            bad = None
        occ = [(i, s.req) for i, s in enumerate(self.slots)
               if s.req is not None]
        if bad is None and occ:
            bad = self.preemption.select_victim(occ)
        survivors: list[Request] = []
        for i, slot in enumerate(self.slots):
            req = slot.req
            if req is None or i == bad:
                continue
            req.swap_state = None
            if self.paged:
                try:
                    # byte-exact swap-out BEFORE the pool reset — restore
                    # then replays the exact device state, keeping the
                    # survivor's stream bit-identical
                    owned = self.cm.pages_of(i)
                    saved = self.cm.read(self.cache, jnp.asarray(owned))
                    draft_saved = (
                        self._drafter.snapshot_slot(i)
                        if self._drafter is not None
                        and self._drafter.stateful else None)
                    req.swap_state = (
                        jax.tree.map(np.asarray, saved),
                        int(np.asarray(self._token)[i]),
                        slot.dpos, slot.demitted, len(owned), draft_saved)
                except RuntimeError:
                    req.swap_state = None   # carry died with the fault
            if req.swap_state is None \
                    and np.asarray(req.prompt).ndim != 1:
                # frames frontend without a byte-exact copy: generated
                # tokens cannot be folded back into a float prompt
                self._finish(req, "failed",
                             f"lost to device-fault recovery: {exc}")
                slot.req = None
                continue
            req.preemptions += 1
            survivors.append(req)
        for i, slot in enumerate(self.slots):
            req, slot.req = slot.req, None
            slot.dactive = False
            slot.dpos = slot.demitted = 0
            self.cm.evict(i)
            if req is not None and i == bad:
                self._finish(req, "failed", f"device step fault: {exc}")
        # reversed: slot 0's occupant ends up at the head of the queue,
        # so re-admission preserves the slot order survivors held
        for req in reversed(survivors):
            self.scheduler.requeue(req)
        if self.paged:
            # the radix tree's cached KV died with the pool
            self.cm.clear_tree()
            self.cm.pool.check()
        # rebuild the device-side state (same shapes and shardings: no
        # retrace, and mesh placements survive the recovery)
        self.cache = self._put_cache(self.cm.init())
        self._fresh_carries()
        if self._drafter is not None:
            # the draft cache shares the device that faulted: drop it and
            # replay survivors' drafter rows from their snapshots on
            # re-admission (byte-for-byte, like the target pages)
            self._drafter.reset()
        self.recoveries += 1

    # -- one engine step -----------------------------------------------------

    def has_work(self) -> bool:
        """True while anything is queued, in flight, or resident."""
        return bool(len(self.scheduler) or self._pending is not None
                    or any(s.req is not None for s in self.slots))

    def step(self) -> bool:
        step_no = self._steps
        if self.chaos is not None:
            self.chaos.on_step(self, step_no)
        if self._has_deadlines:
            self._expire_deadlines()
        if self._pending is not None and \
                (len(self.scheduler)
                 and all(s.req is not None for s in self.slots)
                 or all(s.req is None or not s.dactive
                        for s in self.slots)):
            # Catch up on the pending emit when it can change what to do
            # next: either its done flags may free slots for the waiting
            # queue (admission timing then matches the host-driven engine
            # under queue pressure), or EVERY occupied slot finishes inside
            # it — dispatching before applying would burn one all-masked
            # decode step at the tail of each run.
            self._drain()
        self._admit()
        if self.paged:
            self._ensure_pages()
        if not any(s.req is not None for s in self.slots):
            self._drain()
            self._admit()
            if self.paged:
                self._ensure_pages()
            if not any(s.req is not None for s in self.slots):
                if len(self.scheduler):
                    # quiescent with a wedged head of line: reject it if
                    # it can never be admitted (deadlock watchdog) …
                    if self._reject_unadmittable_head():
                        return True
                    # … or end a chaos page hold that alone blocks
                    # progress, and retry on the next step
                    if self.chaos is not None and self.chaos.relent(self):
                        return True
                return False
        args = (self.params, self.cache, self._token, self._pos,
                self._active, self._emitted, self._max_new, self._keys,
                self._temp, self._topk, self._topp)
        args += tuple(jnp.asarray(x) for x in self.cm.step_extra())
        try:
            if self.chaos is not None:
                # BEFORE the draft propose: an injected fault then leaves
                # the drafter's donated cache unconsumed, exactly like the
                # target carries
                self.chaos.pre_dispatch(self, step_no)
            if self.spec is not None:
                drafts = self._drafter.propose(self.slots, self._token,
                                               self._pos)
                with _quiet_donation():
                    out = self._spec_step_fn(*args, jnp.asarray(drafts))
            else:
                with _quiet_donation():
                    out = self._step_fn(*args)
        except RuntimeError as e:     # XlaRuntimeError subclasses this
            self._recover_step_fault(e)
            return True
        (self.cache, self._token, self._pos, self._active,
         self._emitted, self._keys, emit) = out
        if self.chaos is not None:
            emit = self.chaos.filter_emit(step_no, emit)
        self._steps += 1
        if self.spec is not None:
            # variable acceptance: the host shadows can only advance from
            # the actual commit counts, so spec mode settles every step
            # immediately (no readback overlap). The one-batched-readback-
            # per-step invariant is untouched — exactly one _apply_spec per
            # dispatched step, and readbacks == steps stays exact-gated.
            self._apply_spec((emit, [s.req for s in self.slots]))
            self._sample_page_stats()
            return True
        # mirror the device's deterministic stop conditions on the host
        # shadows (the readback of this step is still in flight)
        for s in self.slots:
            if s.req is not None and s.dactive:
                s.demitted += 1
                s.dpos += 1
                if (s.demitted >= s.req.max_new_tokens
                        or s.dpos >= self.max_seq - 1):
                    s.dactive = False
        if self.paged:
            self._sample_page_stats()
        prev, self._pending = self._pending, (emit,
                                              [s.req for s in self.slots])
        if prev is not None:
            self._apply(prev)           # readback of step k-1 overlaps k
        return True

    def _sample_page_stats(self):
        rows = {i: min(s.dpos, self.max_seq)
                for i, s in enumerate(self.slots) if s.req is not None}
        self.cm.note_step(rows)

    def flush(self):
        """Settle the in-flight readback (public form of the drain the
        run loop does at exit — the streaming facade calls this)."""
        self._drain()

    def _drain(self):
        if self._pending is not None:
            prev, self._pending = self._pending, None
            self._apply(prev)

    def _apply(self, pending):
        (emit_tok, done), reqs = pending
        # THE host readback: one batched device->host transfer settles a
        # whole dispatched step (sharded runs included — the emit pair is
        # replicated by construction, so no extra per-shard transfers).
        # Counted so the bench CI can gate one-readback-per-step exactly.
        self._readbacks += 1
        tok = np.asarray(emit_tok)
        fin = np.asarray(done)
        for i, req in enumerate(reqs):
            if req is None or req.done or tok[i] == -1:
                # ``req.done``: a request quarantined by the corrupt-
                # readback path below was still device-active when the
                # overlapped NEXT snapshot was taken — its late tokens
                # must not resurrect the finished stream
                continue
            t = int(tok[i])
            if t < 0 or t >= self.cfg.vocab:
                # corrupt/NaN readback: a valid emit is -1 or a vocab id,
                # nothing else. Only this request is quarantined — the
                # other slots' device state is untouched, so their
                # streams continue undisturbed.
                if self.slots[i].req is req:
                    self.slots[i].req = None
                    self.slots[i].dactive = False
                    self._active = self._active.at[i].set(False)
                    self.cm.evict(i)
                self._finish(req, "failed",
                             f"corrupt readback: token {t} outside "
                             f"[0, {self.cfg.vocab})")
                continue
            req.out_tokens.append(t)
            if fin[i]:
                self._finish(req, "done")
                if self.slots[i].req is req:
                    if self._prefix_cache:
                        # publish the full sequence's pages before freeing
                        # them: coverage stops one short of the end — the
                        # final emitted token's KV row was never written
                        # (and the overlapped extra dispatch may write
                        # there), so only strictly-earlier full pages are
                        # valid
                        prompt = np.asarray(req.prompt)
                        toks = np.concatenate(
                            [prompt,
                             np.asarray(req.out_tokens, prompt.dtype)])
                        self.cm.insert_prompt(i, toks, len(toks) - 1)
                    self.slots[i].req = None
                    # (paged) later dispatches route this slot's masked
                    # writes to the trap page; its pages are safe to reuse
                    self.cm.evict(i)

    def _apply_spec(self, pending):
        """Settle a spec step: ONE batched readback of the ``[slots,
        k+1]`` commit matrix + done flags; each slot's host shadows then
        advance by its actual acceptance count. Commit rows are prefix-
        contiguous by construction (-1 past the accepted prefix), so the
        committed tokens are ``row[row != -1]`` and the per-request
        ordering matches target-only decoding bit for bit."""
        (emit_tok, done), reqs = pending
        self._readbacks += 1
        tok = np.asarray(emit_tok)
        fin = np.asarray(done)
        for i, req in enumerate(reqs):
            if req is None or req.done:
                continue
            row = tok[i]
            committed = row[row != -1]
            if committed.size == 0:
                continue        # slot idle this step: nothing committed
            if ((committed < 0) | (committed >= self.cfg.vocab)).any():
                # corrupt/NaN readback: quarantine this request only (the
                # plain path's contract — other slots' device state is
                # untouched and their streams continue undisturbed)
                if self.slots[i].req is req:
                    self.slots[i].req = None
                    self.slots[i].dactive = False
                    self._active = self._active.at[i].set(False)
                    self.cm.evict(i)
                bad = int(committed[
                    (committed < 0) | (committed >= self.cfg.vocab)][0])
                self._finish(req, "failed",
                             f"corrupt readback: token {bad} outside "
                             f"[0, {self.cfg.vocab})")
                continue
            e = int(committed.size)
            self._spec_slot_steps += 1
            self._spec_emitted += e
            req.accepted_tokens += e - 1    # e = 1 carry + (e-1) drafts
            req.out_tokens.extend(int(t) for t in committed)
            slot = self.slots[i]
            if slot.req is req and slot.dactive:
                slot.demitted += e
                slot.dpos += e
                if (slot.demitted >= req.max_new_tokens
                        or slot.dpos >= self.max_seq - 1):
                    slot.dactive = False
            if fin[i]:
                self._finish(req, "done")
                if slot.req is req:
                    if self._prefix_cache:
                        # identical coverage rule to the plain path: stop
                        # one short of the end — the final committed
                        # token's KV row was never written
                        prompt = np.asarray(req.prompt)
                        toks = np.concatenate(
                            [prompt,
                             np.asarray(req.out_tokens, prompt.dtype)])
                        self.cm.insert_prompt(i, toks, len(toks) - 1)
                    slot.req = None
                    self.cm.evict(i)

    def run(self, max_steps: int = 10_000):
        while max_steps > 0 and self.has_work():
            if not self.step():
                break
            max_steps -= 1
        self._drain()
        return self.finished

    # -- introspection -------------------------------------------------------

    def stats(self) -> dict:
        """Decode steps, prefill retrace count, bucket coverage, scheduler
        counters, and (paged) preemption + page-pool utilization/
        fragmentation."""
        n = _jit_cache_size(self._admit_fn)
        if n is None:       # private jax API gone: shape-count fallback
            prefill_compiles = len(self._prefill_shapes) \
                + len(self._suffix_shapes)
        else:
            prefill_compiles = self._compiles_base + n
            if self._prefix_cache:
                prefill_compiles += \
                    _jit_cache_size(self._admit_suffix_fn) or 0
        out = {
            "steps": self._steps,
            "readbacks": self._readbacks,
            "prefill_compiles": int(prefill_compiles),
            "prefill_shapes": sorted(s[0] for s in self._prefill_shapes),
            "suffix_shapes": sorted(self._suffix_shapes),
            "pad_prefill": self._pad_ok,
            "slots": self.n_slots,
            "paged": self.paged,
            "preemptions": self.preemptions,
            # request-lifecycle outcomes (exact-gated by the bench CI)
            "aborted": self._lifecycle["aborted"],
            "rejected": self._lifecycle["rejected"],
            "failed": self._lifecycle["failed"],
            "deadline_expired": self._lifecycle["deadline"],
            "recoveries": self.recoveries,
        }
        out.update(self.scheduler.stats())
        if self.spec_config is not None:
            # surfaced whenever spec was REQUESTED — an inert config
            # (contiguous cache, frames frontend) reports zeros, so the
            # bench twin rows stay shape-stable either way
            ss, emitted = self._spec_slot_steps, self._spec_emitted
            draft_tokens = ss * self.spec_config.k
            accepted = emitted - ss
            out["spec_on"] = self.spec is not None
            out["spec_drafter"] = self.spec_config.drafter
            out["spec_k"] = self.spec_config.k
            out["draft_tokens"] = draft_tokens
            out["accepted_tokens"] = accepted
            out["accepted_per_step"] = emitted / ss if ss else 0.0
            out["accept_rate"] = \
                accepted / draft_tokens if draft_tokens else 0.0
        if self._plan is not None:
            out["mesh"] = self._plan.describe()
        if self.chaos is not None:
            out.update(self.chaos.stats())
        if self.paged:
            out["preempt_mode"] = self.preempt_mode
            out.update(self.cm.stats())
        return out
