"""Device-resident continuous-batching serving engine.

A fixed pool of decode slots; requests join as slots free up (continuous
batching à la SGLang/vLLM). The decode hot path never leaves the device:

* **Donated fused step** — one jit-ed program per engine runs the model
  decode step, greedy sampling (argmax over the real vocab), stop-condition
  evaluation (max-new-tokens / max-seq), and slot masking. The KV/state
  pool cache and the token/pos/active/emitted buffers are donated
  (``donate_argnums``), so on TPU/GPU the cache updates in place instead of
  being copied every token (CPU ignores donation with a warning we
  suppress).
* **Overlapped readback** — the host reads ONE small batched emit
  (token-or-minus-one, done flags) per step, and the readback of step *k*
  is deferred until after step *k+1* has been dispatched. There is no
  per-slot ``int(next_tok[i])`` sync anywhere.
* **Bucketed, jitted admission** — prefill + the pool-cache scatter + slot
  state reset are ONE jitted function whose compile key is the padded
  prompt shape. Families whose prefill is exact under right-padding
  (``PAD_PREFILL`` — causal attention over a positional KV cache) pad
  prompts to power-of-two buckets, so an arbitrary request mix triggers at
  most ``log2(max_seq)+1`` prefill compiles. Stateful families (MoE
  capacity routing, recurrences, bidirectional encoders) prefill at exact
  length — identical to the historical engine's compile behavior.
* **Paged KV pool with oversubscription** — for families that declare
  ``PAGED_OK`` (positional K/V, slot-independent decode: the dense
  transformer), the per-slot ``slots x max_seq`` cache is replaced by a
  global ``[num_pages, page_size, ...]`` block pool plus per-slot page
  tables (SGLang/vLLM-style). Capacity is then bounded by *actual token
  count*, not worst-case length: ``num_pages`` may be much smaller than
  ``slots * max_seq / page_size``. Admission allocates whole pages and
  writes the bucketed prefill through the axes-driven
  ``registry.write_pages``; decode grows a slot's table one page at a time
  and gathers K/V blocks through it (``paged_flash_decode`` kernel). When
  the pool runs dry, the youngest occupant is **preempted**: its pages are
  freed and the request re-queued (front) with its generated prefix folded
  into the prompt — recompute preemption, which under greedy sampling
  reproduces the straight-through stream exactly. Stateful families keep
  the contiguous pool (see each family's ``PAGED_OK`` note).

Token streams are bit-identical to the historical host-driven engine
(``repro.serving.reference.ReferenceEngine``) — paged or not, preempted or
not; asserted end-to-end in ``tests/test_serving.py``. This is the
end-to-end consumer of all three paper kernels on TPU: flash-decode
(with the Kernel-1 merge, paged form included), fused add-RMSNorm,
silu-and-mul.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
import warnings
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry
from repro.serving.paging import PagePool


@contextlib.contextmanager
def _quiet_donation():
    """Donation is a TPU/GPU in-place-update optimization; the CPU backend
    ignores it and warns once per compile. Scoped to the engine's dispatch
    sites so importing this module doesn't mutate the global filter."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        yield


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # token ids [S] (or frames [S, D])
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0               # set by Engine.submit
    t_first: float = 0.0                # wall time of the first token (TTFT)
    preemptions: int = 0                # paged engine: times evicted+requeued
    arrival: int = -1                   # FCFS rank, stamped by Engine.submit
    # swap-preemption payload: (host KV pages, token, pos, emitted) — the
    # victim's exact device state, restored verbatim on re-admission
    swap_state: Optional[tuple] = dataclasses.field(default=None, repr=False)


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    # exact host mirror of the device's per-slot decode state — the device
    # stop conditions are deterministic, so the host can track position,
    # emit count, and active-ness without waiting for the (overlapped)
    # readback. The paged allocator predicts each step's write page from
    # ``dpos``; the drain heuristic reads ``dactive``.
    dpos: int = 0                       # device pos (next write position)
    demitted: int = 0                   # device emitted count
    dactive: bool = False               # device active flag


class Engine:
    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 max_seq: int = 512, greedy: bool = True,
                 paged: Optional[bool] = None, page_size: int = 16,
                 num_pages: Optional[int] = None, preempt: str = "swap"):
        """``paged=None`` auto-selects: paged pool when the family supports
        it (``registry.paged_ok``), contiguous otherwise. ``num_pages``
        defaults to full subscription (``slots * max_seq / page_size``);
        pass fewer to oversubscribe — admission then waits for pages and
        decode growth preempts the youngest occupant when the pool runs
        dry.

        ``preempt`` picks what eviction does with the victim's KV:

        * ``"swap"`` (default) — copy its pages to host, restore the same
          bytes on re-admission. Bit-exact: the stream provably equals the
          never-preempted stream, so the ReferenceEngine equivalence and
          the CI goldens hold under arbitrary preemption.
        * ``"recompute"`` — drop the pages; re-admission folds the
          generated prefix into the prompt and re-prefills (vLLM's
          recompute mode). Cheaper in host memory but only *greedy-stable*:
          prefill and decode accumulate in different orders, so a
          near-tied argmax many steps later can flip (observed at one
          token in ~10^3 under heavy eviction) — fine for serving, not for
          bit-exact replay."""
        if not greedy:
            raise NotImplementedError("only greedy (argmax) sampling")
        if preempt not in ("swap", "recompute"):
            raise ValueError(f"preempt={preempt!r}: want 'swap'|'recompute'")
        self.preempt_mode = preempt
        self.params, self.cfg = params, cfg
        self.n_slots, self.max_seq = slots, max_seq
        self.slots = [_Slot() for _ in range(slots)]
        if paged and not registry.paged_ok(cfg):
            raise ValueError(f"family {cfg.family!r} (window={cfg.window}) "
                             "cannot serve from a paged pool")
        self.paged = registry.paged_ok(cfg) if paged is None else bool(paged)
        if self.paged:
            if max_seq % page_size:
                raise ValueError(f"page_size={page_size} must divide "
                                 f"max_seq={max_seq} (the gathered logical "
                                 "cache must tile exactly)")
            self.page_size = page_size
            self._n_pt = max_seq // page_size
            if num_pages is None:
                num_pages = slots * self._n_pt      # full subscription
            self.num_pages = num_pages
            self._pool = PagePool(num_pages, page_size, slots, self._n_pt)
            # +1: physical page 0 is the trap page (see repro.serving.paging)
            self.cache, _ = registry.init_paged_cache(cfg, num_pages + 1,
                                                      page_size)
        else:
            self.page_size = self.num_pages = None
            self._pool = None
            self.cache, _ = registry.init_cache(cfg, slots, max_seq)
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        self.preemptions = 0
        self._arrivals = 0
        self._peak_pages = 0
        self._util_sum = 0.0
        self._frag_sum = 0.0
        self._pad_ok = registry.pad_prefill_ok(cfg)
        # device-resident per-slot decode state
        self._token = jnp.zeros((slots,), jnp.int32)
        self._pos = jnp.zeros((slots,), jnp.int32)
        self._active = jnp.zeros((slots,), jnp.bool_)
        self._emitted = jnp.zeros((slots,), jnp.int32)
        self._max_new = jnp.zeros((slots,), jnp.int32)
        self._step_fn = jax.jit(self._make_step(),
                                donate_argnums=(1, 2, 3, 4, 5))
        # Admission (prefill + pool scatter + slot state reset) is ONE
        # jitted program keyed by the (padded) prompt shape: bucketed
        # families compile at most log2(max_seq)+1 of them; exact-length
        # families (MoE capacity routing, recurrences, bidirectional
        # encoders) compile per unique length — the historical engine's
        # behavior, minus its eager scatter and host argmax.
        self._admit_fn = jax.jit(self._make_admit(),
                                 donate_argnums=(1, 2, 3, 4, 5, 6))
        if self.paged:
            # swap-in restore; compile key = saved page count (<= n_pt)
            self._restore_fn = jax.jit(self._make_restore(),
                                       donate_argnums=(0, 1, 2, 3, 4, 5))
        # (emit arrays, request snapshot) of the last dispatched step, not
        # yet read back — drained after the NEXT dispatch (overlap)
        self._pending = None
        self._steps = 0
        self._prefill_shapes: set[tuple] = set()

    # -- jitted programs -----------------------------------------------------

    def _make_step(self):
        cfg, vocab, max_seq = self.cfg, self.cfg.vocab, self.max_seq
        paged = self.paged

        def body(params, cache, token, pos, active, emitted, max_new,
                 page_table=None):
            if paged:
                logits, cache = registry.decode_step_paged(
                    params, cfg, cache, page_table, token, pos)
            else:
                logits, cache = registry.decode_step(params, cfg, cache,
                                                     token, pos)
            # greedy sampling over the whole pool (masked slots produce a
            # token too — exactly like the host engine — so families whose
            # decode couples slots, e.g. MoE capacity routing, see an
            # identical pool state)
            nxt = jnp.argmax(logits[:, :vocab], axis=-1).astype(jnp.int32)
            new_pos = pos + 1
            new_emitted = emitted + active.astype(jnp.int32)
            done = active & ((new_emitted >= max_new)
                             | (new_pos >= max_seq - 1))
            new_active = active & ~done
            # the emit pair is computed DIFFERENTLY from the state outputs
            # so its buffers never alias state buffers donated into the
            # next dispatch while the host still holds the emit
            emit_tok = jnp.where(active, nxt, -1)
            return (cache, nxt, new_pos, new_active, new_emitted,
                    (emit_tok, done))

        if paged:
            # the page table is a host-owned np array re-sent each dispatch
            # (tiny: slots * pages_per_slot i32) — NOT donated
            def fused(params, cache, token, pos, active, emitted, max_new,
                      page_table):
                return body(params, cache, token, pos, active, emitted,
                            max_new, page_table)
        else:
            def fused(params, cache, token, pos, active, emitted, max_new):
                return body(params, cache, token, pos, active, emitted,
                            max_new)
        return fused

    def _make_admit(self):
        cfg, vocab, max_seq = self.cfg, self.cfg.vocab, self.max_seq
        encdec = cfg.family == "encdec"
        pad_ok = self._pad_ok
        page = self.page_size

        def admit(params, cache, token, pos, active, emitted, max_new,
                  prompt, length, slot, req_max_new):
            logits, kv = registry.prefill(
                params, cfg, prompt[None],
                length=length if pad_ok else None)
            cache = registry.write_slot(cfg, cache, kv, slot, max_seq)
            tok0 = jnp.argmax(logits[0, :vocab]).astype(jnp.int32)
            start = jnp.int32(1) if encdec else length
            token = token.at[slot].set(tok0)
            pos = pos.at[slot].set(start)
            active = active.at[slot].set(True)
            emitted = emitted.at[slot].set(1)
            max_new = max_new.at[slot].set(req_max_new)
            return cache, token, pos, active, emitted, max_new, tok0

        def admit_paged(params, cache, token, pos, active, emitted, max_new,
                        prompt, length, slot, req_max_new, req_emitted,
                        pages):
            # req_emitted carries the cumulative emit count across requeues
            # (recompute preemption: the generated prefix is already in the
            # prompt and in out_tokens); pages is the physical destination
            # of each logical prompt page, trap-padded to the bucket, so
            # the compile key stays (bucket shape) — identical retrace
            # behavior to the contiguous engine.
            logits, kv = registry.prefill(params, cfg, prompt[None],
                                          length=length)
            cache = registry.write_pages(cfg, cache, kv, pages, page)
            tok0 = jnp.argmax(logits[0, :vocab]).astype(jnp.int32)
            token = token.at[slot].set(tok0)
            pos = pos.at[slot].set(length)
            active = active.at[slot].set(True)
            emitted = emitted.at[slot].set(req_emitted)
            max_new = max_new.at[slot].set(req_max_new)
            return cache, token, pos, active, emitted, max_new, tok0

        return admit_paged if self.paged else admit

    def _make_restore(self):
        """Jitted swap-in: write a victim's saved pages back into (new)
        physical pages and restore its device slot state verbatim."""
        cfg, page = self.cfg, self.page_size

        def restore(cache, token, pos, active, emitted, max_new,
                    saved, tok, dpos, demitted, req_max_new, slot, pages):
            cache = registry.write_pages(cfg, cache, saved, pages, page)
            token = token.at[slot].set(tok)
            pos = pos.at[slot].set(dpos)
            active = active.at[slot].set(True)
            emitted = emitted.at[slot].set(demitted)
            max_new = max_new.at[slot].set(req_max_new)
            return cache, token, pos, active, emitted, max_new

        return restore

    # -- request lifecycle ---------------------------------------------------

    def submit(self, req: Request):
        req.t_submit = time.perf_counter()
        req.arrival = self._arrivals
        self._arrivals += 1
        self.queue.append(req)

    def _bucket_len(self, n: int) -> Optional[int]:
        """Padded prompt length, or None for an exact-length prefill."""
        if not self._pad_ok:
            return None
        cap = min(self.max_seq, self.cfg.window or self.max_seq)
        if n > cap:
            return None            # longer than the paddable window: exact
        b = 1
        while b < n:
            b *= 2
        return min(b, cap)

    def _readmit_swapped(self, i: int, slot: _Slot, req: Request) -> bool:
        """Swap-in re-admission: restore the victim's saved pages + device
        state byte-for-byte (no prefill, no token emitted). False when the
        pool cannot hold the pages yet (head-of-line waits)."""
        saved, tok, dpos, demitted, n_real = req.swap_state
        if not self._pool.alloc_n(i, n_real):
            return False
        self.queue.popleft()
        pages = jnp.asarray(np.asarray(self._pool.owned[i], np.int32))
        with _quiet_donation():
            out = self._restore_fn(
                self.cache, self._token, self._pos, self._active,
                self._emitted, self._max_new,
                jax.tree.map(jnp.asarray, saved), jnp.int32(tok),
                jnp.int32(dpos), jnp.int32(demitted),
                jnp.int32(req.max_new_tokens), jnp.int32(i), pages)
        (self.cache, self._token, self._pos, self._active,
         self._emitted, self._max_new) = out
        req.swap_state = None
        slot.req = req
        slot.dpos = dpos
        slot.demitted = demitted
        slot.dactive = True
        return True

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot.req is None and self.queue:
                req = self.queue[0]
                if self.paged and req.swap_state is not None:
                    if not self._readmit_swapped(i, slot, req):
                        return         # head-of-line: FIFO waits for pages
                    continue
                prompt = np.asarray(req.prompt)
                if req.out_tokens:
                    # recompute re-admission after preemption: the generated
                    # prefix joins the prompt, so prefill rebuilds the exact
                    # logical cache the victim lost
                    prompt = np.concatenate(
                        [prompt, np.asarray(req.out_tokens, prompt.dtype)])
                n = len(prompt)
                b = self._bucket_len(n)
                pages_arg = None
                if self.paged:
                    n_real = -(-n // self.page_size)
                    if not self._pool.alloc_n(i, n_real):
                        return     # head-of-line: FIFO waits for pages
                    plen = b if b is not None else n
                    b_pages = max(1, -(-plen // self.page_size))
                    pages = np.zeros((b_pages,), np.int32)   # tail -> trap
                    pages[:n_real] = self._pool.owned[i]
                    pages_arg = jnp.asarray(pages)
                self.queue.popleft()
                if b is not None and b > n:
                    pad = np.zeros((b - n,) + prompt.shape[1:], prompt.dtype)
                    prompt = np.concatenate([prompt, pad])
                self._prefill_shapes.add(prompt.shape)
                args = (self.params, self.cache, self._token, self._pos,
                        self._active, self._emitted, self._max_new,
                        jnp.asarray(prompt), jnp.int32(n), jnp.int32(i),
                        jnp.int32(req.max_new_tokens))
                if self.paged:
                    args += (jnp.int32(len(req.out_tokens) + 1), pages_arg)
                with _quiet_donation():
                    out = self._admit_fn(*args)
                (self.cache, self._token, self._pos, self._active,
                 self._emitted, self._max_new, tok0) = out
                was_requeued = bool(req.out_tokens)
                req.out_tokens.append(int(tok0))
                if not req.t_first:
                    req.t_first = time.perf_counter()
                if self.paged and was_requeued \
                        and (len(req.out_tokens) >= req.max_new_tokens
                             or n >= self.max_seq - 1):
                    # Recompute re-admission delivered the request's FINAL
                    # token: in the straight-through run this token came
                    # from the decode step that fired the stop condition,
                    # so it must not decode again. (A fresh admission never
                    # checks — the reference engine always decodes at least
                    # one step after prefill.)
                    req.done = True
                    self.finished.append(req)
                    self._active = self._active.at[i].set(False)
                    self._pool.release(i)
                    continue
                slot.req = req
                slot.dpos = 1 if self.cfg.family == "encdec" else n
                slot.demitted = len(req.out_tokens)
                slot.dactive = True

    # -- paged pool growth / preemption --------------------------------------

    def _preempt(self, victim: int) -> None:
        """Evict the occupant of ``victim``: free its pages, deactivate the
        device slot, and re-queue the request at the FRONT (it keeps its
        FIFO rank). ``preempt="swap"`` first copies the victim's pages and
        device state to host for a byte-exact swap-in later;
        ``"recompute"`` drops them — re-admission folds the generated
        prefix into the prompt and re-prefills. Caller must have drained
        the pending emit (the victim's stream must be settled before its
        pages are reused)."""
        assert self._pending is None
        slot = self.slots[victim]
        req = slot.req
        if self.preempt_mode == "swap":
            owned = np.asarray(self._pool.owned[victim], np.int32)
            saved = registry.read_pages(self.cfg, self.cache,
                                        jnp.asarray(owned), self.page_size)
            req.swap_state = (
                jax.tree.map(np.asarray, saved),      # host copy (swap out)
                int(np.asarray(self._token)[victim]),
                slot.dpos, slot.demitted, len(owned))
        self._pool.release(victim)
        slot.req = None
        slot.dactive = False
        self._active = self._active.at[victim].set(False)
        req.preemptions += 1
        self.preemptions += 1
        self.queue.appendleft(req)

    def _ensure_pages(self) -> None:
        """Before a dispatch, make every device-active slot's next write
        position page-backed. On pool exhaustion: settle the in-flight
        step (finished slots free pages), then preempt the youngest
        occupant (FCFS — latest admission loses) until the write fits."""
        for i in range(self.n_slots):
            slot = self.slots[i]
            if slot.req is None or not slot.dactive:
                continue
            need = slot.dpos // self.page_size     # page written this step
            while need >= len(self._pool.owned[i]):
                if self._pool.alloc(i):
                    continue
                self._drain()
                if self.slots[i].req is None or not self.slots[i].dactive:
                    break              # the drain settled this very slot
                if self._pool.num_free:
                    continue           # the drain freed finished slots
                occ = [j for j in range(self.n_slots)
                       if self.slots[j].req is not None]
                victim = max(occ, key=lambda j: self.slots[j].req.arrival)
                self._preempt(victim)
                if victim == i:
                    break              # preempted ourselves; requeued

    # -- one engine step -----------------------------------------------------

    def step(self) -> bool:
        if self._pending is not None and \
                (self.queue and all(s.req is not None for s in self.slots)
                 or all(s.req is None or not s.dactive
                        for s in self.slots)):
            # Catch up on the pending emit when it can change what to do
            # next: either its done flags may free slots for the waiting
            # queue (admission timing then matches the host-driven engine
            # under queue pressure), or EVERY occupied slot finishes inside
            # it — dispatching before applying would burn one all-masked
            # decode step at the tail of each run.
            self._drain()
        self._admit()
        if self.paged:
            self._ensure_pages()
        if not any(s.req is not None for s in self.slots):
            self._drain()
            self._admit()
            if self.paged:
                self._ensure_pages()
            if not any(s.req is not None for s in self.slots):
                return False
        args = (self.params, self.cache, self._token, self._pos,
                self._active, self._emitted, self._max_new)
        if self.paged:
            args += (jnp.asarray(self._pool.table),)
        with _quiet_donation():
            out = self._step_fn(*args)
        (self.cache, self._token, self._pos, self._active,
         self._emitted, emit) = out
        self._steps += 1
        # mirror the device's deterministic stop conditions on the host
        # shadows (the readback of this step is still in flight)
        for s in self.slots:
            if s.req is not None and s.dactive:
                s.demitted += 1
                s.dpos += 1
                if (s.demitted >= s.req.max_new_tokens
                        or s.dpos >= self.max_seq - 1):
                    s.dactive = False
        if self.paged:
            self._sample_page_stats()
        prev, self._pending = self._pending, (emit,
                                              [s.req for s in self.slots])
        if prev is not None:
            self._apply(prev)           # readback of step k-1 overlaps k
        return True

    def _sample_page_stats(self):
        in_use = self._pool.pages_in_use
        self._peak_pages = max(self._peak_pages, in_use)
        self._util_sum += in_use / self._pool.num_pages
        alloc_rows = in_use * self.page_size
        used_rows = sum(min(s.dpos, self.max_seq) for s in self.slots
                        if s.req is not None)
        if alloc_rows:
            self._frag_sum += 1.0 - min(used_rows, alloc_rows) / alloc_rows

    def _drain(self):
        if self._pending is not None:
            prev, self._pending = self._pending, None
            self._apply(prev)

    def _apply(self, pending):
        (emit_tok, done), reqs = pending
        tok = np.asarray(emit_tok)
        fin = np.asarray(done)
        for i, req in enumerate(reqs):
            if req is None or tok[i] < 0:
                continue
            req.out_tokens.append(int(tok[i]))
            if fin[i]:
                req.done = True
                self.finished.append(req)
                if self.slots[i].req is req:
                    self.slots[i].req = None
                    if self.paged:
                        # later dispatches route this slot's masked writes
                        # to the trap page; its pages are safe to reuse
                        self._pool.release(i)

    def run(self, max_steps: int = 10_000):
        while max_steps > 0 and (self.queue or self._pending is not None
                                 or any(s.req is not None
                                        for s in self.slots)):
            if not self.step():
                break
            max_steps -= 1
        self._drain()
        return self.finished

    # -- introspection -------------------------------------------------------

    def stats(self) -> dict:
        """Decode steps, prefill retrace count, bucket coverage, and (paged)
        preemption + page-pool utilization/fragmentation."""
        try:
            prefill_compiles = self._admit_fn._cache_size()
        except Exception:
            prefill_compiles = len(self._prefill_shapes)
        out = {
            "steps": self._steps,
            "prefill_compiles": int(prefill_compiles),
            "prefill_shapes": sorted(s[0] for s in self._prefill_shapes),
            "pad_prefill": self._pad_ok,
            "slots": self.n_slots,
            "paged": self.paged,
            "preemptions": self.preemptions,
        }
        if self.paged:
            steps = max(self._steps, 1)
            out.update({
                "preempt_mode": self.preempt_mode,
                "page_size": self.page_size,
                "num_pages": self.num_pages,
                "peak_pages_in_use": self._peak_pages,
                # time-averaged pool occupancy and internal fragmentation
                # (allocated-but-unwritten rows / allocated rows)
                "page_util_mean": self._util_sum / steps,
                "page_frag_mean": self._frag_sum / steps,
            })
        return out
