"""Device-resident continuous-batching serving engine.

A fixed pool of decode slots; requests join as slots free up (continuous
batching à la SGLang/vLLM). The decode hot path never leaves the device:

* **Donated fused step** — one jit-ed program per engine runs the model
  decode step, greedy sampling (argmax over the real vocab), stop-condition
  evaluation (max-new-tokens / max-seq), and slot masking. The KV/state
  pool cache and the token/pos/active/emitted buffers are donated
  (``donate_argnums``), so on TPU/GPU the cache updates in place instead of
  being copied every token (CPU ignores donation with a warning we
  suppress).
* **Overlapped readback** — the host reads ONE small batched emit
  (token-or-minus-one, done flags) per step, and the readback of step *k*
  is deferred until after step *k+1* has been dispatched. There is no
  per-slot ``int(next_tok[i])`` sync anywhere.
* **Bucketed, jitted admission** — prefill + the pool-cache scatter + slot
  state reset are ONE jitted function whose compile key is the padded
  prompt shape. Families whose prefill is exact under right-padding
  (``PAD_PREFILL`` — causal attention over a positional KV cache) pad
  prompts to power-of-two buckets, so an arbitrary request mix triggers at
  most ``log2(max_seq)+1`` prefill compiles. Stateful families (MoE
  capacity routing, recurrences, bidirectional encoders) prefill at exact
  length — identical to the historical engine's compile behavior.

Token streams are bit-identical to the historical host-driven engine
(``repro.serving.reference.ReferenceEngine``); asserted end-to-end in
``tests/test_serving.py``. This is the end-to-end consumer of all three
paper kernels on TPU: flash-decode (with the Kernel-1 merge), fused
add-RMSNorm, silu-and-mul.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
import warnings
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry


@contextlib.contextmanager
def _quiet_donation():
    """Donation is a TPU/GPU in-place-update optimization; the CPU backend
    ignores it and warns once per compile. Scoped to the engine's dispatch
    sites so importing this module doesn't mutate the global filter."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        yield


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # token ids [S] (or frames [S, D])
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0               # set by Engine.submit
    t_first: float = 0.0                # wall time of the first token (TTFT)


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    start: int = 0                      # decode start position (host copy)


class Engine:
    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 max_seq: int = 512, greedy: bool = True):
        if not greedy:
            raise NotImplementedError("only greedy (argmax) sampling")
        self.params, self.cfg = params, cfg
        self.n_slots, self.max_seq = slots, max_seq
        self.slots = [_Slot() for _ in range(slots)]
        self.cache, _ = registry.init_cache(cfg, slots, max_seq)
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        self._pad_ok = registry.pad_prefill_ok(cfg)
        # device-resident per-slot decode state
        self._token = jnp.zeros((slots,), jnp.int32)
        self._pos = jnp.zeros((slots,), jnp.int32)
        self._active = jnp.zeros((slots,), jnp.bool_)
        self._emitted = jnp.zeros((slots,), jnp.int32)
        self._max_new = jnp.zeros((slots,), jnp.int32)
        self._step_fn = jax.jit(self._make_step(),
                                donate_argnums=(1, 2, 3, 4, 5))
        # Admission (prefill + pool scatter + slot state reset) is ONE
        # jitted program keyed by the (padded) prompt shape: bucketed
        # families compile at most log2(max_seq)+1 of them; exact-length
        # families (MoE capacity routing, recurrences, bidirectional
        # encoders) compile per unique length — the historical engine's
        # behavior, minus its eager scatter and host argmax.
        self._admit_fn = jax.jit(self._make_admit(),
                                 donate_argnums=(1, 2, 3, 4, 5, 6))
        # (emit arrays, request snapshot) of the last dispatched step, not
        # yet read back — drained after the NEXT dispatch (overlap)
        self._pending = None
        self._steps = 0
        self._prefill_shapes: set[tuple] = set()

    # -- jitted programs -----------------------------------------------------

    def _make_step(self):
        cfg, vocab, max_seq = self.cfg, self.cfg.vocab, self.max_seq

        def fused(params, cache, token, pos, active, emitted, max_new):
            logits, cache = registry.decode_step(params, cfg, cache,
                                                 token, pos)
            # greedy sampling over the whole pool (masked slots produce a
            # token too — exactly like the host engine — so families whose
            # decode couples slots, e.g. MoE capacity routing, see an
            # identical pool state)
            nxt = jnp.argmax(logits[:, :vocab], axis=-1).astype(jnp.int32)
            new_pos = pos + 1
            new_emitted = emitted + active.astype(jnp.int32)
            done = active & ((new_emitted >= max_new)
                             | (new_pos >= max_seq - 1))
            new_active = active & ~done
            # the emit pair is computed DIFFERENTLY from the state outputs
            # so its buffers never alias state buffers donated into the
            # next dispatch while the host still holds the emit
            emit_tok = jnp.where(active, nxt, -1)
            return (cache, nxt, new_pos, new_active, new_emitted,
                    (emit_tok, done))

        return fused

    def _make_admit(self):
        cfg, vocab, max_seq = self.cfg, self.cfg.vocab, self.max_seq
        encdec = cfg.family == "encdec"
        pad_ok = self._pad_ok

        def admit(params, cache, token, pos, active, emitted, max_new,
                  prompt, length, slot, req_max_new):
            logits, kv = registry.prefill(
                params, cfg, prompt[None],
                length=length if pad_ok else None)
            cache = registry.write_slot(cfg, cache, kv, slot, max_seq)
            tok0 = jnp.argmax(logits[0, :vocab]).astype(jnp.int32)
            start = jnp.int32(1) if encdec else length
            token = token.at[slot].set(tok0)
            pos = pos.at[slot].set(start)
            active = active.at[slot].set(True)
            emitted = emitted.at[slot].set(1)
            max_new = max_new.at[slot].set(req_max_new)
            return cache, token, pos, active, emitted, max_new, tok0

        return admit

    # -- request lifecycle ---------------------------------------------------

    def submit(self, req: Request):
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def _bucket_len(self, n: int) -> Optional[int]:
        """Padded prompt length, or None for an exact-length prefill."""
        if not self._pad_ok:
            return None
        cap = min(self.max_seq, self.cfg.window or self.max_seq)
        if n > cap:
            return None            # longer than the paddable window: exact
        b = 1
        while b < n:
            b *= 2
        return min(b, cap)

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot.req is None and self.queue:
                req = self.queue.popleft()
                prompt = np.asarray(req.prompt)
                n = len(prompt)
                b = self._bucket_len(n)
                if b is not None and b > n:
                    pad = np.zeros((b - n,) + prompt.shape[1:], prompt.dtype)
                    prompt = np.concatenate([prompt, pad])
                self._prefill_shapes.add(prompt.shape)
                with _quiet_donation():
                    out = self._admit_fn(
                        self.params, self.cache, self._token, self._pos,
                        self._active, self._emitted, self._max_new,
                        jnp.asarray(prompt), jnp.int32(n), jnp.int32(i),
                        jnp.int32(req.max_new_tokens))
                (self.cache, self._token, self._pos, self._active,
                 self._emitted, self._max_new, tok0) = out
                req.out_tokens.append(int(tok0))
                req.t_first = time.perf_counter()
                slot.req = req
                slot.start = 1 if self.cfg.family == "encdec" else n

    # -- one engine step -----------------------------------------------------

    def _done_in_pending(self, slot: _Slot) -> bool:
        """True when the slot's request finishes within the not-yet-applied
        pending emit (the host can predict the device stop conditions from
        its applied token count and start position)."""
        req = slot.req
        n_out = len(req.out_tokens)
        return (n_out + 1 >= req.max_new_tokens
                or slot.start + n_out >= self.max_seq - 1)

    def step(self) -> bool:
        if self._pending is not None and \
                (self.queue and all(s.req is not None for s in self.slots)
                 or all(s.req is None or self._done_in_pending(s)
                        for s in self.slots)):
            # Catch up on the pending emit when it can change what to do
            # next: either its done flags may free slots for the waiting
            # queue (admission timing then matches the host-driven engine
            # under queue pressure), or EVERY occupied slot finishes inside
            # it — dispatching before applying would burn one all-masked
            # decode step at the tail of each run.
            self._drain()
        self._admit()
        if not any(s.req is not None for s in self.slots):
            self._drain()
            self._admit()
            if not any(s.req is not None for s in self.slots):
                return False
        with _quiet_donation():
            out = self._step_fn(self.params, self.cache, self._token,
                                self._pos, self._active, self._emitted,
                                self._max_new)
        (self.cache, self._token, self._pos, self._active,
         self._emitted, emit) = out
        self._steps += 1
        prev, self._pending = self._pending, (emit,
                                              [s.req for s in self.slots])
        if prev is not None:
            self._apply(prev)           # readback of step k-1 overlaps k
        return True

    def _drain(self):
        if self._pending is not None:
            prev, self._pending = self._pending, None
            self._apply(prev)

    def _apply(self, pending):
        (emit_tok, done), reqs = pending
        tok = np.asarray(emit_tok)
        fin = np.asarray(done)
        for i, req in enumerate(reqs):
            if req is None or tok[i] < 0:
                continue
            req.out_tokens.append(int(tok[i]))
            if fin[i]:
                req.done = True
                self.finished.append(req)
                if self.slots[i].req is req:
                    self.slots[i].req = None

    def run(self, max_steps: int = 10_000):
        while max_steps > 0 and (self.queue or self._pending is not None
                                 or any(s.req is not None
                                        for s in self.slots)):
            if not self.step():
                break
            max_steps -= 1
        self._drain()
        return self.finished

    # -- introspection -------------------------------------------------------

    def stats(self) -> dict:
        """Decode steps, prefill retrace count, and bucket coverage."""
        try:
            prefill_compiles = self._admit_fn._cache_size()
        except Exception:
            prefill_compiles = len(self._prefill_shapes)
        return {
            "steps": self._steps,
            "prefill_compiles": int(prefill_compiles),
            "prefill_shapes": sorted(s[0] for s in self._prefill_shapes),
            "pad_prefill": self._pad_ok,
            "slots": self.n_slots,
        }
