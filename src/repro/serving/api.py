"""Facade layer of the serving API: ``LLMEngine``.

Callers who don't want to manage ``Request`` objects, drive ``step()``,
or scrape ``Request.out_tokens`` get two entry points over the
device-resident engine:

    generate(prompts, sampling_params) -> list[RequestOutput]
        Submit a batch, run it to completion, return per-request outputs
        in submission order.

    stream(prompts, sampling_params) -> iterator[TokenEvent]
        Same submission, but yields per-token events incrementally as the
        engine's overlapped readbacks land — tokens of concurrent requests
        interleave, each event carries (rid, token, index, done).

Both accept a single ``SamplingParams`` for the whole batch or one per
prompt, per-request ``max_new_tokens`` / ``priorities``, and share the
engine's slots/cache across calls (request ids keep increasing), so a
long-lived ``LLMEngine`` serves successive waves the way the paper's
SGLang substrate does.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, Optional, Sequence, Union

import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.cache_manager import CacheConfig
from repro.serving.engine import Engine, Request
from repro.serving.sampling import SamplingParams


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """One decoded token of one request, in stream order. A request that
    ends without a fresh token (aborted, rejected, deadline-expired, or
    failed with nothing new since the last event) closes its stream with
    a terminal sentinel event: ``token=-1, done=True`` and the
    ``finish_reason`` set."""
    rid: int
    token: int
    index: int          # 0-based position within the request's output
    done: bool          # True on the request's final event
    finish_reason: Optional[str] = None  # set on the final event only
    accepted_tokens: int = 0    # cumulative draft tokens the speculative
                                # verify committed for this request, as of
                                # this event (0 with spec off)


@dataclasses.dataclass
class RequestOutput:
    """Completed request: the full output stream plus serving metadata.
    ``finish_reason`` is the lifecycle outcome (``done | aborted |
    rejected | failed | deadline``); anything but ``done`` carries the
    detail in ``error`` and possibly a partial ``tokens`` stream."""
    rid: int
    prompt_len: int
    tokens: list
    ttft_s: Optional[float] = None      # submit -> first token
    preemptions: int = 0
    prefix_hit_tokens: int = 0          # prompt tokens served from the
                                        # radix prefix cache
    accepted_tokens: int = 0            # draft tokens committed by the
                                        # speculative verify (0 spec off)
    finish_reason: str = "done"
    error: Optional[str] = None


SamplingLike = Union[SamplingParams, Sequence[SamplingParams], None]


class LLMEngine:
    """vLLM-style facade over the layered serving stack.

    ``scheduler`` is a policy name (``"fcfs"`` / ``"priority"`` /
    ``"sjf"``) or a ``Scheduler`` instance; ``preemption`` likewise
    (``"swap"`` / ``"recompute"``); ``page_size`` / ``num_pages`` /
    ``paged`` configure the cache manager (auto-selects paged for
    families that support it; ``num_pages`` below full subscription
    oversubscribes). ``mesh`` takes a ``(data, model)``
    ``jax.sharding.Mesh`` (see ``repro.launch.mesh.make_local_mesh``)
    and runs the donated step programs sharded over it via
    ``repro.sharding.tp`` — token streams stay bit-identical to the
    single-device engine. ``spec`` takes a
    ``repro.serving.spec.SpecConfig`` and turns on speculative decoding
    (greedy requests only; streams stay bit-identical to target-only,
    just fewer steps)."""

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 max_seq: int = 512, scheduler="fcfs", preemption="swap",
                 paged: Optional[bool] = None, page_size: int = 16,
                 num_pages: Optional[int] = None, prefix_cache: bool = True,
                 sampling: Optional[SamplingParams] = None, chaos=None,
                 mesh=None, spec=None):
        self.cfg = cfg
        self.engine = Engine(
            params, cfg, slots=slots, max_seq=max_seq, sampling=sampling,
            scheduler=scheduler, preemption=preemption, chaos=chaos,
            mesh=mesh, spec=spec,
            cache_manager=CacheConfig(paged=paged, page_size=page_size,
                                      num_pages=num_pages,
                                      prefix_cache=prefix_cache))
        self._next_rid = 0

    # -- submission ----------------------------------------------------------

    def abort(self, rid: int) -> bool:
        """Cancel a live request by rid (``finish_reason="aborted"``);
        its slot pages / radix retains roll back immediately. True when a
        live request was found."""
        return self.engine.abort(rid)

    def _submit(self, prompts: Iterable, sampling_params: SamplingLike,
                max_new_tokens, priorities,
                deadlines=None) -> list[Request]:
        prompts = list(prompts)
        n = len(prompts)
        if isinstance(sampling_params, SamplingParams) \
                or sampling_params is None:
            sampling_params = [sampling_params] * n
        if len(sampling_params) != n:
            raise ValueError(f"{len(sampling_params)} sampling_params for "
                             f"{n} prompts")
        if isinstance(max_new_tokens, int):
            max_new_tokens = [max_new_tokens] * n
        elif len(max_new_tokens) != n:
            raise ValueError(f"{len(max_new_tokens)} max_new_tokens for "
                             f"{n} prompts")
        priorities = list(priorities) if priorities is not None else [0] * n
        if len(priorities) != n:
            raise ValueError(f"{len(priorities)} priorities for {n} prompts")
        if deadlines is None or isinstance(deadlines, (int, float)):
            deadlines = [deadlines] * n
        elif len(deadlines) != n:
            raise ValueError(f"{len(deadlines)} deadlines for {n} prompts")
        reqs = []
        for prompt, sp, mnt, prio, dl in zip(prompts, sampling_params,
                                             max_new_tokens, priorities,
                                             deadlines):
            req = Request(rid=self._next_rid, prompt=np.asarray(prompt),
                          max_new_tokens=int(mnt), sampling=sp,
                          priority=int(prio),
                          deadline_s=None if dl is None else float(dl))
            self._next_rid += 1
            self.engine.submit(req)
            reqs.append(req)
        return reqs

    # -- entry points --------------------------------------------------------

    def stream(self, prompts: Iterable, sampling_params: SamplingLike = None,
               *, max_new_tokens=16, priorities=None, deadlines=None,
               max_steps: int = 10_000) -> Iterator[TokenEvent]:
        """Submit ``prompts`` and yield ``TokenEvent``s as tokens land.

        Events of concurrent requests interleave; per request they arrive
        in stream order with ``done=True`` on the last one. The engine's
        one-step readback overlap is preserved — an event can trail its
        dispatch by one step, never more. Every submitted request's
        stream terminates: requests that end without a fresh token
        (aborted / rejected / deadline / failed — including an engine
        that stops making progress, which fails the leftovers rather
        than silently dropping them) close with a ``token=-1,
        done=True`` sentinel carrying the ``finish_reason``."""
        reqs = self._submit(prompts, sampling_params, max_new_tokens,
                            priorities, deadlines)
        emitted = {req.rid: 0 for req in reqs}
        closed: set = set()

        def new_events():
            for req in reqs:
                while emitted[req.rid] < len(req.out_tokens):
                    i = emitted[req.rid]
                    emitted[req.rid] += 1
                    last = req.done \
                        and emitted[req.rid] == len(req.out_tokens)
                    if last:
                        closed.add(req.rid)
                    yield TokenEvent(
                        rid=req.rid, token=req.out_tokens[i], index=i,
                        done=last,
                        finish_reason=req.finish_reason if last else None,
                        accepted_tokens=req.accepted_tokens)
                if req.done and req.rid not in closed:
                    # terminal sentinel: the request finished without a
                    # fresh token to carry the done flag
                    closed.add(req.rid)
                    yield TokenEvent(
                        rid=req.rid, token=-1, index=len(req.out_tokens),
                        done=True, finish_reason=req.finish_reason,
                        accepted_tokens=req.accepted_tokens)

        steps = max_steps
        while steps > 0 and self.engine.has_work():
            if not self.engine.step():
                break
            steps -= 1
            yield from new_events()
        self.engine.flush()
        self._fail_leftovers(reqs)
        yield from new_events()
        self._release(reqs)

    def generate(self, prompts: Iterable,
                 sampling_params: SamplingLike = None, *,
                 max_new_tokens=16, priorities=None, deadlines=None,
                 max_steps: int = 10_000) -> list[RequestOutput]:
        """Submit ``prompts``, run to completion, return outputs in
        submission order. Per-request failures never raise: each output
        carries its ``finish_reason`` (and ``error`` detail) instead."""
        reqs = self._submit(prompts, sampling_params, max_new_tokens,
                            priorities, deadlines)
        self.engine.run(max_steps=max_steps)
        self._fail_leftovers(reqs)
        outs = []
        for req in reqs:
            ttft = (req.t_first - req.t_submit) if req.t_first else None
            outs.append(RequestOutput(
                rid=req.rid, prompt_len=len(req.prompt),
                tokens=list(req.out_tokens), ttft_s=ttft,
                preemptions=req.preemptions,
                prefix_hit_tokens=req.prefix_hit_tokens,
                accepted_tokens=req.accepted_tokens,
                finish_reason=req.finish_reason or "done",
                error=req.error))
        self._release(reqs)
        return outs

    def _fail_leftovers(self, reqs) -> None:
        """An engine that stopped making progress (``step()`` returned
        False / ``max_steps`` ran out) may leave requests undone; mark
        them failed — releasing any residency they still hold — so every
        stream terminates instead of silently dropping."""
        for req in reqs:
            if not req.done:
                self.engine.cancel_request(
                    req, "failed",
                    "engine stopped making progress before this request "
                    "finished")

    def _release(self, reqs) -> None:
        """Drop this wave's completed Requests from the engine's finished
        list (by identity — Request equality touches numpy prompts) so a
        long-lived facade doesn't retain every prompt ever served."""
        done = {id(r) for r in reqs}
        self.engine.finished = [r for r in self.engine.finished
                                if id(r) not in done]

    # -- introspection -------------------------------------------------------

    def stats(self) -> dict:
        return self.engine.stats()
