"""Radix prefix cache over the paged KV pool (SGLang's RadixAttention).

Maps token-prefix paths to **full** physical pages: every tree edge is one
page-sized token chunk, and the node at the end of the edge owns the
physical page holding that chunk's KV. Matching is therefore page-aligned
by construction — a request can only reuse a cached prefix in whole-page
units, which is exactly the granularity the paged decode step addresses.

The tree holds one external reference (``pool.retain``) per node page, so
a cached page survives the releasing of every slot that wrote or mapped
it. Eviction is LRU over *unpinned leaves*: a leaf whose page has
refcount 1 (only the tree's own ref) may be dropped; a page also mapped by
any live slot has refcount >= 2 and is never reclaimed. Victims are chosen
by oldest ``last_use`` (monotonic counter, deterministic — goldens must
not depend on wall-clock), with the physical page id breaking ties.

The tree never touches device memory: inserts record pages some slot
already wrote, matches hand back page ids for the admission path to map
read-only (``pool.map_shared``), and eviction just drops refs.
"""

from __future__ import annotations

from repro.serving.paging import PagePool


class _Node:
    __slots__ = ("chunk", "page", "children", "parent", "last_use")

    def __init__(self, chunk, page, parent):
        self.chunk = chunk          # tuple of page_size token ids (root: ())
        self.page = page            # physical page holding this chunk's KV
        self.children = {}          # chunk tuple -> _Node
        self.parent = parent
        self.last_use = 0


class RadixCache:
    """Radix tree over finished prompts, one node per full KV page."""

    def __init__(self, page_size: int):
        self.page_size = page_size
        self.root = _Node((), None, None)
        self._clock = 0

    # -- queries ------------------------------------------------------------

    def _chunks(self, tokens):
        ps = self.page_size
        n_full = len(tokens) // ps
        return [tuple(int(t) for t in tokens[i * ps:(i + 1) * ps])
                for i in range(n_full)]

    def match(self, tokens) -> list[int]:
        """Longest cached page-aligned prefix of ``tokens``; returns the
        physical pages along the path and freshens their LRU stamps."""
        self._clock += 1
        node, pages = self.root, []
        for chunk in self._chunks(tokens):
            child = node.children.get(chunk)
            if child is None:
                break
            child.last_use = self._clock
            pages.append(child.page)
            node = child
        return pages

    def insert(self, tokens, pages: list[int], pool: PagePool) -> list[bool]:
        """Record ``tokens``'s full-page chunks as cached in ``pages``
        (the physical pages some slot just wrote / mapped, in order).
        New nodes retain their page; chunks already present keep the
        tree's existing page. Returns per-chunk "newly inserted" flags."""
        self._clock += 1
        node, new = self.root, []
        for chunk, page in zip(self._chunks(tokens), pages):
            child = node.children.get(chunk)
            if child is None:
                child = _Node(chunk, page, node)
                pool.retain(page)
                node.children[chunk] = child
                new.append(True)
            else:
                new.append(False)
            child.last_use = self._clock
            node = child
        return new

    # -- eviction -----------------------------------------------------------

    def _evictable_leaves(self, pool: PagePool):
        out, stack = [], [self.root]
        while stack:
            node = stack.pop()
            for child in node.children.values():
                if child.children:
                    stack.append(child)
                elif pool.refcnt[child.page] == 1:   # only the tree's ref
                    out.append(child)
        return out

    def evict(self, need: int, pool: PagePool) -> int:
        """LRU-drop unpinned leaves until ``need`` pages were freed (or no
        candidate remains). Returns the number actually freed."""
        freed = 0
        while freed < need:
            leaves = self._evictable_leaves(pool)
            if not leaves:
                break
            victim = min(leaves, key=lambda n: (n.last_use, n.page))
            pool.drop(victim.page)
            del victim.parent.children[victim.chunk]
            freed += 1
        return freed

    def has_evictable(self, pool: PagePool) -> bool:
        return bool(self._evictable_leaves(pool))

    def clear(self, pool: PagePool) -> int:
        """Drop every tree reference (crash recovery: the cached KV died
        with the device pool, so the whole tree is poisoned). Unlike
        ``evict`` this also drops interior nodes and pages that live
        slots still map — the *tree's* ref goes away; slot mappings keep
        their own refs. Returns the number of refs dropped."""
        dropped = 0
        for page in self.pages():
            pool.drop(page)
            dropped += 1
        self.root = _Node((), None, None)
        self._clock = 0
        return dropped

    # -- stats --------------------------------------------------------------

    def pages(self) -> list[int]:
        out, stack = [], [self.root]
        while stack:
            node = stack.pop()
            for child in node.children.values():
                out.append(child.page)
                stack.append(child)
        return out

    @property
    def n_pages(self) -> int:
        return len(self.pages())
