"""Mesh construction. A FUNCTION (not a module-level constant) so importing
this module never touches jax device state."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Production TPU v5e mesh: 16x16 (one pod, 256 chips) or
    2x16x16 (two pods, 512 chips). The ``pod`` axis is the DCN hop —
    gradient reduction composes (pod, data); see sharding/rules.py."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1):
    """Whatever this host has (CPU smoke tests: 1 device)."""
    n = jax.device_count()
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
