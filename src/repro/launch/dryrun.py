import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production meshes with 512 placeholder host devices.

For each cell this builds the REAL step function (train_step with
microbatched grad-accum + AdamW, or prefill/serve_step over the KV cache),
jits it with the full in/out shardings from sharding/rules.py, lowers with
ShapeDtypeStruct stand-ins (no allocation), compiles, and records
memory_analysis / cost_analysis / the collective schedule for §Roofline.

Usage:
    python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both --out benchmarks/artifacts/dryrun
"""

import argparse
import dataclasses
import json
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import SHAPES, ModelConfig, ShapeSpec, cells_for
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.roofline import analysis
from repro.sharding import rules
from repro.training import optimizer as opt
from repro.training.train_step import TrainConfig, make_train_step

MICROBATCHES = {"train_4k": 8}


def _init_specs(cfg: ModelConfig):
    """ShapeDtypeStructs + logical axes for params without allocation."""
    key = jax.random.PRNGKey(0)
    p_shapes = jax.eval_shape(lambda k: registry.init(cfg, k)[0], key)
    # the logical-axes tree is static python data; building it requires the
    # arrays only for their .shape, which eval_shape provides — re-run init
    # under eval_shape capturing axes via a mutable cell
    cell = {}

    def capture(k):
        params, axes = registry.init(cfg, k)
        cell["axes"] = axes
        return params

    jax.eval_shape(capture, key)
    return p_shapes, cell["axes"]


def lower_cell(cfg: ModelConfig, spec: ShapeSpec, mesh, *,
               microbatches: int | None = None):
    """Lower + compile one (arch x shape) cell on a mesh. Returns results."""
    chips = mesh.devices.size
    p_specs, p_axes = _init_specs(cfg)
    p_sh = rules.tree_shardings(p_specs, p_axes, mesh)

    if spec.kind == "train":
        batch_axes = tuple(n for n in ("pod", "data")
                           if n in mesh.axis_names)
        tcfg = TrainConfig(
            microbatches=microbatches or MICROBATCHES.get(spec.name, 1),
            batch_axes=batch_axes)
        step = make_train_step(cfg, tcfg, param_shardings=p_sh)
        o_specs = jax.eval_shape(
            lambda p: {"opt": opt.init(p)}, p_specs)
        o_sh = {"opt": opt.OptState(
            jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            rules.tree_shardings(o_specs["opt"].m, p_axes, mesh),
            rules.tree_shardings(o_specs["opt"].v, p_axes, mesh))}
        b_specs, b_axes = registry.batch_spec(cfg, spec.global_batch,
                                              spec.seq_len)
        b_sh = rules.tree_shardings(b_specs, b_axes, mesh)
        metrics_sh = jax.tree.map(
            lambda _: jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()),
            {"lr": 0, "grad_norm": 0, "loss": 0})
        fn = jax.jit(step,
                     in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, metrics_sh),
                     donate_argnums=(0, 1))
        args = (p_specs, o_specs, b_specs)
        tokens = spec.global_batch * spec.seq_len

    elif spec.kind == "prefill":
        def prefill_fn(params, prompt):
            return registry.prefill(params, cfg, prompt)
        pr_spec, pr_axes = registry.prompt_spec(cfg, spec.global_batch,
                                                spec.seq_len)
        pr_sh = rules.sharding_for(pr_axes, pr_spec.shape, mesh)
        c_specs, c_axes = registry.cache_spec(cfg, spec.global_batch,
                                              spec.seq_len)
        c_sh = rules.tree_shardings(c_specs, c_axes, mesh)
        logits_sh = rules.sharding_for(
            ("batch", "vocab"), (spec.global_batch, cfg.padded_vocab), mesh)
        fn = jax.jit(prefill_fn, in_shardings=(p_sh, pr_sh),
                     out_shardings=(logits_sh, c_sh))
        args = (p_specs, pr_spec)
        tokens = spec.global_batch * spec.seq_len

    else:  # decode
        def serve_fn(params, cache, token, pos):
            return registry.decode_step(params, cfg, cache, token, pos)
        c_specs, c_axes = registry.cache_spec(cfg, spec.global_batch,
                                              spec.seq_len)
        c_sh = rules.tree_shardings(c_specs, c_axes, mesh)
        b = spec.global_batch
        tok_spec = jax.ShapeDtypeStruct((b,), jnp.int32)
        pos_spec = jax.ShapeDtypeStruct((b,), jnp.int32)
        tok_sh = rules.sharding_for(("batch",), (b,), mesh)
        logits_sh = rules.sharding_for(("batch", "vocab"),
                                       (b, cfg.padded_vocab), mesh)
        fn = jax.jit(serve_fn,
                     in_shardings=(p_sh, c_sh, tok_sh, tok_sh),
                     out_shardings=(logits_sh, c_sh),
                     donate_argnums=(1,))
        args = (p_specs, c_specs, tok_spec, pos_spec)
        tokens = spec.global_batch            # one new token per sequence

    with mesh:
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    roof = analysis.analyze(
        arch=cfg.name, shape=spec.name,
        mesh_name="x".join(str(s) for s in mesh.devices.shape),
        chips=chips, cost=cost, hlo_text=hlo, mem_stats=mem,
        model_flops_global=analysis.model_flops(cfg, spec.kind, tokens),
        kernel_traffic=analysis.kernel_traffic(cfg, spec, chips))
    return roof, compiled


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir=None,
             verbose=True):
    cfg = configs.get(arch)
    spec = SHAPES[shape_name]
    if spec.name == "long_500k" and not configs.long_context_ok(cfg):
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped",
                "reason": "pure full attention; long_500k needs "
                          "sub-quadratic mixer (DESIGN.md)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        roof, compiled = lower_cell(cfg, spec, mesh)
        row = roof.row()
        row["status"] = "ok"
        if verbose:
            mem = compiled.memory_analysis()
            print(f"[{arch} x {shape_name} x "
                  f"{'x'.join(str(s) for s in mesh.devices.shape)}] OK")
            print(f"  memory_analysis: temp="
                  f"{getattr(mem, 'temp_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"args={getattr(mem, 'argument_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"out={getattr(mem, 'output_size_in_bytes', 0)/2**30:.2f}GiB")
            print(f"  cost_analysis: flops/chip={roof.flops_per_chip:.3e} "
                  f"bytes/chip={roof.bytes_per_chip:.3e}")
            print(f"  roofline: compute={row['compute_ms']:.2f}ms "
                  f"memory={row['memory_ms']:.2f}ms "
                  f"collective={row['collective_ms']:.2f}ms "
                  f"dominant={row['dominant']}")
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug report
        row = {"arch": arch, "shape": shape_name,
               "mesh": "multi" if multi_pod else "single",
               "status": "FAIL", "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
        if verbose:
            print(f"[{arch} x {shape_name}] FAIL: {row['error']}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{'multi' if multi_pod else 'single'}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(row, f, indent=2, default=str)
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(configs.ARCH_IDS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    rows = []
    if args.all:
        for arch in configs.ARCH_IDS:
            cfg = configs.get(arch)
            for spec in cells_for(cfg):
                for mp in meshes:
                    rows.append(run_cell(arch, spec.name, mp, args.out))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for mp in meshes:
            rows.append(run_cell(args.arch, args.shape, mp, args.out))
    n_ok = sum(r["status"] == "ok" for r in rows)
    n_skip = sum(r["status"] == "skipped" for r in rows)
    print(f"\n{n_ok} ok / {n_skip} skipped / "
          f"{len(rows) - n_ok - n_skip} failed of {len(rows)} cells")


if __name__ == "__main__":
    main()
