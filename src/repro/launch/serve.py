"""Serving driver over the layered API: ``LLMEngine.generate`` on top of
SamplingParams (greedy / temperature / top-k / top-p, per-request seed),
a pluggable scheduler (fcfs / priority / sjf), and the unified cache
manager (contiguous or paged KV with preemption for PAGED_OK families).

CPU-runnable:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --requests 6 --slots 3 --max-new 8
    # non-greedy, seeded (reproducible):
    PYTHONPATH=src python -m repro.launch.serve --temperature 0.8 \
        --top-k 40 --top-p 0.95 --seed 7
    # priority admission over an oversubscribed paged pool:
    PYTHONPATH=src python -m repro.launch.serve --scheduler priority \
        --requests 8 --prompt-len 48 --max-new 24 --num-pages 12
    # chaos drill: abort rid 1 at step 2, inject a device fault at step
    # 5 (quarantine + swap-restore recovery), per-request deadline:
    PYTHONPATH=src python -m repro.launch.serve --requests 6 \
        --chaos "abort@2:rid=1,device_fault@5:slot=0" --deadline 30
    # tensor-parallel over a forced-host 4-device mesh (data=2, model=2);
    # streams are bit-identical to the single-device run:
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m repro.launch.serve --tp 2 --slots 4
    # speculative decoding (greedy only; streams bit-identical to the
    # target-only run, in fewer steps):
    PYTHONPATH=src python -m repro.launch.serve --spec ngram --spec-k 4
    PYTHONPATH=src python -m repro.launch.serve --spec draft_model
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import registry
from repro.serving import (ChaosInjector, LLMEngine, SamplingParams,
                           SpecConfig)

_LIFECYCLE = ("aborted", "rejected", "failed", "deadline_expired",
              "recoveries")


def parse_chaos(spec: str):
    """Compact fault-plan syntax: ``kind@step[:k=v[;k=v...]],...`` —
    e.g. ``abort@2:rid=1,device_fault@5:slot=0,
    pool_exhaustion@8:pages=3;steps=4``."""
    from repro.reliability import Fault
    faults = []
    for part in spec.split(","):
        head, _, kv = part.strip().partition(":")
        kind, _, step = head.partition("@")
        extra = {}
        for item in filter(None, kv.split(";")):
            k, _, v = item.partition("=")
            extra[k.strip()] = float(v) if k.strip() == "seconds" \
                else int(v)
        faults.append(Fault(kind=kind.strip(), step=int(step), **extra))
    return faults


def _make_spec(spec: str, k: int, cfg, seed: int):
    """Resolve ``--spec/--spec-k`` into a ``SpecConfig``. The draft model
    is a shrunk same-arch sibling (half the layers, fresh init key) — a
    stand-in with the right shape of cost/accept tradeoff, the way
    qwen2-0.5b would draft for qwen3-8b in production."""
    if spec == "ngram":
        return SpecConfig(drafter="ngram", k=k)
    import dataclasses
    dcfg = dataclasses.replace(cfg, name=cfg.name + "-draft",
                               n_layers=max(1, cfg.n_layers // 2))
    draft_params, _ = registry.init(dcfg, jax.random.PRNGKey(seed + 1))
    return SpecConfig(drafter="draft_model", k=k,
                      draft_params=draft_params, draft_cfg=dcfg)


def run(*, arch: str = "qwen2-0.5b", smoke: bool = True, requests: int = 6,
        slots: int = 3, max_new: int = 8, max_seq: int = 128,
        prompt_len: int = 16, seed: int = 0, verbose: bool = True,
        page_size: int = 16, num_pages: int | None = None,
        prefix_cache: bool = True, scheduler: str = "fcfs",
        temperature: float = 0.0, top_k: int = 0, top_p: float = 1.0,
        sampling_seed: int | None = None, deadline: float | None = None,
        chaos: str | None = None, tp: int | None = None,
        spec: str | None = None, spec_k: int = 4):
    cfg = configs.smoke(arch) if smoke else configs.get(arch)
    params, _ = registry.init(cfg, jax.random.PRNGKey(seed))
    injector = ChaosInjector(parse_chaos(chaos)) if chaos else None
    mesh = None
    if tp is not None:
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh(tp)
    spec_cfg = _make_spec(spec, spec_k, cfg, seed) if spec else None
    llm = LLMEngine(params, cfg, slots=slots, max_seq=max_seq,
                    scheduler=scheduler, page_size=page_size,
                    num_pages=num_pages, prefix_cache=prefix_cache,
                    chaos=injector, mesh=mesh, spec=spec_cfg)
    sp = SamplingParams(temperature=temperature, top_k=top_k, top_p=top_p,
                        seed=sampling_seed)
    rng = np.random.default_rng(seed)
    prompts = []
    for _ in range(requests):
        n = int(rng.integers(4, prompt_len + 1))
        if cfg.frontend == "frames":
            prompts.append(rng.standard_normal((n, cfg.d_model))
                           .astype(np.float32))
        else:
            prompts.append(rng.integers(0, cfg.vocab, (n,), dtype=np.int32))
    # under non-FCFS schedulers, give the batch a deterministic priority
    # spread so the policy has something to reorder
    priorities = [rid % 3 for rid in range(requests)]
    t0 = time.perf_counter()
    outs = llm.generate(prompts, sp, max_new_tokens=max_new,
                        priorities=priorities, deadlines=deadline)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(o.tokens) for o in outs)
    if verbose:
        for o in outs:
            tail = "" if o.finish_reason == "done" else (
                f"  [{o.finish_reason}"
                + (f": {o.error}]" if o.error else "]"))
            print(f"req {o.rid}: prompt[{o.prompt_len}] -> {o.tokens}"
                  f"{tail}")
        s = llm.stats()
        ttfts = [o.ttft_s for o in outs if o.ttft_s is not None]
        mode = "greedy" if sp.greedy else (
            f"T={sp.temperature:g}"
            + (f",top_k={sp.top_k}" if sp.top_k else "")
            + (f",top_p={sp.top_p:g}" if sp.top_p < 1 else ""))
        print(f"{len(outs)} requests, {total_tokens} tokens in {dt:.2f}s "
              f"({total_tokens/dt:.1f} tok/s, continuous batching x{slots}, "
              f"ttft {np.mean(ttfts)*1e3:.0f}ms, {s['steps']} steps, "
              f"{s['prefill_compiles']} prefill compiles, "
              f"sampling={mode}, scheduler={s['scheduler']} "
              f"({s['sched_reorders']} reorders)")
        if "mesh" in s:
            m = s["mesh"]
            sharded = [k for k in ("heads_tp", "mlp_tp", "vocab_tp",
                                   "batch_dp") if m[k]] or ["replicated"]
            print(f"mesh: data={m['data']} x model={m['model']} "
                  f"({', '.join(sharded)}), {s['readbacks']} readbacks "
                  f"in {s['steps']} steps")
        if s["paged"]:
            print(f"paged pool: {s['num_pages']} pages x {s['page_size']} "
                  f"rows ({s['preempt_mode']} preemption) — "
                  f"{s['preemptions']} preemptions, "
                  f"peak {s['peak_pages_in_use']}/{s['num_pages']} pages, "
                  f"mean util {s['page_util_mean']:.0%}, "
                  f"frag {s['page_frag_mean']:.0%}")
        if s.get("spec_on"):
            print(f"spec decode: {s['spec_drafter']} drafter, "
                  f"k={s['spec_k']} — "
                  f"{s['accepted_per_step']:.2f} tokens/step, "
                  f"{s['accepted_tokens']}/{s['draft_tokens']} drafts "
                  f"accepted ({s['accept_rate']:.0%})")
        if s.get("prefix_cache"):
            print(f"prefix cache: {s['prefix_hit_tokens']}/"
                  f"{s['prefix_query_tokens']} prompt tokens served from "
                  f"the radix tree (hit rate {s['prefix_hit_rate']:.0%}), "
                  f"{s['cow_copies']} CoW copies, "
                  f"{s['tree_pages']} cached pages, "
                  f"{s['tree_evictions']} tree evictions")
        lc = {k: s[k] for k in _LIFECYCLE if s.get(k)}
        if lc or "chaos_injected" in s:
            bits = ", ".join(f"{k}={v}" for k, v in lc.items()) \
                or "every request finished clean"
            print(f"lifecycle: {bits}")
            if "chaos_injected" in s:
                fired = {k: v for k, v in s["chaos_injected"].items() if v}
                print(f"chaos: injected {fired or 'nothing'}, "
                      f"{s['chaos_relents']} relents")
    return outs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b",
                    choices=list(configs.ARCH_IDS))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="paged-pool size; below slots*max_seq/page_size "
                         "oversubscribes (admission queues + preemption)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable radix prefix caching (on by default "
                         "for paged token-prompt families)")
    ap.add_argument("--scheduler", default="fcfs",
                    choices=["fcfs", "priority", "sjf"],
                    help="admission policy (requests carry rid%%3 "
                         "priorities so 'priority' visibly reorders)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy argmax (default); >0 samples")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k highest logits (0 = disabled)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 = disabled)")
    ap.add_argument("--seed", type=int, default=None, dest="sampling_seed",
                    help="per-request sampling seed (default: request id, "
                         "so runs are reproducible but requests diverge)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request wall-clock budget in seconds "
                         "(finish_reason='deadline' on expiry)")
    ap.add_argument("--chaos", default=None, metavar="PLAN",
                    help="step-indexed fault plan, e.g. "
                         "'abort@2:rid=1,device_fault@5:slot=0,"
                         "pool_exhaustion@8:pages=3;steps=4'")
    ap.add_argument("--tp", type=int, default=None, metavar="M",
                    help="model-parallel size: serve sharded over a "
                         "(devices/M, M) (data, model) mesh; streams "
                         "stay bit-identical to the single-device run")
    ap.add_argument("--spec", default=None,
                    choices=["ngram", "draft_model"],
                    help="speculative decoding drafter (greedy only; "
                         "'draft_model' drafts with a half-depth "
                         "same-arch sibling)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens proposed per decode step "
                         "(the fused verify scores k+1 positions)")
    args = ap.parse_args()
    run(arch=args.arch, requests=args.requests, slots=args.slots,
        max_new=args.max_new, max_seq=args.max_seq,
        prompt_len=args.prompt_len, page_size=args.page_size,
        num_pages=args.num_pages, prefix_cache=not args.no_prefix_cache,
        scheduler=args.scheduler,
        temperature=args.temperature, top_k=args.top_k, top_p=args.top_p,
        sampling_seed=args.sampling_seed, deadline=args.deadline,
        chaos=args.chaos, tp=args.tp, spec=args.spec, spec_k=args.spec_k)


if __name__ == "__main__":
    main()
