"""Serving driver: device-resident continuous-batching engine over the
fused decode step (on-device sampling + stop conditions, bucketed prefill,
paged KV pool with preemption for PAGED_OK families).

CPU-runnable:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --requests 6 --slots 3 --max-new 8
    # oversubscribed paged pool (forces preemption + swap-in):
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --requests 8 --prompt-len 48 --max-new 24 --num-pages 12
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import registry
from repro.serving.engine import Engine, Request


def run(*, arch: str = "qwen2-0.5b", smoke: bool = True, requests: int = 6,
        slots: int = 3, max_new: int = 8, max_seq: int = 128,
        prompt_len: int = 16, seed: int = 0, verbose: bool = True,
        page_size: int = 16, num_pages: int | None = None):
    cfg = configs.smoke(arch) if smoke else configs.get(arch)
    params, _ = registry.init(cfg, jax.random.PRNGKey(seed))
    engine = Engine(params, cfg, slots=slots, max_seq=max_seq,
                    page_size=page_size, num_pages=num_pages)
    rng = np.random.default_rng(seed)
    t0 = time.perf_counter()
    for rid in range(requests):
        n = int(rng.integers(4, prompt_len + 1))
        if cfg.frontend == "frames":
            prompt = rng.standard_normal((n, cfg.d_model)).astype(np.float32)
        else:
            prompt = rng.integers(0, cfg.vocab, (n,), dtype=np.int32)
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=max_new))
    done = engine.run()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out_tokens) for r in done)
    if verbose:
        for r in sorted(done, key=lambda r: r.rid):
            print(f"req {r.rid}: prompt[{len(r.prompt)}] -> "
                  f"{r.out_tokens}")
        s = engine.stats()
        ttfts = [r.t_first - r.t_submit for r in done if r.t_first]
        print(f"{len(done)} requests, {total_tokens} tokens in {dt:.2f}s "
              f"({total_tokens/dt:.1f} tok/s, continuous batching x{slots}, "
              f"ttft {np.mean(ttfts)*1e3:.0f}ms, {s['steps']} steps, "
              f"{s['prefill_compiles']} prefill compiles)")
        if s["paged"]:
            print(f"paged pool: {s['num_pages']} pages x {s['page_size']} "
                  f"rows ({s['preempt_mode']} preemption) — "
                  f"{s['preemptions']} preemptions, "
                  f"peak {s['peak_pages_in_use']}/{s['num_pages']} pages, "
                  f"mean util {s['page_util_mean']:.0%}, "
                  f"frag {s['page_frag_mean']:.0%}")
    return done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b",
                    choices=list(configs.ARCH_IDS))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="paged-pool size; below slots*max_seq/page_size "
                         "oversubscribes (admission queues + preemption)")
    args = ap.parse_args()
    run(arch=args.arch, requests=args.requests, slots=args.slots,
        max_new=args.max_new, max_seq=args.max_seq,
        prompt_len=args.prompt_len, page_size=args.page_size,
        num_pages=args.num_pages)


if __name__ == "__main__":
    main()
