"""End-to-end training driver (deliverable (b)'s e2e path).

Wires every substrate: config -> model init -> sharded train_step (micro-
batched, optionally compressed grads) -> synthetic restartable pipeline ->
async checkpointing -> straggler watchdog -> heartbeat -> crash/restart
recovery (optionally with an injected failure, for drills).

CPU-runnable:
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
        --steps 40 --batch 8 --seq 128 --microbatches 2 --fail-at 12
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.pipeline import Pipeline
from repro.models import registry
from repro.training import optimizer as opt
from repro.training.checkpoint import Checkpointer
from repro.training.fault_tolerance import (FailureInjector, Heartbeat,
                                            StragglerWatchdog,
                                            run_with_restarts)
from repro.training.train_step import TrainConfig, init_state, make_train_step


def train_once(*, cfg, tcfg: TrainConfig, steps: int, batch: int, seq: int,
               ckpt_dir: str, ckpt_every: int = 10, seed: int = 0,
               injector: FailureInjector | None = None, log_every: int = 10,
               verbose: bool = True):
    """One training attempt; resumes from the latest committed checkpoint."""
    ckpt = Checkpointer(ckpt_dir)
    params, _ = registry.init(cfg, jax.random.PRNGKey(seed))
    state = init_state(cfg, tcfg, params)
    start_step = 0
    pipe_state = {"seed": seed, "step": 0}

    latest = ckpt.latest_step()
    if latest is not None:
        (params, state), extra, start_step = ckpt.restore((params, state))
        pipe_state = extra.get("pipeline", pipe_state)
        if verbose:
            print(f"[restore] resumed from step {start_step}")

    pipe = Pipeline(cfg, batch, seq, seed=pipe_state["seed"],
                    start_step=pipe_state["step"])
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
    watchdog = StragglerWatchdog()
    heart = Heartbeat(ckpt_dir + "/heartbeat.json")
    losses = []

    try:
        for step in range(start_step, steps):
            t0 = time.perf_counter()
            data = pipe.next()
            if injector is not None:
                injector.maybe_fail(step)
            params, state, metrics = step_fn(params, state, data)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.perf_counter() - t0
            slow = watchdog.observe(step, dt)
            heart.beat(step)
            if verbose and (step % log_every == 0 or slow):
                print(f"step {step:>5} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f} "
                      f"{dt*1e3:.0f}ms{'  [STRAGGLER]' if slow else ''}")
            if (step + 1) % ckpt_every == 0 or step + 1 == steps:
                ckpt.save(step + 1, (params, state),
                          extra={"pipeline": pipe.state_dict()})
    finally:
        pipe.close()
        ckpt.wait()
    return {"params": params, "state": state, "losses": losses,
            "flagged_steps": watchdog.flagged_steps}


def run(*, arch: str, smoke: bool = True, steps: int = 40, batch: int = 8,
        seq: int = 128, microbatches: int = 1, compress: bool = False,
        ckpt_dir: str = "/tmp/repro_ckpt", fail_at: int | None = None,
        max_restarts: int = 2, lr: float = 3e-4, seed: int = 0,
        verbose: bool = True):
    cfg = configs.smoke(arch) if smoke else configs.get(arch)
    tcfg = TrainConfig(
        microbatches=microbatches, compress_grads=compress,
        adamw=opt.AdamWConfig(lr=lr, warmup_steps=max(steps // 10, 1),
                              total_steps=steps))
    injector = FailureInjector(fail_at)

    def attempt():
        return train_once(cfg=cfg, tcfg=tcfg, steps=steps, batch=batch,
                          seq=seq, ckpt_dir=ckpt_dir, injector=injector,
                          seed=seed, verbose=verbose)

    def on_restart(n, e):
        if verbose:
            print(f"[fault-tolerance] attempt {n} after: {e} — restarting "
                  f"from latest committed checkpoint")

    return run_with_restarts(attempt, max_restarts=max_restarts,
                             on_restart=on_restart)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b",
                    choices=list(configs.ARCH_IDS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()
    out = run(arch=args.arch, smoke=args.smoke, steps=args.steps,
              batch=args.batch, seq=args.seq,
              microbatches=args.microbatches, compress=args.compress_grads,
              ckpt_dir=args.ckpt_dir, fail_at=args.fail_at, lr=args.lr)
    print(f"final loss {out['losses'][-1]:.4f} "
          f"(first {out['losses'][0]:.4f}) over {len(out['losses'])} steps")


if __name__ == "__main__":
    main()
