"""End-to-end driver: train a reduced qwen2-class LM for a few hundred
steps on CPU with the full production substrate — microbatched grad accum,
AdamW, async checkpointing, deterministic restartable data pipeline, a
straggler watchdog, and an injected mid-run failure to demonstrate
checkpoint/restart recovery.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import shutil

from repro.launch.train import run

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--arch", default="qwen2-0.5b")
args = ap.parse_args()

ckpt = "/tmp/repro_train_lm"
shutil.rmtree(ckpt, ignore_errors=True)
out = run(arch=args.arch, smoke=True, steps=args.steps, batch=8, seq=128,
          microbatches=2, ckpt_dir=ckpt,
          fail_at=args.steps // 2,           # injected failure mid-run
          lr=1e-3)
losses = out["losses"]
k = max(len(losses) // 10, 1)
print(f"\nloss: first-{k}-avg {sum(losses[:k])/k:.4f} -> "
      f"last-{k}-avg {sum(losses[-k:])/k:.4f} "
      f"({len(losses)} post-restart steps, "
      f"{len(out['flagged_steps'])} straggler flags)")
print("survived one injected failure via checkpoint/restart.")
