"""Serve a small model with batched requests through the device-resident
continuous-batching engine: one donated jit-ed step per decode token
(model forward + greedy sampling + stop conditions on device, overlapped
host readback), bucketed pow2 prefill admission, and the flash-decode
kernel (paper Kernel 1's merge) on the attention path.

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import run

run(arch="qwen2-0.5b", requests=6, slots=3, max_new=8, max_seq=128)
