"""Serve a small model with batched requests through the device-resident
continuous-batching engine: one donated jit-ed step per decode token
(model forward + greedy sampling + stop conditions on device, overlapped
host readback), bucketed pow2 prefill admission, and the flash-decode
kernel (paper Kernel 1's merge, paged form) on the attention path.

The second run oversubscribes the paged KV pool (8 pages x 16 rows vs
3 slots x 128 positions), so admission queues on free pages and the
engine preempts + swaps the youngest occupant — the printed stats show
preemptions and page utilization/fragmentation.

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import run

run(arch="qwen2-0.5b", requests=6, slots=3, max_new=8, max_seq=128)

print("\n--- oversubscribed paged pool ---")
run(arch="qwen2-0.5b", requests=8, slots=3, max_new=24, max_seq=128,
    prompt_len=48, num_pages=8)
