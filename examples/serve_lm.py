"""Serve a small model with batched requests through the continuous-
batching engine (prefill + KV-cache decode; the decode path consumes the
flash-decode kernel whose combiner is paper Kernel 1).

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import run

run(arch="qwen2-0.5b", requests=6, slots=3, max_new=8, max_seq=128)
