"""Serve a small model through the layered serving API: ``LLMEngine``
over the device-resident continuous-batching engine — one donated jit-ed
step per decode token with sampling (greedy or temperature/top-k/top-p)
fused on device, pluggable admission scheduling, and the unified cache
manager (bucketed pow2 prefill, paged KV via the flash-decode kernel).

Three runs:
1. greedy FCFS — the bit-exact baseline configuration;
2. seeded non-greedy sampling (temperature + nucleus) — still one batched
   host readback per step, reproducible per seed;
3. an oversubscribed paged pool (8 pages x 16 rows vs 3 slots x 128
   positions) under priority scheduling — admission queues on free pages
   and the engine preempts + swaps the youngest occupant; the stats line
   shows the policy, preemptions, and page utilization/fragmentation.

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import run

run(arch="qwen2-0.5b", requests=6, slots=3, max_new=8, max_seq=128)

print("\n--- seeded sampling (temperature 0.8, top-p 0.95) ---")
run(arch="qwen2-0.5b", requests=6, slots=3, max_new=8, max_seq=128,
    temperature=0.8, top_p=0.95, sampling_seed=7)

print("\n--- oversubscribed paged pool, priority admission ---")
run(arch="qwen2-0.5b", requests=8, slots=3, max_new=24, max_seq=128,
    prompt_len=48, num_pages=8, scheduler="priority")
