"""Quickstart: optimize one production kernel with the Astra multi-agent
loop (Algorithm 1) and reintegrate it into the framework.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import optimize, reintegrate
from repro.kernels import ops

# 1. Run Algorithm 1 on the SwiGLU kernel (paper Kernel 3): the testing
#    agent builds a production-shape suite, the profiling agent evaluates
#    the TPU-v5e cost model, the planning agent attacks the dominant
#    roofline term, the coding agent applies the knob moves.
log = optimize("silu_and_mul", rounds=5, verbose=True)
print()
print(log.table())
print(f"\nspeedup over baseline: {log.speedup():.2f}x")

# 2. Reintegrate (paper §3.2 post-processing): the tuned variant becomes
#    the framework-wide kernel — every model's MLP now uses it.
reintegrate({"silu_and_mul": log})
print(f"installed: {ops.get_variant('silu_and_mul').describe()}")

# 3. Use it through the public op (Pallas interpret on CPU).
x = jax.random.normal(jax.random.PRNGKey(0), (8, 1024), jnp.bfloat16)
y = ops.silu_and_mul(x, impl="pallas")
print(f"silu_and_mul({x.shape}) -> {y.shape} {y.dtype}")
