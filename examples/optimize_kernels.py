"""Reproduce the paper end-to-end: optimize the SGLang kernels with the
multi-agent system, compare with the single-agent baseline (Table 3), and
print the per-round optimization trajectories (the case-study data behind
the paper's §5.3) — then go beyond Algorithm 1 with the pluggable search
strategies (beam / population) sharing one memoized evaluation cache.

    PYTHONPATH=src python examples/optimize_kernels.py
"""
import numpy as np

from repro.core import (SPACES, ProfilingAgent, TestingAgent,
                        optimize_single_agent, reintegrate)
from repro.search import BeamSearch, EvalCache, SearchOrchestrator

# One orchestrator = one evaluation cache: every genome any strategy
# visits is validated/profiled at most once, process-wide.
cache = EvalCache()
orch = SearchOrchestrator(cache=cache)
kernels = ("merge_attn_states_lse", "fused_add_rmsnorm", "silu_and_mul")

results = {k: orch.search(k, strategy="greedy", rounds=5) for k in kernels}
hifi = ProfilingAgent(reps=10**6)
tester = TestingAgent()

print(f"{'kernel':<24}{'base us':>9}{'MA us':>9}{'MA':>7}{'SA':>7}")
mas, sas = [], []
for name, log in results.items():
    space = SPACES[name]
    tests = tester.generate_tests(space)
    base = hifi.profile(space, space.baseline, tests).geomean_latency_us
    ma = hifi.profile(space, log.best().code, tests).geomean_latency_us
    sa_log = optimize_single_agent(name, rounds=5)
    sa = hifi.profile(space, sa_log.final_variant, tests).geomean_latency_us
    mas.append(base / ma); sas.append(base / sa)
    print(f"{name:<24}{base:>9.2f}{ma:>9.2f}{base/ma:>6.2f}x{base/sa:>6.2f}x")
print(f"{'geomean':<24}{'':>9}{'':>9}"
      f"{np.exp(np.mean(np.log(mas))):>6.2f}x"
      f"{np.exp(np.mean(np.log(sas))):>6.2f}x")
print("\npaper: MA 1.26/1.25/1.46 (avg 1.32x); SA 0.73/1.18/1.48 (avg 1.08x)\n")

for name, log in results.items():
    print(f"=== trajectory: {name} ===")
    print(log.table())
    print()

# Beam search re-walks the greedy path through the cache (hits) and spends
# its width on the moves Algorithm 1 never tries.
print("=== beam search (width=4), sharing the evaluation cache ===")
for name in kernels:
    beam = orch.search(name, strategy=BeamSearch(width=4), rounds=5)
    best = beam.best()
    c = beam.meta["cache"]
    print(f"{name:<24} best {best.perf.geomean_latency_us:>8.2f}us  "
          f"genomes={c['misses']} cache_hits={c['hits']}")
print(f"cache: {cache.stats()}\n")

reintegrate(results)
print("tuned variants reintegrated into the serving/training framework.")
