"""Long-context (500k) decode with the three sub-quadratic architectures:
shows the bounded KV cache / recurrent state that makes the long_500k cell
feasible, plus the split-KV + merge_attn_states distributed-decode math.

    PYTHONPATH=src python examples/long_context_decode.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.kernels import ref
from repro.models import registry

print("== bounded decode state at seq_len=524288 ==")
for arch in ("h2o-danube-1.8b", "xlstm-1.3b", "recurrentgemma-2b"):
    cfg = configs.get(arch)
    spec, _ = registry.cache_spec(cfg, 1, 524288)
    total = sum(np.prod(s.shape) * s.dtype.itemsize
                for s in jax.tree.leaves(spec))
    print(f"{arch:<22} cache/state = {total/2**30:.2f} GiB "
          f"(window={cfg.window}, family={cfg.family})")

print("\n== split-KV decode: per-shard partials merged with Kernel 1 ==")
b, hq, hkv, dh, s = 2, 8, 2, 64, 4096
ks = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(ks[0], (b, hq, dh))
k = jax.random.normal(ks[1], (b, s, hkv, dh))
v = jax.random.normal(ks[2], (b, s, hkv, dh))
full = ref.flash_decode_attention(q, k, v)
n_shards = 8
parts = []
for i in range(n_shards):
    sl = slice(i * s // n_shards, (i + 1) * s // n_shards)
    o = ref.flash_decode_attention(q, k[:, sl], v[:, sl])
    lse = ref.flash_decode_lse(q, k[:, sl])
    parts.append((o, lse))
o, lse = parts[0]
for o2, lse2 in parts[1:]:
    o, lse = ref.merge_attn_states_lse(o, lse, o2, lse2)
err = float(jnp.max(jnp.abs(o - full)))
print(f"{n_shards}-shard tree-merge vs monolithic decode: max|err| = {err:.2e}")
assert err < 1e-4
print("sequence-parallel decode is exact — the paper's kernel is the "
      "distributed combiner.")
