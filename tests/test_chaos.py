"""Request-lifecycle robustness + chaos-injection tests: abort/deadline
rollback through the refcounted pool (radix-shared pages included),
admission validation and the infeasibility watchdog, per-request failure
isolation (corrupt readbacks, prefill faults), crash-consistent recovery
from injected device-step faults (survivor streams bit-identical to an
undisturbed run), and the stream()/generate() no-silent-drop guarantee."""

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import registry
from repro.reliability import Fault, FaultSchedule
from repro.serving.cache_manager import CacheConfig
from repro.serving.chaos import ChaosInjector, InjectedDeviceFault
from repro.serving.engine import Engine, Request
from repro.serving.api import LLMEngine
from repro.training.fault_tolerance import FailureInjector

_STATE = {}


def _setup():
    if not _STATE:
        cfg = configs.smoke("qwen2-0.5b")
        _STATE["cfg"] = cfg
        _STATE["params"] = registry.init(cfg, jax.random.PRNGKey(0))[0]
    return _STATE["cfg"], _STATE["params"]


def _prompts(cfg, n=4, length=12, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (length,), dtype=np.int32)
            for _ in range(n)]


def _run(cfg, params, prompts, *, max_new=8, slots=2, max_seq=64, **kw):
    eng = Engine(params, cfg, slots=slots, max_seq=max_seq, **kw)
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=p.copy(),
                           max_new_tokens=max_new))
    eng.run()
    return eng, {r.rid: list(r.out_tokens) for r in eng.finished}


# -- shared fault-schedule core ---------------------------------------------

def test_fault_schedule_fires_once_and_filters_kinds():
    sched = FaultSchedule([Fault("abort", step=3, rid=1),
                           Fault("device_fault", step=3, slot=0)])
    assert sched.due(2) == []
    only_abort = sched.due(3, kinds=("abort",))
    assert [f.kind for f in only_abort] == ["abort"]
    assert not sched.exhausted
    rest = sched.due(3)
    assert [f.kind for f in rest] == ["device_fault"]
    assert sched.due(3) == []          # fire-once
    assert sched.exhausted and sched.fired == 2


def test_failure_injector_back_compat():
    inj = FailureInjector(fail_at_step=5)
    inj.maybe_fail(4)
    assert not inj.fired
    with pytest.raises(RuntimeError, match="injected failure at step 5"):
        inj.maybe_fail(5)
    assert inj.fired
    inj.maybe_fail(5)                  # raises once, then inert
    FailureInjector(None).maybe_fail(0)


def test_chaos_injector_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown chaos fault kind"):
        ChaosInjector([Fault("meteor_strike", step=0)])


# -- abort / deadline --------------------------------------------------------

def test_abort_queued_and_resident_is_prefix_exact():
    """Abort one queued and one resident request mid-run: their streams
    are prefixes of the undisturbed run, survivors are bit-identical,
    and the pool invariants hold after every rollback."""
    cfg, params = _setup()
    prompts = _prompts(cfg)
    _, gold = _run(cfg, params, prompts)
    chaos = ChaosInjector([Fault("abort", step=2, rid=0),   # resident
                           Fault("abort", step=1, rid=3)])  # still queued
    eng, out = _run(cfg, params, prompts, chaos=chaos)
    reasons = {r.rid: r.finish_reason for r in eng.finished}
    assert reasons[0] == "aborted" and reasons[3] == "aborted"
    assert reasons[1] == "done" and reasons[2] == "done"
    assert out[1] == gold[1] and out[2] == gold[2]
    assert out[0] == gold[0][:len(out[0])] and len(out[0]) < len(gold[0])
    assert out[3] == []
    assert eng.stats()["aborted"] == 2
    assert chaos.exhausted
    eng._pool.check()
    assert all(not pages for pages in eng._pool.owned)


def test_abort_unknown_rid_returns_false():
    cfg, params = _setup()
    eng = Engine(params, cfg, slots=2, max_seq=64)
    assert not eng.abort(99)
    assert eng.stats()["aborted"] == 0


def test_abort_with_tree_shared_prefix_pages():
    """Abort a request whose prefix pages are radix-shared: the pool
    invariants hold, the tree pages survive the abort, and a follow-up
    identical prompt still gets the prefix hit."""
    cfg, params = _setup()
    rng = np.random.default_rng(7)
    base = rng.integers(0, cfg.vocab, (32,), dtype=np.int32)
    tail = rng.integers(0, cfg.vocab, (4,), dtype=np.int32)
    eng = Engine(params, cfg, slots=2, max_seq=64)
    # seed the tree with the base prefix
    eng.submit(Request(rid=0, prompt=base.copy(), max_new_tokens=4))
    eng.run()
    tree_pages = set(eng.cm.tree.pages())
    assert tree_pages, "tree must hold the base prefix"
    # admit a sharer (maps the cached prefix read-only), then abort it
    victim = Request(rid=1, prompt=np.concatenate([base, tail]),
                     max_new_tokens=8)
    eng.submit(victim)
    eng.step()                         # resident, prefix mapped shared
    assert victim.prefix_hit_tokens == 32
    assert eng.abort(1)
    assert victim.finish_reason == "aborted"
    eng._pool.check()
    assert tree_pages <= set(eng.cm.tree.pages()), \
        "abort must not drop tree-shared pages"
    # the follow-up identical prompt still hits the cached prefix
    follow = Request(rid=2, prompt=np.concatenate([base, tail]),
                     max_new_tokens=8)
    eng.submit(follow)
    eng.run()
    assert follow.finish_reason == "done"
    assert follow.prefix_hit_tokens >= 32
    eng._pool.check()


def test_deadline_expires_queued_and_resident():
    cfg, params = _setup()
    prompts = _prompts(cfg, n=3)
    eng = Engine(params, cfg, slots=2, max_seq=64)
    rs = [Request(rid=i, prompt=p, max_new_tokens=8)
          for i, p in enumerate(prompts)]
    rs[2].deadline_s = 0.0             # expires before it can be admitted
    for r in rs:
        eng.submit(r)
    eng.step()                         # rs[0], rs[1] resident
    assert rs[2].finish_reason == "deadline" and rs[2].out_tokens == []
    rs[0].deadline_s = 1e-9            # now expire a RESIDENT request
    eng._has_deadlines = True
    eng.run()
    assert rs[0].finish_reason == "deadline"
    assert rs[1].finish_reason == "done" and len(rs[1].out_tokens) == 8
    assert eng.stats()["deadline_expired"] == 2
    eng._pool.check()


# -- admission validation / watchdog ----------------------------------------

def test_submit_rejects_invalid_prompts():
    cfg, params = _setup()
    eng = Engine(params, cfg, slots=2, max_seq=64,
                 cache_manager=CacheConfig(page_size=16))
    rng = np.random.default_rng(0)
    bad = [
        (np.zeros((0,), np.int32), "empty prompt"),
        (rng.random((8,)).astype(np.float32), "integer-typed"),
        (np.array([0, cfg.vocab + 5], np.int32), "outside"),
        (rng.integers(0, cfg.vocab, (200,), dtype=np.int32), "max_seq"),
    ]
    for rid, (prompt, needle) in enumerate(bad):
        req = Request(rid=rid, prompt=prompt)
        eng.submit(req)
        assert req.finish_reason == "rejected", needle
        assert needle in req.error
    assert eng.stats()["rejected"] == len(bad)
    # the page-infeasibility guard itself (defensive: under any valid
    # geometry page_size divides max_seq, so the max_seq check above
    # fires first; the guard protects future geometries + the watchdog)
    assert "pages" in eng.cm.infeasible(10 * eng.max_seq)
    assert eng.cm.infeasible(8) is None
    # the engine still serves a valid wave afterwards
    ok = Request(rid=99, prompt=rng.integers(0, cfg.vocab, (10,),
                                             dtype=np.int32),
                 max_new_tokens=4)
    eng.submit(ok)
    eng.run()
    assert ok.finish_reason == "done" and len(ok.out_tokens) == 4
    eng._pool.check()


def test_watchdog_rejects_wedged_head_instead_of_deadlocking():
    """A never-admittable request that slipped past submit() validation
    (pushed straight into the scheduler) must be rejected by the
    quiescent-engine watchdog, not deadlock the queue behind it."""
    cfg, params = _setup()
    eng = Engine(params, cfg, slots=2, max_seq=64)
    rng = np.random.default_rng(1)
    wedge = Request(rid=0, prompt=rng.integers(0, cfg.vocab, (200,),
                                               dtype=np.int32),
                    arrival=0)
    ok = Request(rid=1, prompt=rng.integers(0, cfg.vocab, (10,),
                                            dtype=np.int32),
                 max_new_tokens=4, arrival=1)
    eng.scheduler.push(wedge)          # bypasses admission validation
    eng.scheduler.push(ok)
    eng.run()
    assert wedge.finish_reason == "rejected"
    assert ok.finish_reason == "done" and len(ok.out_tokens) == 4
    assert eng.stats()["rejected"] == 1


# -- failure isolation -------------------------------------------------------

def test_corrupt_readback_quarantines_one_request():
    cfg, params = _setup()
    prompts = _prompts(cfg)
    _, gold = _run(cfg, params, prompts)
    chaos = ChaosInjector([Fault("corrupt_readback", step=3, slot=1)])
    eng, out = _run(cfg, params, prompts, chaos=chaos)
    reasons = {r.rid: r.finish_reason for r in eng.finished}
    failed = [rid for rid, fr in reasons.items() if fr == "failed"]
    assert len(failed) == 1
    assert "corrupt readback" in next(r.error for r in eng.finished
                                      if r.rid == failed[0])
    for rid, fr in reasons.items():
        if fr == "done":
            assert out[rid] == gold[rid], "other slots must be untouched"
    assert eng.stats()["failed"] == 1 and chaos.exhausted
    eng._pool.check()


def test_device_fault_recovery_survivors_bit_identical():
    """The crash-consistency headline: quarantine the faulting slot,
    swap-restore the survivors, finish them bit-identical to an
    undisturbed run — then serve a second wave normally."""
    cfg, params = _setup()
    prompts = _prompts(cfg, n=4, length=20, seed=3)
    _, gold = _run(cfg, params, prompts)
    chaos = ChaosInjector([Fault("device_fault", step=4, slot=0)])
    eng = Engine(params, cfg, slots=2, max_seq=64, chaos=chaos)
    rs = [Request(rid=i, prompt=p.copy(), max_new_tokens=8)
          for i, p in enumerate(prompts)]
    for r in rs:
        eng.submit(r)
    eng.run()
    assert chaos.exhausted
    reasons = {r.rid: r.finish_reason for r in rs}
    assert sorted(reasons.values()) == ["done", "done", "done", "failed"]
    quarantined = next(rid for rid, fr in reasons.items()
                       if fr == "failed")
    for r in rs:
        if r.finish_reason == "done":
            assert list(r.out_tokens) == gold[r.rid], \
                f"survivor {r.rid} diverged after recovery"
            assert r.rid == quarantined or len(r.out_tokens) == 8
    s = eng.stats()
    assert s["recoveries"] == 1 and s["failed"] == 1
    eng._pool.check()
    assert all(not pages for pages in eng._pool.owned)
    # the recovered engine keeps serving: identical second wave
    eng2_out = {}
    for i, p in enumerate(prompts):
        r = Request(rid=100 + i, prompt=p.copy(), max_new_tokens=8)
        eng.submit(r)
        eng2_out[i] = r
    eng.run()
    for i, r in eng2_out.items():
        assert r.finish_reason == "done"
        assert list(r.out_tokens) == gold[i]
    eng._pool.check()


def test_device_fault_without_slot_uses_preemption_policy():
    cfg, params = _setup()
    prompts = _prompts(cfg, n=2)
    _, gold = _run(cfg, params, prompts)
    chaos = ChaosInjector([Fault("device_fault", step=3)])   # no slot
    eng, out = _run(cfg, params, prompts, chaos=chaos)
    reasons = {r.rid: r.finish_reason for r in eng.finished}
    # youngest-victim policy quarantines the later arrival (rid 1)
    assert reasons == {0: "done", 1: "failed"}
    assert out[0] == gold[0]
    eng._pool.check()


def test_pool_exhaustion_chaos_streams_unchanged():
    """Chaos page holds squeeze an oversubscribed pool: the preemption
    machinery absorbs the pressure and every stream stays bit-identical
    to the undisturbed oversubscribed run."""
    cfg, params = _setup()
    prompts = _prompts(cfg, n=4, length=24, seed=5)
    cm = CacheConfig(page_size=16, num_pages=6)
    _, gold = _run(cfg, params, prompts, max_new=16, cache_manager=cm)
    chaos = ChaosInjector([Fault("pool_exhaustion", step=2, pages=3,
                                 steps=6)])
    eng, out = _run(cfg, params, prompts, max_new=16, cache_manager=cm,
                    chaos=chaos)
    assert all(r.finish_reason == "done" for r in eng.finished)
    assert out == gold
    assert chaos.injected["pool_exhaustion"] == 1
    eng._pool.check()


def test_injected_device_fault_is_runtime_error():
    exc = InjectedDeviceFault("boom", slot=2)
    assert isinstance(exc, RuntimeError) and exc.slot == 2


def test_stall_fault_sleeps_and_deadline_catches_it():
    cfg, params = _setup()
    prompts = _prompts(cfg, n=2)
    chaos = ChaosInjector([Fault("stall", step=1, seconds=0.02)])
    eng = Engine(params, cfg, slots=2, max_seq=64, chaos=chaos)
    rs = [Request(rid=i, prompt=p, max_new_tokens=6, deadline_s=0.01)
          for i, p in enumerate(prompts)]
    for r in rs:
        eng.submit(r)
    eng.run()
    assert chaos.injected["stall"] == 1
    # the stall burned the whole budget: both requests expire
    assert all(r.finish_reason == "deadline" for r in rs)
    eng._pool.check()


# -- facade: no silent drops -------------------------------------------------

def test_stream_marks_stalled_requests_failed():
    """A stream whose engine stops making progress terminates EVERY
    request: leftovers are failed with terminal sentinel events instead
    of silently dropping after flush()."""
    cfg, params = _setup()
    llm = LLMEngine(params, cfg, slots=2, max_seq=64)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (10,), dtype=np.int32)
               for _ in range(3)]
    events = list(llm.stream(prompts, max_new_tokens=8, max_steps=2))
    rids = {e.rid for e in events}
    terminal = {e.rid: e for e in events if e.done}
    assert len(rids) == 3 and len(terminal) == 3, \
        "every submitted request's stream must terminate"
    assert any(e.finish_reason == "failed" for e in terminal.values())
    assert llm.engine._pool.check() is None
    # the facade stays serviceable for the next wave
    outs = llm.generate(prompts, max_new_tokens=4)
    assert all(o.finish_reason == "done" for o in outs)


def test_generate_reports_failures_instead_of_raising():
    cfg, params = _setup()
    llm = LLMEngine(params, cfg, slots=2, max_seq=64,
                    chaos=ChaosInjector([Fault("device_fault", step=3,
                                               slot=0)]))
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab, (12,), dtype=np.int32)
               for _ in range(2)]
    prompts.append(np.zeros((0,), np.int32))             # rejected
    outs = llm.generate(prompts, max_new_tokens=6)
    by_reason = sorted(o.finish_reason for o in outs)
    assert by_reason == ["done", "failed", "rejected"]
    assert all(o.error for o in outs if o.finish_reason != "done")


def test_facade_abort_mid_stream():
    cfg, params = _setup()
    llm = LLMEngine(params, cfg, slots=2, max_seq=64)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, (10,), dtype=np.int32)
               for _ in range(2)]
    events = []
    it = llm.stream(prompts, max_new_tokens=8)
    first = next(it)
    assert llm.abort(first.rid)
    events = [first] + list(it)
    terminal = {e.rid: e for e in events if e.done}
    assert terminal[first.rid].finish_reason == "aborted"
    other = next(rid for rid in terminal if rid != first.rid)
    assert terminal[other].finish_reason == "done"
    assert llm.engine._pool.check() is None


# -- chaos page seizure: allocator-level cross-validation --------------------

def test_seize_free_respects_pool_invariants():
    from repro.serving.paging import PagePool
    pool = PagePool(8, 16, 2, 4)
    pages = pool.seize_free(3)
    assert len(pages) == 3
    pool.check()
    assert pool.num_free == 5
    assert pool.alloc_n(0, 4) and not pool.alloc_n(1, 2)
    pool.check()
    pool.release_seized(pages)
    pool.check()
    assert pool.alloc_n(1, 2)
    pool.release(0)
    pool.release(1)
    pool.check()
    assert pool.num_free == 8
    # seizing more than available clips
    assert len(pool.seize_free(99)) == 8 and pool.num_free == 0
    pool.check()


def test_seize_release_random_churn():
    """Plain-random cross-validation of the chaos seize/release rules
    (mirrors the hypothesis state machine, which needs the hypothesis
    package): interleave seizes, allocations, shared mappings, and
    releases; check() must hold throughout."""
    from repro.serving.paging import PagePool
    rng = np.random.default_rng(11)
    pool = PagePool(12, 16, 3, 6)
    seized: list[int] = []
    for _ in range(600):
        op = rng.integers(0, 5)
        slot = int(rng.integers(0, 3))
        if op == 0:
            seized.extend(pool.seize_free(int(rng.integers(1, 4))))
        elif op == 1 and seized:
            k = int(rng.integers(1, len(seized) + 1))
            drop, seized[:] = seized[:k], seized[k:]
            pool.release_seized(drop)
        elif op == 2:
            pool.alloc_n(slot, int(rng.integers(1, 3)))
        elif op == 3:
            cands = [p for pages in pool.owned for p in pages]
            take = [p for p in dict.fromkeys(cands)
                    if p not in pool.owned[slot]][:2]
            if take and len(pool.owned[slot]) + len(take) \
                    <= pool.pages_per_slot:
                pool.map_shared(slot, take)
        else:
            pool.release(slot)
        pool.check()
