"""Astra multi-agent system behaviour (Algorithm 1, paper §3.2/§5.2)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ProfilingAgent, TestingAgent, SPACES, optimize,
                        optimize_all, optimize_single_agent, reintegrate)
from repro.kernels import ops


def test_log_schema_matches_algorithm1():
    """Log = (round, code, correctness, performance) for rounds 0..R."""
    log = optimize("silu_and_mul", rounds=3)
    assert len(log.entries) == 4
    assert [e.round for e in log.entries] == [0, 1, 2, 3]
    assert log.entries[0].correct is True          # baseline entry
    assert log.entries[0].code.name == "baseline"
    for e in log.entries:
        assert e.perf.geomean_latency_us > 0
        assert isinstance(e.correct, bool)


def test_every_candidate_is_validated_against_oracle():
    log = optimize("fused_add_rmsnorm", rounds=3)
    for e in log.entries[1:]:
        assert e.max_err >= 0
        assert e.correct                            # catalog moves are safe


def test_best_selection_and_speedup():
    log = optimize("silu_and_mul", rounds=5)
    best = log.best()
    assert best.correct
    lats = [e.perf.geomean_latency_us for e in log.entries if e.correct]
    assert best.perf.geomean_latency_us == min(lats)
    assert log.speedup() >= 1.0                     # never ships a regression


def test_planner_reverts_regressions():
    """If a round regresses, the next suggestion restores the best state."""
    log = optimize("fused_add_rmsnorm", rounds=6)
    lats = [e.perf.geomean_latency_us for e in log.entries]
    # after any regression, some later entry must come back near the best
    best = min(lats)
    assert lats[-1] <= best * 1.10


def test_multi_agent_beats_single_agent_on_complex_kernel():
    """Paper Table 3's headline: MA > SA on Kernel 1, SA ~ MA on Kernel 3."""
    hi_fi = ProfilingAgent(reps=100000)
    tester = TestingAgent()
    results = {}
    for name in ("merge_attn_states_lse", "silu_and_mul"):
        space = SPACES[name]
        tests = tester.generate_tests(space)
        base = hi_fi.profile(space, space.baseline, tests).geomean_latency_us
        ma = optimize(name, rounds=5)
        ma_lat = hi_fi.profile(space, ma.best().code,
                               tests).geomean_latency_us
        sa = optimize_single_agent(name, rounds=5)
        sa_lat = hi_fi.profile(space, sa.final_variant,
                               tests).geomean_latency_us
        results[name] = (base / ma_lat, base / sa_lat)
    ma1, sa1 = results["merge_attn_states_lse"]
    ma3, sa3 = results["silu_and_mul"]
    assert ma1 > sa1, "MA must beat SA on the complex kernel (paper K1)"
    assert sa1 < 1.0, "SA regresses on K1 (paper: 0.73x)"
    assert ma1 > 1.0
    assert abs(ma3 - sa3) / ma3 < 0.25, "SA ~ MA on the simple kernel (K3)"


def test_reintegration_installs_best_variants():
    old = {k: ops.get_variant(k) for k in
           ("silu_and_mul", "fused_add_rmsnorm")}
    try:
        results = {k: optimize(k, rounds=2)
                   for k in ("silu_and_mul", "fused_add_rmsnorm")}
        reintegrate(results)
        for k, log in results.items():
            assert ops.get_variant(k) == log.best().code
    finally:
        ops.set_variants(**old)


def test_profiling_noise_scales_with_reps():
    space = SPACES["silu_and_mul"]
    tests = TestingAgent().generate_tests(space)[:2]
    sloppy = ProfilingAgent(reps=1).profile(space, space.baseline, tests)
    careful = ProfilingAgent(reps=100).profile(space, space.baseline, tests)
    assert sloppy.noise_scale == pytest.approx(careful.noise_scale * 10)


def test_llm_backend_is_explicitly_unavailable():
    from repro.core.policy import LLMBackend
    with pytest.raises(NotImplementedError):
        LLMBackend()
