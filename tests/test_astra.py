"""Astra multi-agent system behaviour (Algorithm 1, paper §3.2/§5.2).

The searches are expensive (interpret-mode Pallas validation per round),
so they run ONCE per module through a shared ``SearchOrchestrator`` —
its evaluation cache also makes repeated genomes free — and every test
asserts against the shared logs.
"""

import jax.numpy as jnp
import pytest

from repro.core import (ProfilingAgent, TestingAgent, SPACES,
                        optimize_single_agent, reintegrate)
from repro.kernels import ops
from repro.search import SearchOrchestrator

SILU_ROUNDS = 5
RMS_ROUNDS = 6


@pytest.fixture(scope="module")
def orch():
    """One orchestrator (one evaluation cache) for the whole module; a
    float32-only suite halves interpret-mode validation cost."""
    return SearchOrchestrator(testing=TestingAgent(dtypes=(jnp.float32,)))


@pytest.fixture(scope="module")
def silu_log(orch):
    return orch.search("silu_and_mul", rounds=SILU_ROUNDS)


@pytest.fixture(scope="module")
def rms_log(orch):
    return orch.search("fused_add_rmsnorm", rounds=RMS_ROUNDS)


def test_log_schema_matches_algorithm1(silu_log):
    """Log = (round, code, correctness, performance) for rounds 0..R."""
    assert len(silu_log.entries) == SILU_ROUNDS + 1
    assert [e.round for e in silu_log.entries] == list(range(SILU_ROUNDS + 1))
    assert silu_log.entries[0].correct is True          # baseline entry
    assert silu_log.entries[0].code.name == "baseline"
    for e in silu_log.entries:
        assert e.perf.geomean_latency_us > 0
        assert isinstance(e.correct, bool)


def test_every_candidate_is_validated_against_oracle(rms_log):
    for e in rms_log.entries[1:]:
        assert e.max_err >= 0
        assert e.correct                            # catalog moves are safe


def test_best_selection_and_speedup(silu_log):
    best = silu_log.best()
    assert best.correct
    lats = [e.perf.geomean_latency_us for e in silu_log.entries if e.correct]
    assert best.perf.geomean_latency_us == min(lats)
    assert silu_log.speedup() >= 1.0                # never ships a regression


def test_planner_reverts_regressions(rms_log):
    """If a round regresses, the next suggestion restores the best state."""
    lats = [e.perf.geomean_latency_us for e in rms_log.entries]
    # after any regression, some later entry must come back near the best
    best = min(lats)
    assert lats[-1] <= best * 1.10


def test_search_log_surfaces_cache_hit_counts(silu_log):
    cache = silu_log.meta["cache"]
    assert cache["misses"] >= 1
    assert cache["hits"] >= 0
    assert cache["max_evals_per_genome"] <= 1
    assert silu_log.meta["strategy"] == "greedy"


@pytest.mark.slow
def test_multi_agent_beats_single_agent_on_complex_kernel(silu_log):
    """Paper Table 3's headline: MA > SA on Kernel 1, SA ~ MA on Kernel 3.

    K1's win is compute-side (hoisted LSE weights); on a float32-only
    suite the kernel is memory-bound everywhere, so this search needs the
    full bf16+f32 production suite.
    """
    hi_fi = ProfilingAgent(reps=100000)
    tester = TestingAgent()
    results = {}
    logs = {"merge_attn_states_lse":
            SearchOrchestrator().search("merge_attn_states_lse", rounds=5),
            "silu_and_mul": silu_log}
    for name, ma in logs.items():
        space = SPACES[name]
        tests = tester.generate_tests(space)
        base = hi_fi.profile(space, space.baseline, tests).geomean_latency_us
        ma_lat = hi_fi.profile(space, ma.best().code,
                               tests).geomean_latency_us
        sa = optimize_single_agent(name, rounds=5)
        sa_lat = hi_fi.profile(space, sa.final_variant,
                               tests).geomean_latency_us
        results[name] = (base / ma_lat, base / sa_lat)
    ma1, sa1 = results["merge_attn_states_lse"]
    ma3, sa3 = results["silu_and_mul"]
    assert ma1 > sa1, "MA must beat SA on the complex kernel (paper K1)"
    assert sa1 < 1.0, "SA regresses on K1 (paper: 0.73x)"
    assert ma1 > 1.0
    assert abs(ma3 - sa3) / ma3 < 0.25, "SA ~ MA on the simple kernel (K3)"


def test_reintegration_installs_best_variants(silu_log, rms_log):
    old = {k: ops.get_variant(k) for k in
           ("silu_and_mul", "fused_add_rmsnorm")}
    try:
        results = {"silu_and_mul": silu_log, "fused_add_rmsnorm": rms_log}
        reintegrate(results)
        for k, log in results.items():
            assert ops.get_variant(k) == log.best().code
    finally:
        ops.set_variants(**old)


def test_profiling_noise_scales_with_reps():
    space = SPACES["silu_and_mul"]
    tests = TestingAgent().generate_tests(space)[:2]
    sloppy = ProfilingAgent(reps=1).profile(space, space.baseline, tests)
    careful = ProfilingAgent(reps=100).profile(space, space.baseline, tests)
    assert sloppy.noise_scale == pytest.approx(careful.noise_scale * 10)


def test_llm_backend_is_explicitly_unavailable():
    from repro.core.policy import LLMBackend
    with pytest.raises(NotImplementedError):
        LLMBackend()
