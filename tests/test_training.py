"""Training substrate: optimizer, schedules, microbatching equivalence,
checkpoint atomicity/restore, fault-tolerant restart, data determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import Pipeline, _batch_np
from repro.models import registry
from repro.training import optimizer as opt
from repro.training.checkpoint import Checkpointer
from repro.training.fault_tolerance import (StragglerWatchdog,
                                            run_with_restarts)
from repro.training.train_step import TrainConfig, init_state, make_train_step


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    cfg = opt.AdamWConfig(lr=0.2, weight_decay=0.0, warmup_steps=0,
                          total_steps=200, min_lr_frac=1.0)
    for _ in range(150):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = opt.update(cfg, params, grads, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_grad_clip_and_schedule():
    g = {"w": jnp.full((10,), 100.0)}
    clipped, gn = opt.clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(100.0 * np.sqrt(10), rel=1e-5)
    assert float(jnp.linalg.norm(clipped["w"])) == pytest.approx(1.0, 1e-4)
    cfg = opt.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(opt.schedule(cfg, 5)) == pytest.approx(5e-4)
    assert float(opt.schedule(cfg, 10)) == pytest.approx(1e-3)
    assert float(opt.schedule(cfg, 100)) == pytest.approx(
        1e-3 * cfg.min_lr_frac, rel=1e-3)


def test_microbatching_matches_full_batch():
    """Grad accumulation over M microbatches == single big batch."""
    cfg = configs.smoke("qwen2-0.5b")
    params, _ = registry.init(cfg, jax.random.PRNGKey(0))
    batch = registry.make_batch(cfg, 8, 16, jax.random.PRNGKey(1))
    outs = {}
    for m in (1, 4):
        tcfg = TrainConfig(microbatches=m)
        state = init_state(cfg, tcfg, params)
        step = make_train_step(cfg, tcfg)
        new_p, _, metrics = step(params, state, batch)
        outs[m] = (metrics["loss"], new_p)
    np.testing.assert_allclose(float(outs[1][0]), float(outs[4][0]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(outs[1][1]), jax.tree.leaves(outs[4][1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_compressed_training_still_learns():
    cfg = configs.smoke("qwen2-0.5b")
    params, _ = registry.init(cfg, jax.random.PRNGKey(0))
    tcfg = TrainConfig(compress_grads=True,
                       adamw=opt.AdamWConfig(lr=1e-3, warmup_steps=0,
                                             total_steps=30))
    state = init_state(cfg, tcfg, params)
    assert "err_fb" in state
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = registry.make_batch(cfg, 4, 16, jax.random.PRNGKey(1))
    losses = []
    for _ in range(12):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]     # memorizes the fixed batch


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((2, 3))}}
    for step in (1, 2, 3):
        ck.save(step, tree, extra={"pipeline": {"seed": 7, "step": step}},
                blocking=True)
    assert ck.committed_steps() == [2, 3]            # gc keeps last 2
    restored, extra, step = ck.restore(tree)
    assert step == 3 and extra["pipeline"]["step"] == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tmp_path):
    """A .tmp directory (simulated crash mid-save) is never restored."""
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.ones(3)}
    ck.save(1, tree, blocking=True)
    os.makedirs(tmp_path / "step_00000002.tmp")      # crashed save
    assert ck.latest_step() == 1
    _, _, step = ck.restore(tree)
    assert step == 1


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, {"w": jnp.zeros(10)}, blocking=False)
    ck.wait()
    assert ck.latest_step() == 5


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_failure_injection_and_restart_resumes_exactly(tmp_path):
    from repro.launch.train import run
    out = run(arch="qwen2-0.5b", steps=14, batch=2, seq=16,
              ckpt_dir=str(tmp_path), fail_at=8, verbose=False)
    assert len(out["losses"]) >= 6                  # resumed and finished
    # deterministic pipeline -> the rerun of step 5..13 saw the same data
    out2 = run(arch="qwen2-0.5b", steps=14, batch=2, seq=16,
               ckpt_dir=str(tmp_path) + "_clean", fail_at=None,
               verbose=False)
    np.testing.assert_allclose(out["losses"][-1], out2["losses"][-1],
                               rtol=1e-4)


def test_run_with_restarts_gives_up():
    calls = []

    def always_fails():
        calls.append(1)
        raise RuntimeError("boom")

    with pytest.raises(RuntimeError):
        run_with_restarts(always_fails, max_restarts=2)
    assert len(calls) == 3


def test_straggler_watchdog():
    w = StragglerWatchdog(threshold=3.0, consecutive_limit=2)
    for i in range(10):
        assert not w.observe(i, 0.1)
    assert w.observe(10, 1.0)
    assert not w.should_restart
    w.observe(11, 1.0)
    assert w.should_restart
    assert w.flagged_steps == [10, 11]


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_determinism_and_cursor():
    cfg = configs.smoke("qwen2-0.5b")
    p1 = Pipeline(cfg, 4, 16, seed=3)
    batches = [p1.next() for _ in range(4)]
    state = p1.state_dict()
    assert state["step"] == 4
    p1.close()
    # restart mid-stream: batch 4 onward must match a fresh run's batch 4+
    p2 = Pipeline.restore(cfg, 4, 16, state)
    nxt = p2.next()
    p2.close()
    want = _batch_np(cfg, 4, 16, 3, 4)
    np.testing.assert_array_equal(np.asarray(nxt["tokens"]), want["tokens"])
    # and differs from batch 3
    assert not np.array_equal(np.asarray(nxt["tokens"]),
                              np.asarray(batches[3]["tokens"]))


def test_pipeline_sharding_partitions_stream():
    cfg = configs.smoke("qwen2-0.5b")
    a = _batch_np(cfg, 8, 16, 0, 0, shard=0, n_shards=2)
    b = _batch_np(cfg, 8, 16, 0, 0, shard=1, n_shards=2)
    assert a["tokens"].shape == (4, 16)
    assert not np.array_equal(a["tokens"], b["tokens"])
