"""Speculative-decoding tests: greedy spec streams bit-identical to
target-only decoding for both drafters, composed with every serving
subsystem — forced swap preemption (stateful draft-cache snapshot and
byte-for-byte restore), radix prefix-cache hits, abort mid-burst, and an
injected device fault with crash-consistent drafter recovery — plus the
SpecConfig/admission/reference validation surface and the inert-config
behavior on cache layouts that cannot speculate."""

import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import registry
from repro.reliability import Fault
from repro.serving import SpecConfig
from repro.serving.api import LLMEngine
from repro.serving.cache_manager import CacheConfig
from repro.serving.chaos import ChaosInjector
from repro.serving.engine import Engine, Request
from repro.serving.reference import ReferenceEngine
from repro.serving.sampling import SamplingParams
from repro.serving.spec import DRAFTERS, make_drafter
from repro.serving.spec.drafter import DraftModelDrafter, NGramDrafter

_STATE = {}


def _setup(arch="qwen2-0.5b"):
    if arch not in _STATE:
        cfg = configs.smoke(arch)
        _STATE[arch] = (cfg, registry.init(cfg, jax.random.PRNGKey(0))[0])
    return _STATE[arch]


def _spec(cfg, params, drafter, k=3):
    """A SpecConfig for tests: self-drafting with the target model itself
    (every draft accepted — the strongest exactness stressor, since the
    verify rolls through full k+1 commits), or prompt-lookup n-grams."""
    if drafter == "draft_model":
        return SpecConfig(drafter="draft_model", k=k, draft_params=params,
                          draft_cfg=cfg)
    return SpecConfig(drafter="ngram", k=k)


def _prompts(cfg, n=4, length=12, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (length,), dtype=np.int32)
            for _ in range(n)]


def _run(cfg, params, prompts, *, max_new=8, slots=2, max_seq=64, **kw):
    eng = Engine(params, cfg, slots=slots, max_seq=max_seq, **kw)
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=p.copy(),
                           max_new_tokens=max_new))
    eng.run()
    return eng, {r.rid: list(r.out_tokens) for r in eng.finished}


# -- bit-identity vs target-only ---------------------------------------------

@pytest.mark.parametrize("drafter", DRAFTERS)
def test_spec_streams_bit_identical(drafter):
    """The headline guarantee: greedy spec streams equal target-only
    streams token for token, with exactly one readback per step."""
    cfg, params = _setup()
    prompts = _prompts(cfg, n=6, length=14, seed=1)
    _, gold = _run(cfg, params, prompts, slots=3)
    eng, out = _run(cfg, params, prompts, slots=3,
                    spec=_spec(cfg, params, drafter))
    assert out == gold
    s = eng.stats()
    assert s["spec_on"] and s["spec_drafter"] == drafter
    assert s["readbacks"] == s["steps"]
    assert s["draft_tokens"] > 0 and s["accepted_tokens"] >= 0


def test_self_draft_accepts_nearly_all():
    """Self-drafting with the target model must accept almost every draft
    (only budget clipping at stream tails loses tokens), so decode takes
    far fewer steps than target-only."""
    cfg, params = _setup()
    prompts = _prompts(cfg, n=4, length=10, seed=2)
    plain, gold = _run(cfg, params, prompts, max_new=12)
    eng, out = _run(cfg, params, prompts, max_new=12,
                    spec=_spec(cfg, params, "draft_model", k=3))
    assert out == gold
    s = eng.stats()
    assert s["accepted_per_step"] > 1.0
    assert 0.0 < s["accept_rate"] <= 1.0
    assert s["steps"] < plain.stats()["steps"]


def test_spec_exact_max_new_budget():
    """Variable acceptance must stop at exactly the same token count as
    target-only decoding for every budget — the on-device clamp cannot
    overshoot on the final partial step (k=4 > several of the budgets)."""
    cfg, params = _setup()
    for max_new in (1, 2, 5, 7):
        prompts = _prompts(cfg, n=3, seed=3)
        _, gold = _run(cfg, params, prompts, max_new=max_new)
        eng, out = _run(cfg, params, prompts, max_new=max_new,
                        spec=_spec(cfg, params, "draft_model", k=4))
        assert out == gold
        assert all(len(v) == max(max_new, 2) for v in out.values())
        assert eng.stats()["readbacks"] == eng.stats()["steps"]


# -- composition with the serving subsystems ---------------------------------

@pytest.mark.parametrize("drafter", DRAFTERS)
def test_spec_bit_identical_under_forced_preemption(drafter):
    """Oversubscribed pool: requests are swap-evicted mid-generation and
    readmitted — the drafter state (contiguous KV rows for draft_model)
    must survive the round-trip, streams staying bit-identical."""
    cfg, params = _setup()
    prompts = _prompts(cfg, n=5, length=26, seed=4)
    kw = dict(max_new=16, slots=3, max_seq=64,
              cache_manager=CacheConfig(page_size=16, num_pages=6))
    _, gold = _run(cfg, params, prompts, **kw)
    eng, out = _run(cfg, params, prompts,
                    spec=_spec(cfg, params, drafter), **kw)
    assert eng.stats()["preemptions"] >= 1
    assert out == gold
    eng._pool.check()


def test_spec_prefix_cache_hits_stay_exact():
    """Shared-prefix prompts under spec: the radix cache must land hits
    (insertion covers only committed tokens) and streams must equal the
    spec-less cached run."""
    cfg, params = _setup()
    rng = np.random.default_rng(5)
    head = rng.integers(0, cfg.vocab, (17,), dtype=np.int32)
    prompts = [np.concatenate([head, rng.integers(0, cfg.vocab, (t,),
                                                  dtype=np.int32)])
               for t in (3, 5, 7, 4)]
    kw = dict(max_new=8, slots=2, max_seq=64,
              cache_manager=CacheConfig(page_size=16, num_pages=12))
    _, gold = _run(cfg, params, prompts, **kw)
    eng, out = _run(cfg, params, prompts,
                    spec=_spec(cfg, params, "draft_model"), **kw)
    assert eng.stats()["prefix_hit_tokens"] > 0
    assert out == gold
    eng._pool.check()


def test_spec_abort_mid_burst_is_prefix_exact():
    """Aborting a resident request between spec steps frees its pages and
    leaves survivors bit-identical; the aborted stream is a committed
    prefix of its undisturbed run."""
    cfg, params = _setup()
    prompts = _prompts(cfg, n=3, length=12, seed=6)
    _, gold = _run(cfg, params, prompts, max_new=10, slots=3)
    eng = Engine(params, cfg, slots=3, max_seq=64,
                 spec=_spec(cfg, params, "draft_model"))
    rs = [Request(rid=i, prompt=p.copy(), max_new_tokens=10)
          for i, p in enumerate(prompts)]
    for r in rs:
        eng.submit(r)
    for _ in range(2):
        eng.step()
    assert eng.abort(1)
    eng.run()
    reasons = {r.rid: r.finish_reason for r in rs}
    assert reasons == {0: "done", 1: "aborted", 2: "done"}
    assert list(rs[0].out_tokens) == gold[0]
    assert list(rs[2].out_tokens) == gold[2]
    n = len(rs[1].out_tokens)
    assert 0 < n < 10 and list(rs[1].out_tokens) == gold[1][:n]
    assert eng.stats()["aborted"] == 1
    eng._pool.check()
    assert all(not pages for pages in eng._pool.owned)


def test_spec_device_fault_recovery_restores_drafter_state():
    """Injected device fault mid-spec-decode: the faulting slot is
    quarantined, survivors are swap-restored AND the stateful drafter's
    per-slot cache rows are restored byte-for-byte (every restore_slot
    round-trips through snapshot_slot exactly), streams finishing
    bit-identical to an undisturbed run."""
    cfg, params = _setup()
    prompts = _prompts(cfg, n=4, length=18, seed=7)
    _, gold = _run(cfg, params, prompts, max_new=10)
    chaos = ChaosInjector([Fault("device_fault", step=3, slot=0)])
    eng = Engine(params, cfg, slots=2, max_seq=64, chaos=chaos,
                 spec=_spec(cfg, params, "draft_model"))
    assert isinstance(eng._drafter, DraftModelDrafter)
    orig_restore = eng._drafter.restore_slot
    roundtrips = []

    def checked_restore(slot, saved):
        orig_restore(slot, saved)
        after = eng._drafter.snapshot_slot(slot)
        roundtrips.append(all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(saved),
                            jax.tree.leaves(after))))

    eng._drafter.restore_slot = checked_restore
    rs = [Request(rid=i, prompt=p.copy(), max_new_tokens=10)
          for i, p in enumerate(prompts)]
    for r in rs:
        eng.submit(r)
    eng.run()
    assert chaos.exhausted
    reasons = sorted(r.finish_reason for r in rs)
    assert reasons == ["done", "done", "done", "failed"]
    assert roundtrips and all(roundtrips), \
        "drafter cache rows must restore byte-for-byte"
    for r in rs:
        if r.finish_reason == "done":
            assert list(r.out_tokens) == gold[r.rid], \
                f"survivor {r.rid} diverged after recovery"
    s = eng.stats()
    assert s["recoveries"] == 1 and s["failed"] == 1
    assert s["readbacks"] == s["steps"]
    eng._pool.check()


def test_spec_inert_on_contiguous_and_moe():
    """Layouts that cannot speculate (contiguous pool: no trap page; moe
    serves contiguous) leave the config silently inert: zero counters,
    streams identical to a spec-less run."""
    for cfg, params, kw in (
            (*_setup("qwen2-0.5b"),
             dict(cache_manager=CacheConfig(paged=False))),
            (*_setup("olmoe-1b-7b"), {})):
        prompts = _prompts(cfg, n=3, length=8, seed=8)
        _, gold = _run(cfg, params, prompts, max_new=5, **kw)
        eng, out = _run(cfg, params, prompts, max_new=5,
                        spec=SpecConfig(drafter="ngram", k=3), **kw)
        s = eng.stats()
        assert not s["spec_on"]
        assert s["draft_tokens"] == 0 and s["accepted_per_step"] == 0.0
        assert out == gold


# -- facade + API surface ----------------------------------------------------

def test_llm_engine_reports_accepted_tokens():
    """LLMEngine(spec=...) surfaces per-request accepted_tokens on
    RequestOutput, matching the engine counters; streams equal no-spec."""
    cfg, params = _setup()
    prompts = _prompts(cfg, n=3, length=10, seed=9)
    plain = LLMEngine(params, cfg, slots=3, max_seq=64)
    gold = plain.generate(prompts, max_new_tokens=8)
    llm = LLMEngine(params, cfg, slots=3, max_seq=64,
                    spec=_spec(cfg, params, "draft_model"))
    outs = llm.generate(prompts, max_new_tokens=8)
    assert [o.tokens for o in outs] == [o.tokens for o in gold]
    assert all(o.accepted_tokens == 0 for o in gold)
    assert sum(o.accepted_tokens for o in outs) \
        == llm.engine.stats()["accepted_tokens"]
    assert any(o.accepted_tokens > 0 for o in outs)


def test_stream_one_event_per_accepted_token():
    """A spec step can land several tokens at once, but stream() still
    yields exactly one in-order TokenEvent per accepted token — spec-off
    consumers see no behavioral change."""
    cfg, params = _setup()
    prompts = _prompts(cfg, n=2, length=10, seed=10)
    llm = LLMEngine(params, cfg, slots=2, max_seq=64,
                    spec=_spec(cfg, params, "draft_model"))
    per = {}
    for ev in llm.stream(prompts, max_new_tokens=6):
        assert ev.token >= 0
        assert ev.index == per.get(ev.rid, 0), "per-token, in order"
        per[ev.rid] = ev.index + 1
        if ev.done:
            assert ev.accepted_tokens > 0
    assert per and all(n == 6 for n in per.values())


def test_reference_engine_rejects_spec():
    """The host-driven oracle cannot speculate; passing a SpecConfig is a
    typed error, not a silent ignore."""
    cfg, params = _setup()
    with pytest.raises(ValueError, match="target-only oracle"):
        ReferenceEngine(params, cfg, slots=2, max_seq=64,
                        spec=SpecConfig(drafter="ngram"))


def test_spec_rejects_non_greedy_at_admission():
    """Sampling requests cannot serve under spec (the verify commits
    argmax agreement only): rejected up front with a typed reason."""
    cfg, params = _setup()
    eng = Engine(params, cfg, slots=2, max_seq=64,
                 spec=_spec(cfg, params, "ngram"))
    req = Request(rid=0, prompt=_prompts(cfg, n=1)[0], max_new_tokens=4,
                  sampling=SamplingParams(temperature=0.7))
    eng.submit(req)
    assert req.finish_reason == "rejected"
    assert "greedy" in req.error
    assert eng.stats()["rejected"] == 1


def test_spec_config_validation():
    with pytest.raises(ValueError, match="drafter="):
        SpecConfig(drafter="oracle")
    with pytest.raises(ValueError, match="k=0"):
        SpecConfig(k=0)
    with pytest.raises(ValueError, match="ngram=0"):
        SpecConfig(ngram=0)
    with pytest.raises(ValueError, match="draft_params"):
        SpecConfig(drafter="draft_model")


def test_make_drafter_rejects_frames_and_vocab_mismatch():
    cfg, params = _setup()
    frames = dataclasses.replace(configs.smoke("seamless-m4t-large-v2"))
    with pytest.raises(ValueError, match="frames"):
        make_drafter(SpecConfig(drafter="draft_model", k=2,
                                draft_params=params, draft_cfg=frames),
                     cfg, slots=2, max_seq=64)
    small_vocab = dataclasses.replace(cfg, vocab=cfg.vocab // 2)
    with pytest.raises(ValueError, match="vocab"):
        make_drafter(SpecConfig(drafter="draft_model", k=2,
                                draft_params=params,
                                draft_cfg=small_vocab),
                     cfg, slots=2, max_seq=64)


def test_write_mask_requires_paged_layout():
    """The trap-page trick needs the paged pool; the contiguous decode
    surface refuses a write_mask instead of silently dropping it."""
    cfg, _ = _setup()
    with pytest.raises(ValueError, match="trap page"):
        registry.decode_cached(None, cfg, None, None, None,
                               write_mask=np.ones((2,), bool))


def test_ngram_drafter_prompt_lookup():
    """The n-gram drafter proposes the continuation of a repeated prompt
    pattern (host-side, stateless — exact by construction)."""
    d = NGramDrafter(k=3, ngram=2)
    assert not d.stateful
    ctx = np.array([5, 6, 7, 8, 5, 6], dtype=np.int32)
    np.testing.assert_array_equal(d._lookup(ctx), [7, 8, 5])
    # no match anywhere -> zeros fallback, never garbage
    cold = d._lookup(np.array([1, 2, 3], dtype=np.int32))
    assert cold.shape == (3,) and (cold == 0).all()
