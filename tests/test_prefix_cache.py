"""Radix prefix-cache tests: bit-identical streams (prefix-cached ==
no-prefix-cache paged == host-driven reference) for greedy and seeded
non-greedy sampling, forced copy-on-write on a full-prompt match, forced
preemption while pages are shared, LRU tree eviction under pool pressure,
and the prefill-compile collapse that is the feature's whole point."""

import jax
import numpy as np

from repro import configs
from repro.models import registry
from repro.serving.cache_manager import CacheConfig
from repro.serving.engine import Engine, Request
from repro.serving.reference import ReferenceEngine
from repro.serving.sampling import SamplingParams

_STATE = {}


def _setup():
    if not _STATE:
        cfg = configs.smoke("qwen2-0.5b")
        _STATE["cfg"] = cfg
        _STATE["params"] = registry.init(cfg, jax.random.PRNGKey(0))[0]
    return _STATE["cfg"], _STATE["params"]


def _shared_prompts(cfg, seed=0):
    """A staircase over one 48-token base: page-aligned extensions, one
    diverging tail, and one exact duplicate (the forced-CoW shape)."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, cfg.vocab, (48,), dtype=np.int32)
    tail = rng.integers(0, cfg.vocab, (5,), dtype=np.int32)
    return [base[:32], base[:48], np.concatenate([base[:32], tail]),
            base[:48].copy()]


def _run(engine_cls, cfg, params, prompts, *, max_new=6, slots=3,
         max_seq=64, sampling=None, **kw):
    eng = engine_cls(params, cfg, slots=slots, max_seq=max_seq, **kw)
    for rid, p in enumerate(prompts):
        sp = sampling[rid] if sampling is not None else None
        eng.submit(Request(rid=rid, prompt=p, max_new_tokens=max_new,
                           sampling=sp))
    eng.run()
    return {r.rid: list(r.out_tokens) for r in eng.finished}, eng


def test_greedy_streams_bit_identical():
    """Prefix-cached == no-prefix-cache paged == host reference, while the
    cache actually hits (the equality must not be vacuous)."""
    cfg, params = _setup()
    prompts = _shared_prompts(cfg)
    hit, eng = _run(Engine, cfg, params, prompts)
    cold, _ = _run(Engine, cfg, params, prompts,
                   cache_manager=CacheConfig(prefix_cache=False))
    ref, _ = _run(ReferenceEngine, cfg, params, prompts)
    assert hit == cold == ref
    s = eng.stats()
    assert s["prefix_cache"] and s["prefix_hit_tokens"] > 0
    eng._pool.check()


def test_seeded_sampling_streams_bit_identical():
    """Seeded non-greedy draws are a pure function of (seed, index), so
    prefix-cached and cold-cache engines must emit identical streams.
    (The host reference is greedy-only, so the cold paged engine is the
    oracle here.)"""
    cfg, params = _setup()
    prompts = _shared_prompts(cfg)
    sampling = [SamplingParams(temperature=0.8, top_k=20, top_p=0.95,
                               seed=11 * rid + 3)
                for rid in range(len(prompts))]
    hit, eng = _run(Engine, cfg, params, prompts, sampling=sampling)
    cold, _ = _run(Engine, cfg, params, prompts, sampling=sampling,
                   cache_manager=CacheConfig(prefix_cache=False))
    assert hit == cold
    assert eng.stats()["prefix_hit_tokens"] > 0
    assert all(len(v) == 6 for v in hit.values())


def test_forced_cow_divergence():
    """Two requests share a full-prompt prefix then diverge: the duplicate
    admission must copy-on-write its final page (the next decode write
    would otherwise land in a tree-shared page) and still match the
    cold-cache streams token for token."""
    cfg, params = _setup()
    rng = np.random.default_rng(1)
    base = rng.integers(0, cfg.vocab, (32,), dtype=np.int32)
    tail = rng.integers(0, cfg.vocab, (7,), dtype=np.int32)
    prompts = [base, base.copy(), np.concatenate([base[:16], tail])]
    hit, eng = _run(Engine, cfg, params, prompts, max_new=8)
    cold, _ = _run(Engine, cfg, params, prompts, max_new=8,
                   cache_manager=CacheConfig(prefix_cache=False))
    ref, _ = _run(ReferenceEngine, cfg, params, prompts, max_new=8)
    assert hit == cold == ref
    s = eng.stats()
    assert s["cow_copies"] >= 1, "full-prompt match must trigger CoW"
    # the duplicate decoded its own continuation, not a shared buffer:
    # identical prompts share streams, the diverging one does not
    assert hit[0] == hit[1] and hit[2] != hit[0]
    eng._pool.check()


def test_preemption_while_shared():
    """Oversubscribed pool + shared prefixes: swap preemption of a victim
    whose table maps tree-shared pages must leave the tree intact and
    keep streams bit-identical to the never-evicting reference."""
    cfg, params = _setup()
    rng = np.random.default_rng(2)
    base = rng.integers(0, cfg.vocab, (32,), dtype=np.int32)
    t1 = rng.integers(0, cfg.vocab, (3,), dtype=np.int32)
    prompts = [base, base.copy(), np.concatenate([base, t1])]
    kw = dict(max_new=20, slots=3, max_seq=64)
    hit, eng = _run(Engine, cfg, params, prompts,
                    cache_manager=CacheConfig(page_size=16, num_pages=5),
                    **kw)
    ref, _ = _run(ReferenceEngine, cfg, params, prompts, **kw)
    assert hit == ref
    assert eng.stats()["preemptions"] >= 1
    eng._pool.check()
    assert all(not pages for pages in eng._pool.owned)


def test_tree_eviction_under_pressure():
    """Distinct prompts through a minimal pool: every admission must
    reclaim the previous request's tree-cached pages (they are unpinned
    once the request finishes), and the tree never blocks completion."""
    cfg, params = _setup()
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, (24,), dtype=np.int32)
               for _ in range(4)]
    out, eng = _run(Engine, cfg, params, prompts, max_new=4, slots=2,
                    cache_manager=CacheConfig(page_size=16, num_pages=4))
    assert sorted(out) == [0, 1, 2, 3]
    assert all(len(v) == 4 for v in out.values())
    s = eng.stats()
    assert s["tree_evictions"] >= 1
    eng._pool.check()


def test_prefill_compile_collapse():
    """The headline effect: page-aligned staircase prompts reuse cached
    prefixes, so the warm engine compiles (and runs) fewer prefill
    programs than the cold one — suffixes collapse into one bucket."""
    cfg, params = _setup()
    rng = np.random.default_rng(4)
    base = rng.integers(0, cfg.vocab, (48,), dtype=np.int32)
    prompts = [base[:16], base[:32], base[:48]]
    hit, eng = _run(Engine, cfg, params, prompts)
    cold, ceng = _run(Engine, cfg, params, prompts,
                      cache_manager=CacheConfig(prefix_cache=False))
    assert hit == cold
    s, cs = eng.stats(), ceng.stats()
    assert s["prefix_hit_tokens"] == 16 + 32
    assert s["prefill_compiles"] < cs["prefill_compiles"]
    assert s["suffix_shapes"] == [16]


def test_prefix_cache_gating():
    """The knob and the per-family gate: disabled managers report no
    prefix stats; non-paged families never build a tree."""
    cfg, params = _setup()
    eng = Engine(params, cfg, slots=2, max_seq=64,
                 cache_manager=CacheConfig(prefix_cache=False))
    assert not eng.cm.prefix_cache
    assert "prefix_hit_tokens" not in eng.stats()
    cfg_moe = configs.smoke("olmoe-1b-7b")
    assert not registry.prefix_cache_ok(cfg_moe)
