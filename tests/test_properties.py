"""Hypothesis property tests on the system's mathematical invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st  # noqa: E402
from hypothesis.stateful import (  # noqa: E402
    RuleBasedStateMachine, invariant, precondition, rule)

from repro.kernels import ref
from repro.serving.paging import PagePool
from repro.training import compression

jax.config.update("jax_enable_x64", False)

dims = st.integers(min_value=1, max_value=6)
small_f = st.floats(min_value=-8, max_value=8, allow_nan=False,
                    width=32)


def arr(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), rows=st.integers(1, 9),
       d=st.sampled_from([32, 128, 200]))
def test_merge_commutative(seed, rows, d):
    """merge(A, B) == merge(B, A) — LSE merge is symmetric."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    va, vb = (jax.random.normal(k, (rows, d)) for k in ks[:2])
    sa, sb = (jax.random.normal(k, (rows,)) * 6 for k in ks[2:])
    v1, s1 = ref.merge_attn_states_lse(va, sa, vb, sb)
    v2, s2 = ref.merge_attn_states_lse(vb, sb, va, sa)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), rows=st.integers(1, 6),
       d=st.sampled_from([32, 64]))
def test_merge_associative(seed, rows, d):
    """merge(merge(A,B),C) == merge(A,merge(B,C)) — the property that makes
    tree-reduction of split-KV partials valid at any fan-in."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    v = [jax.random.normal(k, (rows, d)) for k in ks[:3]]
    s = [jax.random.normal(k, (rows,)) * 6 for k in ks[3:]]
    vab, sab = ref.merge_attn_states_lse(v[0], s[0], v[1], s[1])
    l_, sl = ref.merge_attn_states_lse(vab, sab, v[2], s[2])
    vbc, sbc = ref.merge_attn_states_lse(v[1], s[1], v[2], s[2])
    r_, sr = ref.merge_attn_states_lse(v[0], s[0], vbc, sbc)
    np.testing.assert_allclose(np.asarray(l_), np.asarray(r_),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sl), np.asarray(sr),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), rows=st.integers(1, 8),
       d=st.sampled_from([64, 256]), shift=small_f)
def test_merge_shift_invariant(seed, rows, d, shift):
    """V_out is invariant to a common shift of both scores (softmax
    normalization); S_out shifts by exactly that amount."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    va, vb = (jax.random.normal(k, (rows, d)) for k in ks[:2])
    sa, sb = (jax.random.normal(k, (rows,)) * 4 for k in ks[2:])
    v1, s1 = ref.merge_attn_states_lse(va, sa, vb, sb)
    v2, s2 = ref.merge_attn_states_lse(va, sa + shift, vb, sb + shift)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2 - s1),
                               np.full((rows,), shift), rtol=2e-4,
                               atol=2e-4)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), rows=st.integers(1, 8),
       d=st.sampled_from([128, 512]), c=st.floats(0.125, 8.0, width=32))
def test_rmsnorm_scale_invariance(seed, rows, d, c):
    """RMSNorm(c*x, w) == RMSNorm(x, w) up to eps — scale invariance."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (rows, d))
    r = jax.random.normal(ks[1], (rows, d))
    w = 1 + 0.1 * jax.random.normal(ks[2], (d,))
    y1, _ = ref.fused_add_rmsnorm(x, r, w, eps=1e-12)
    y2, _ = ref.fused_add_rmsnorm(c * x, c * r, w, eps=1e-12)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=5e-3, atol=5e-3)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16),
       n=st.integers(1, 2000))
def test_compression_roundtrip_bounded(seed, n):
    """Quantize-dequantize error is bounded by scale/2 per element, and
    error feedback keeps the LONG-RUN mean error near zero."""
    g = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (n,)))
    q, s, n_ = compression.quantize(jnp.asarray(g))
    deq = np.asarray(compression.dequantize(q, s, n_, (n,)))
    scales = np.repeat(np.asarray(s)[:, 0], compression.BLOCK)[:n]
    assert np.all(np.abs(deq - g) <= scales / 2 + 1e-7)


def test_compression_error_feedback_accumulates():
    grads = {"w": jnp.full((512,), 0.004)}
    err = None
    total = jnp.zeros((512,))
    for _ in range(8):
        out, err = compression.compress_grads(grads, err)
        total = total + out["w"]
    # with error feedback, the sum of transmitted grads tracks the true sum
    np.testing.assert_allclose(np.asarray(total),
                               np.full((512,), 8 * 0.004), rtol=0.05)


class PagePoolMachine(RuleBasedStateMachine):
    """Random legal interleavings of the refcounted page-pool API —
    private allocation, radix-tree retain/drop, read-only sharing across
    slots, copy-on-write, and slot release — with ``PagePool.check()``
    (refcount = mappings + tree refs, free ⟺ refcount 0, at most one
    writable mapper per shared page, no leaks) asserted after every step.

    Mirrors the engine's usage: shared mappings only target live pages,
    CoW only targets a slot's shared pages and is preceded by ensuring a
    free page, and ``release`` doubles as admission rollback. Chaos
    actions ride along: ``seize_free`` page holds (the injector's
    pool-exhaustion fault) and abort-style compound rollbacks (slot
    release + a batch of tree drops in one step)."""

    SLOTS, NUM_PAGES, PER_SLOT = 3, 12, 6

    def __init__(self):
        super().__init__()
        self.pool = PagePool(num_pages=self.NUM_PAGES, page_size=4,
                             slots=self.SLOTS,
                             pages_per_slot=self.PER_SLOT)
        self.tree: list[int] = []       # simulated radix-tree references
        self.seized: list[int] = []     # live chaos page holds

    def _live(self):
        return [p for p in range(1, self.NUM_PAGES + 1)
                if self.pool.refcnt[p] > 0]

    slots = st.integers(0, SLOTS - 1)

    @rule(slot=slots)
    def alloc(self, slot):
        if len(self.pool.owned[slot]) >= self.PER_SLOT:
            return
        had_free = self.pool.num_free > 0
        assert self.pool.alloc(slot) == had_free

    @rule(slot=slots, n=st.integers(1, 5))
    def alloc_n(self, slot, n):
        fits = (n <= self.pool.num_free
                and len(self.pool.owned[slot]) + n <= self.PER_SLOT)
        before = list(self.pool.owned[slot])
        assert self.pool.alloc_n(slot, n) == fits
        got = len(self.pool.owned[slot]) - len(before)
        assert got == (n if fits else 0), "alloc_n must be all-or-nothing"

    @rule(slot=slots)
    def release(self, slot):
        self.pool.release(slot)
        assert not self.pool.owned[slot] and not self.pool.shared[slot]

    @rule(data=st.data())
    def tree_retain(self, data):
        live = self._live()
        if not live:
            return
        page = data.draw(st.sampled_from(live), label="retain page")
        self.pool.retain(page)
        self.tree.append(page)

    @precondition(lambda self: self.tree)
    @rule(data=st.data())
    def tree_drop(self, data):
        i = data.draw(st.integers(0, len(self.tree) - 1), label="drop idx")
        self.pool.drop(self.tree.pop(i))

    @rule(slot=slots, data=st.data())
    def map_shared(self, slot, data):
        room = self.PER_SLOT - len(self.pool.owned[slot])
        cands = [p for p in self._live()
                 if p not in self.pool.owned[slot]]
        if not room or not cands:
            return
        k = data.draw(st.integers(1, min(room, len(cands))), label="k")
        pages = data.draw(
            st.permutations(cands), label="shared pages")[:k]
        before = {p: self.pool.refcnt[p] for p in pages}
        self.pool.map_shared(slot, list(pages))
        assert all(self.pool.refcnt[p] == before[p] + 1 for p in pages)

    @rule(slot=slots, data=st.data())
    def cow(self, slot, data):
        shared_idx = [i for i, p in enumerate(self.pool.owned[slot])
                      if p in self.pool.shared[slot]]
        if not shared_idx or not self.pool.num_free:
            return
        idx = data.draw(st.sampled_from(shared_idx), label="cow idx")
        src, dst = self.pool.cow(slot, idx)
        assert dst not in self.pool.shared[slot]
        assert self.pool.owned[slot][idx] == dst != src
        assert self.pool.refcnt[dst] == 1

    @rule(n=st.integers(1, 4))
    def chaos_seize(self, n):
        free_before = self.pool.num_free
        got = self.pool.seize_free(n)
        assert len(got) == min(n, free_before)
        assert all(self.pool.refcnt[p] == 1 and self.pool._ext[p] == 1
                   for p in got), "seized pages must be ext-pinned"
        self.seized.extend(got)

    @precondition(lambda self: self.seized)
    @rule(data=st.data())
    def chaos_release(self, data):
        k = data.draw(st.integers(1, len(self.seized)), label="release k")
        drop, self.seized = self.seized[:k], self.seized[k:]
        self.pool.release_seized(drop)

    @rule(slot=slots, data=st.data())
    def abort_rollback(self, data):
        """Abort-style compound rollback: drop the slot's mappings AND a
        batch of tree retains in one step — what ``Engine.abort`` does
        for a resident request holding radix-shared prefix pages."""
        n_drop = data.draw(st.integers(0, min(3, len(self.tree))),
                           label="tree drops")
        self.pool.release(slot)
        for _ in range(n_drop):
            self.pool.drop(self.tree.pop())
        assert not self.pool.owned[slot] and not self.pool.shared[slot]

    @invariant()
    def pool_invariants(self):
        self.pool.check()


PagePoolMachine.TestCase.settings = settings(
    max_examples=30, stateful_step_count=40, deadline=None)
TestPagePoolStateMachine = PagePoolMachine.TestCase


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), b=st.integers(1, 3),
       s=st.sampled_from([64, 100]),
       hq=st.sampled_from([2, 4]), hkv=st.sampled_from([1, 2]))
def test_flash_attention_matches_softmax(seed, b, s, hq, hkv):
    """flash_attention (custom-VJP scan) == plain softmax attention."""
    from repro.models import layers as L
    dh = 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, hq, dh))
    k = jax.random.normal(ks[1], (b, s, hkv, dh))
    v = jax.random.normal(ks[2], (b, s, hkv, dh))
    got = L.flash_attention(q, k, v, True, None, 32, False)
    g = hq // hkv
    sc = jnp.einsum("bqhgd,bkhd->bhgqk",
                    q.reshape(b, s, hkv, g, dh) * dh ** -0.5, k)
    mask = jnp.tril(jnp.ones((s, s), bool))
    sc = jnp.where(mask[None, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, -1)
    want = jnp.einsum("bhgqk,bkhd->bhgqd", p, v) \
        .transpose(0, 3, 1, 2, 4).reshape(b, s, hq, dh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
