"""flash_attention (custom recomputing VJP) vs plain softmax autodiff."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L

B, HQ, HKV, DH = 2, 4, 2, 32


def plain(q, k, v, causal=True, window=None):
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    s = jnp.einsum("bqhgd,bkhd->bhgqk",
                   q.reshape(b, sq, hkv, g, dh) * dh ** -0.5, k)
    qp, kp = jnp.arange(sq), jnp.arange(skv)
    if causal:
        mask = kp[None] <= qp[:, None]
        if window:
            mask &= (qp[:, None] - kp[None]) < window
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, dh)


@pytest.mark.parametrize("causal,window,chunk,s", [
    (True, None, 16, 64), (True, 24, 16, 64), (False, None, 32, 96),
    (True, None, 64, 100),   # padded final chunk
])
def test_forward_and_grads(causal, window, chunk, s):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, s, HQ, DH))
    k = jax.random.normal(ks[1], (B, s, HKV, DH))
    v = jax.random.normal(ks[2], (B, s, HKV, DH))
    got = L.flash_attention(q, k, v, causal, window, chunk, not causal)
    want = plain(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    f = lambda *a: (L.flash_attention(*a, causal, window, chunk,
                                      not causal) ** 2).sum()
    g = lambda *a: (plain(*a, causal, window) ** 2).sum()
    g1 = jax.grad(f, (0, 1, 2))(q, k, v)
    g2 = jax.grad(g, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4)


def test_no_probability_residuals():
    """The custom VJP must not stack per-chunk probabilities: the jaxpr of
    the VJP should contain no [n_chunks, ..., S, chunk]-sized constants."""
    s, chunk = 256, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, s, 2, 16))
    k = jax.random.normal(ks[1], (1, s, 1, 16))
    v = jax.random.normal(ks[2], (1, s, 1, 16))
    f = lambda q, k, v: L.flash_attention(q, k, v, True, None, chunk,
                                          False).sum()
    jaxpr = jax.make_jaxpr(jax.grad(f, (0, 1, 2)))(q, k, v)
    stacked = s // chunk * s * chunk  # elements of a stacked p residual
    for eqn_var in jaxpr.jaxpr.eqns:
        for out in eqn_var.outvars:
            shape = getattr(out.aval, "shape", ())
            assert np.prod(shape, initial=1) < stacked * 2, (
                f"found stacked residual-sized buffer {shape}")
