"""Tensor-parallel serving: bit-identity vs the single-device engine.

Each test shells out to ``tools/sharded_check.py`` so the forced-host
device count (``--xla_force_host_platform_device_count``) lands in
XLA_FLAGS *before* jax initializes — the in-process test session has
already created the default single-CPU backend. The harness runs both
engines in one subprocess and compares token streams plus every
deterministic counter (steps, readbacks, preemptions, prefix hits, CoW
copies, recoveries) across scenarios: greedy, seeded sampling, forced
swap preemption, radix prefix-cache hits, and chaos device-fault
recovery.
"""

import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CHECK = os.path.join(REPO, "tools", "sharded_check.py")


def _run_check(arch, mesh, devices=4):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.pop("XLA_FLAGS", None)  # the harness sets the device count itself
    proc = subprocess.run(
        [sys.executable, CHECK, "--arch", arch, "--mesh", mesh,
         "--devices", str(devices), "--json"],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, \
        f"sharded check failed:\n{proc.stdout}\n{proc.stderr}"
    return json.loads(proc.stdout)


def _assert_scenarios(report):
    sc = report["scenarios"]
    assert set(sc) == {"greedy", "sampling", "preempt", "prefix", "chaos"}
    for name, r in sc.items():
        assert r["ok"], f"{name}: {r['notes']}"
        assert r["streams_match"], name
        # one batched host readback per dispatched step, exactly
        assert r["counters"]["readbacks"] == r["counters"]["steps"]
    assert sc["preempt"]["counters"]["preemptions"] > 0
    assert sc["prefix"]["counters"]["prefix_hit_tokens"] > 0
    assert sc["chaos"]["counters"]["recoveries"] == 1


def test_sharded_streams_bit_identical_full_tp():
    """qwen3-8b smoke on a (2, 2) mesh: heads, MLP, and vocab all shard
    over ``model``; the slot batch shards over ``data``."""
    report = _run_check("qwen3-8b", "2,2")
    assert report["ok"], report
    assert report["plan"] == {"data": 2, "model": 2, "heads_tp": True,
                              "mlp_tp": True, "vocab_tp": True,
                              "batch_dp": True}
    _assert_scenarios(report)


def test_sharded_streams_bit_identical_replicated_heads_fallback():
    """qwen2-0.5b smoke on a (1, 4) mesh: 1 KV head can't shard over 4,
    so heads replicate while the MLP and vocab axes still shard — the
    fallback ``sharding/rules.py`` documents."""
    report = _run_check("qwen2-0.5b", "1,4")
    assert report["ok"], report
    assert report["plan"] == {"data": 1, "model": 4, "heads_tp": False,
                              "mlp_tp": True, "vocab_tp": True,
                              "batch_dp": False}
    _assert_scenarios(report)
