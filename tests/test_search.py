"""Search subsystem: strategies, memoized evaluation, kernel registry,
and the tolerance-semantics regression (near-zero oracle values).

Fast paths use float32-only suites; the full four-kernel beam-vs-greedy
acceptance sweep is ``@pytest.mark.slow``.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TestingAgent, ProfilingAgent, optimize
from repro.core.agents import Suggestion
from repro.core.policy import PolicyBackend
from repro.kernels.registry import (KernelSpace, TestCase, SPACES, get_space,
                                    register_kernel_space,
                                    registered_kernels)
from repro.search import (BeamSearch, EvalCache, GreedyChain, Population,
                          SearchOrchestrator, genome_digest, resolve_strategy)

ALL_KERNELS = ("silu_and_mul", "fused_add_rmsnorm", "merge_attn_states_lse",
               "flash_decode", "paged_flash_decode")


def fast_orchestrator(cache=None):
    """float32-only suites: halves interpret-mode validation cost."""
    return SearchOrchestrator(testing=TestingAgent(dtypes=(jnp.float32,)),
                              cache=cache if cache is not None
                              else EvalCache())


def test_orchestrator_uses_caller_supplied_empty_cache():
    """Regression: an empty EvalCache is falsy (len 0) — the orchestrator
    must still adopt it rather than silently allocating its own."""
    cache = EvalCache()
    orch = SearchOrchestrator(cache=cache)
    assert orch.cache is cache


# ---------------------------------------------------------------- registry

def test_all_four_kernels_registered():
    assert registered_kernels() == tuple(sorted(ALL_KERNELS))
    for name in ALL_KERNELS:
        space = get_space(name)
        assert space.name == name
        assert space.knobs and space.suite_shapes
        assert space.make_inputs is not None
        assert space.shipped is not None


def test_spaces_view_is_dict_compatible():
    assert len(SPACES) == len(ALL_KERNELS)
    assert set(SPACES) == set(ALL_KERNELS)
    assert SPACES["silu_and_mul"] is get_space("silu_and_mul")
    with pytest.raises(KeyError):
        SPACES["no_such_kernel"]


def test_register_rejects_duplicates_and_non_spaces():
    with pytest.raises(ValueError):
        register_kernel_space(get_space("silu_and_mul"))
    with pytest.raises(TypeError):
        register_kernel_space(lambda: "not a space")


# ---------------------------------------------------------------- digests

def test_genome_digest_ignores_cosmetic_name():
    space = get_space("silu_and_mul")
    a = space.baseline
    b = dataclasses.replace(a, name="renamed-but-identical")
    c = dataclasses.replace(a, block_rows=a.block_rows * 2)
    assert genome_digest(a) == genome_digest(b)
    assert genome_digest(a) != genome_digest(c)


# ------------------------------------------------------------------ cache

def test_eval_cache_memoizes_by_genome_content():
    space = get_space("silu_and_mul")
    testing = TestingAgent(dtypes=(jnp.float32,))
    tests = testing.generate_tests(space)[:2]
    profiling = ProfilingAgent(reps=100)
    cache = EvalCache()

    r1 = cache.evaluate(space, space.baseline, tests, testing=testing,
                        profiling=profiling)
    assert not r1.cached and r1.validated and r1.passed
    # same knobs, different cosmetic name -> hit
    renamed = dataclasses.replace(space.baseline, name="other")
    r2 = cache.evaluate(space, renamed, tests, testing=testing,
                        profiling=profiling)
    assert r2.cached and r2.profile is r1.profile
    assert cache.stats() == {"entries": 1, "hits": 1, "misses": 1,
                             "hit_rate": 0.5, "preloaded": 0,
                             "max_evals_per_genome": 1}


def test_eval_cache_upgrades_unvalidated_entry_without_reprofiling():
    space = get_space("silu_and_mul")
    testing = TestingAgent(dtypes=(jnp.float32,))
    tests = testing.generate_tests(space)[:2]
    profiling = ProfilingAgent(reps=100)
    cache = EvalCache()

    r1 = cache.evaluate(space, space.baseline, tests, testing=testing,
                        profiling=profiling, validate=False)
    assert not r1.validated and r1.passed
    r2 = cache.evaluate(space, space.baseline, tests, testing=testing,
                        profiling=profiling, validate=True)
    assert r2.validated and r2.profile is r1.profile
    # profiling ran once, validation ran once: still <= 1 per genome
    assert cache.max_evals_per_genome() == 1
    # now fully validated: further lookups are pure hits
    r3 = cache.evaluate(space, space.baseline, tests, testing=testing,
                        profiling=profiling)
    assert r3.cached


# -------------------------------------------------------------- strategies

def test_resolve_strategy_accepts_name_class_and_instance():
    assert isinstance(resolve_strategy("greedy"), GreedyChain)
    assert isinstance(resolve_strategy(BeamSearch), BeamSearch)
    beam = BeamSearch(width=2)
    assert resolve_strategy(beam) is beam
    with pytest.raises(KeyError):
        resolve_strategy("annealing")


def test_cache_isolated_across_testing_seeds_and_profiling_fidelity():
    """Suites with identical shapes but different agent seed or profiling
    reps must not share cache entries."""
    from repro.search.strategies import SearchContext
    from repro.core import CodingAgent, PlanningAgent
    space = get_space("silu_and_mul")
    cache = EvalCache()

    def ctx(seed, reps):
        testing = TestingAgent(dtypes=(jnp.float32,), seed=seed)
        return SearchContext(space=space, testing=testing,
                             profiling=ProfilingAgent(reps=reps),
                             planning=PlanningAgent(), coding=CodingAgent(),
                             tests=testing.generate_tests(space)[:1],
                             cache=cache)

    digests = {ctx(0, 100).tests_digest, ctx(42, 100).tests_digest,
               ctx(0, 1).tests_digest}
    assert len(digests) == 3


def test_plan_without_explore_holds_when_catalog_exhausted():
    """Algorithm-1 fidelity: plan() never emits exploratory tile resizes —
    a converged greedy chain holds position instead of oscillating."""
    space = get_space("silu_and_mul")
    testing = TestingAgent(dtypes=(jnp.float32,))
    tests = testing.generate_tests(space)[:2]
    backend = PolicyBackend()
    # drive the genome to the catalog optimum: all bool targets reached,
    # vmem high enough that pow2 doubling is off the table
    opt = space.baseline
    for knob in space.knobs:
        if knob.kind == "bool" and knob.target is not None:
            opt = space.mutate(opt, knob, knob.target)
    profile = ProfilingAgent(reps=100).profile(space, opt, tests)
    profile.signals["vmem_frac"] = 0.5          # no resize moves legal
    history = [{"variant": opt, "passed": True, "profile": profile,
                "suggestion": None}]
    sugg = backend.plan(space, opt, True, profile, history)
    assert sugg.value == getattr(opt, sugg.knob), "plan must hold, not explore"
    # beam's plan_many still offers the exploratory breadth
    many = backend.plan_many(space, opt, True, profile, history, k=4)
    assert any("explore" in s.rationale for s in many)


def test_plan_many_first_proposal_matches_greedy_plan():
    space = get_space("silu_and_mul")
    testing = TestingAgent(dtypes=(jnp.float32,))
    tests = testing.generate_tests(space)[:2]
    profile = ProfilingAgent(reps=100).profile(space, space.baseline, tests)
    history = [{"variant": space.baseline, "passed": True,
                "profile": profile, "suggestion": None}]
    backend = PolicyBackend()
    one = backend.plan(space, space.baseline, True, profile, history)
    many = backend.plan_many(space, space.baseline, True, profile, history,
                             k=4)
    assert many, "policy must propose at least one move from the baseline"
    assert (many[0].knob, many[0].value) == (one.knob, one.value)
    assert len({(s.knob, s.value) for s in many}) == len(many)  # distinct
    for s in many:  # no no-op proposals
        assert s.value != getattr(space.baseline, s.knob)


def test_beam_matches_or_beats_greedy_with_memoized_eval():
    cache = EvalCache()
    orch = fast_orchestrator(cache)
    greedy = orch.search("silu_and_mul", strategy="greedy", rounds=4)
    beam = orch.search("silu_and_mul", strategy=BeamSearch(width=4),
                       rounds=4)
    g, b = greedy.best(), beam.best()
    assert b.correct
    assert b.perf.geomean_latency_us <= g.perf.geomean_latency_us
    # the cache guarantees each unique genome was evaluated at most once,
    # even across the two searches; hit counts surface in the search log
    assert cache.max_evals_per_genome() <= 1
    assert beam.meta["cache"]["hits"] >= 1      # beam re-walked greedy's path
    assert beam.meta["strategy"] == "beam"


def test_population_is_seeded_and_finds_correct_variant():
    runs = []
    for _ in range(2):
        orch = fast_orchestrator()
        log = orch.search("silu_and_mul",
                          strategy=Population(size=4, seed=7), rounds=2)
        best = log.best()
        assert best.correct
        assert log.speedup() >= 1.0
        runs.append([e.code.describe() for e in log.entries])
    assert runs[0] == runs[1], "population search must be deterministic"


# Reduced per-kernel suites for the four-kernel acceptance sweep: the full
# default suites put minutes of interpret-mode flash/merge validation behind
# every unique genome; these keep the adversarial structure (ragged rows,
# GQA grouping, -inf empty partitions) at a bounded cost.
REDUCED_SUITES = {
    "silu_and_mul": ({"batch": 16, "hidden": 4096},
                     {"batch": 17, "hidden": 11008}),
    "fused_add_rmsnorm": ({"batch": 256, "hidden": 4096},
                          {"batch": 33, "hidden": 5120}),
    "merge_attn_states_lse": ({"seq": 100, "heads": 7, "head_dim": 128},
                              {"seq": 128, "heads": 8, "head_dim": 256}),
    "flash_decode": ({"batch": 2, "q_heads": 8, "kv_heads": 2,
                      "head_dim": 64, "seq": 512},),
}


@pytest.mark.slow
def test_beam_acceptance_all_four_kernels():
    """Acceptance: BeamSearch(width=4) finds a correct variant at least as
    fast (geomean cost-model latency) as GreedyChain on every registered
    kernel, with each unique genome evaluated at most once."""
    for kernel in ALL_KERNELS:
        space = dataclasses.replace(get_space(kernel),
                                    suite_shapes=REDUCED_SUITES[kernel])
        cache = EvalCache()
        orch = fast_orchestrator(cache)
        greedy = orch.search(space, strategy="greedy", rounds=5)
        beam = orch.search(space, strategy=BeamSearch(width=4), rounds=5)
        g, b = greedy.best(), beam.best()
        assert b.correct, kernel
        assert b.perf.geomean_latency_us <= g.perf.geomean_latency_us, kernel
        assert cache.max_evals_per_genome() <= 1, kernel


# ------------------------------------------------- public API back-compat

def test_optimize_accepts_strategy_and_defaults_to_greedy():
    log = optimize("silu_and_mul", rounds=2,
                   testing=TestingAgent(dtypes=(jnp.float32,)))
    assert log.meta["strategy"] == "greedy"
    assert [e.round for e in log.entries] == [0, 1, 2]
    pop = optimize("silu_and_mul", rounds=1, strategy=Population(size=3),
                   testing=TestingAgent(dtypes=(jnp.float32,)))
    assert pop.meta["strategy"] == "population"
    assert pop.best().correct


# --------------------------------------------- tolerance semantics (fix)

def _toy_space(want: np.ndarray, got: np.ndarray) -> KernelSpace:
    @dataclasses.dataclass(frozen=True)
    class ToyVariant:
        name: str = "toy"

    return KernelSpace(
        name="toy", baseline=ToyVariant(),
        run=lambda variant, *a, interpret=True: jnp.asarray(got),
        oracle=lambda *a: jnp.asarray(want),
        cost=None, knobs=(), suite_shapes=())


def test_tolerance_near_zero_oracle_uses_absolute_bound():
    """err <= atol + rtol*|want|: near zero, atol governs (f32: 1e-4)."""
    tests = [TestCase("t", (), {"dtype": jnp.float32})]
    agent = TestingAgent()
    want = np.array([0.0, 1e-9, -2e-8], np.float32)

    ok, err = agent.validate(_toy_space(want, want + 5e-5),
                             _toy_space(want, want).baseline, tests)
    assert ok and err <= 1.0

    ok, err = agent.validate(_toy_space(want, want + 5e-4),
                             _toy_space(want, want).baseline, tests)
    assert not ok and err > 1.0


def test_tolerance_no_longer_conflates_relative_and_absolute():
    """Old bound (rel <= rtol + atol) let absolute error grow ~1.1e-4*|want|;
    the correct mixed bound caps it at atol + rtol*|want|."""
    tests = [TestCase("t", (), {"dtype": jnp.float32})]
    agent = TestingAgent()
    want = np.array([100.0], np.float32)
    # err = 5e-3: old semantics passed (5e-5 relative < 1.1e-4);
    # correct bound is 1e-4 + 1e-5*100 = 1.1e-3 -> must FAIL.
    ok, err = agent.validate(_toy_space(want, want + 5e-3),
                             _toy_space(want, want).baseline, tests)
    assert not ok and err > 1.0
    # within the mixed bound -> passes
    ok, err = agent.validate(_toy_space(want, want + 5e-4),
                             _toy_space(want, want).baseline, tests)
    assert ok and err <= 1.0


def test_tolerance_nonfinite_oracle_requires_exact_match():
    tests = [TestCase("t", (), {"dtype": jnp.float32})]
    agent = TestingAgent()
    want = np.array([-np.inf, 1.0], np.float32)
    ok, _ = agent.validate(_toy_space(want, want.copy()),
                           _toy_space(want, want).baseline, tests)
    assert ok
    bad = np.array([-1e30, 1.0], np.float32)     # finite stand-in != -inf
    ok, err = agent.validate(_toy_space(want, bad),
                             _toy_space(want, want).baseline, tests)
    assert not ok and err > 1.0
