"""Suite-wide guards.

A wedged candidate evaluation (the exact failure mode the crash-isolated
search defends against) must never hang the test suite. CI installs
``pytest-timeout``, which enforces the ``timeout`` value in pytest.ini.
On environments without it this conftest provides a best-effort SIGALRM
fallback: same budget, main-thread only, skipped where SIGALRM doesn't
exist (or when pytest-timeout is present and already on duty).
"""

import signal

import pytest

try:
    import pytest_timeout           # noqa: F401
    _HAVE_PYTEST_TIMEOUT = True
except ImportError:
    _HAVE_PYTEST_TIMEOUT = False


def _budget_s(item) -> float:
    # pytest-timeout owns the "timeout" ini key when installed; without it
    # the key is unregistered, so read the raw ini file value
    try:
        return float(item.config.inicfg.get("timeout", 0) or 0)
    except (TypeError, ValueError):
        return 0.0


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    limit = 0.0 if _HAVE_PYTEST_TIMEOUT or not hasattr(signal, "SIGALRM") \
        else _budget_s(item)
    if limit <= 0:
        yield
        return

    def _expired(signum, frame):
        pytest.fail(f"test exceeded the {limit:.0f}s suite-wide timeout "
                    "(SIGALRM fallback guard; install pytest-timeout for "
                    "the full implementation)", pytrace=False)

    old = signal.signal(signal.SIGALRM, _expired)
    signal.setitimer(signal.ITIMER_REAL, limit)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0.0)
        signal.signal(signal.SIGALRM, old)
