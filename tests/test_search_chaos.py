"""Crash-isolated search acceptance: sandboxed workers, chaos drills, and
kill -9 resume via the write-ahead journal.

The acceptance criteria of the robustness PR:

  * process isolation is bit-identical to the thread path for
    well-behaved genomes;
  * a chaos run (worker kill + over-deadline hang + corrupted result)
    completes in bounded wall-clock, quarantines only the faulting
    genome, and yields the same best genome as the undisturbed search;
  * ``kill -9`` mid-search followed by resume produces a bit-identical
    ``Log`` — proven both in-process (seeded random journal truncation,
    greedy and beam with workers>1) and with a real SIGKILLed subprocess
    (``tests/driver_search_journal.py``).

Process-isolation tests run on reduced float32 fused_add_rmsnorm suites
(spawn workers pay a JAX import per process — keep the genome count low).
"""

import dataclasses
import json
import os
import random
import signal
import subprocess
import sys
import time

import jax.numpy as jnp
import pytest

from repro.core.agents import Profile, ProfilingAgent, TestingAgent
from repro.core.oplog import Log
from repro.kernels.registry import get_space
from repro.reliability import EvalTimeout, Fault, SearchChaosInjector
from repro.search import (EvalCache, EvalWorkerPool, JournalMismatch,
                          SearchFailure, SearchJournal, SearchOrchestrator,
                          TieredEvaluator, genome_digest, optimize_all,
                          suite_digest)

SMALL = ({"batch": 16, "hidden": 512}, {"batch": 8, "hidden": 512})
TINY = ({"batch": 16, "hidden": 512},)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRIVER = os.path.join(REPO, "tests", "driver_search_journal.py")


def small_space(shapes=SMALL):
    return dataclasses.replace(get_space("fused_add_rmsnorm"),
                               suite_shapes=shapes)


def roster():
    return dict(testing=TestingAgent(dtypes=(jnp.float32,), seed=0),
                profiling=ProfilingAgent(reps=100))


def fingerprint(log):
    """Exact (unrounded) per-entry payload — stricter than LogEntry.row."""
    return [{"round": e.round, "variant": e.code.describe(),
             "correct": bool(e.correct), "rationale": e.rationale,
             "max_err": float(e.max_err),
             "profile": dataclasses.asdict(e.perf)} for e in log.entries]


def result_fields(r):
    return (r.passed, r.max_err, r.validated, r.screened, r.finish_reason,
            r.failed_test, dataclasses.asdict(r.profile))


# -- process isolation ------------------------------------------------------

def test_process_isolation_bit_identical():
    """Well-behaved genomes: sandboxed evaluation returns exactly what the
    thread path returns (frozen thresholds shipped to the worker)."""
    space = small_space()
    ags = roster()
    tests = ags["testing"].generate_tests(space)
    sd = suite_digest(tests)
    base = space.baseline
    variants = [base,
                dataclasses.replace(base, block_rows=base.block_rows * 2),
                dataclasses.replace(base, use_rsqrt=True)]

    ev_t = TieredEvaluator()
    res_t = ev_t.evaluate_many(space, variants, tests, cache=EvalCache(),
                               tests_digest=sd, **ags)
    ev_p = TieredEvaluator()
    with EvalWorkerPool(workers=1, deadline_s=120.0,
                        on_stat=ev_p.bump) as pool:
        res_p = ev_p.evaluate_many(space, variants, tests, cache=EvalCache(),
                                   tests_digest=sd, isolation="process",
                                   pool=pool, **ags)
    assert [result_fields(r) for r in res_t] \
        == [result_fields(r) for r in res_p]
    # and the evaluator's internal search state reconstructs identically
    assert ev_t._best_lat == ev_p._best_lat
    assert ev_t._fail_counts == ev_p._fail_counts
    stats = ev_p.stats
    assert (stats.worker_crashes, stats.eval_timeouts, stats.retries,
            stats.quarantined) == (0, 0, 0, 0)


def test_evaluate_many_rejects_bad_isolation():
    ev = TieredEvaluator()
    with pytest.raises(ValueError):
        ev.evaluate_many(small_space(), [small_space().baseline], [],
                         cache=EvalCache(), isolation="carrier-pigeon",
                         **roster())
    with pytest.raises(ValueError):
        ev.evaluate_many(small_space(), [small_space().baseline], [],
                         cache=EvalCache(), isolation="process", pool=None,
                         **roster())


def test_validate_timeout_budget():
    """The cooperative deadline in TestingAgent.validate raises EvalTimeout
    rather than burning the rest of the suite."""
    space = small_space()
    testing = TestingAgent(dtypes=(jnp.float32,), seed=0)
    tests = testing.generate_tests(space)
    with pytest.raises(EvalTimeout):
        testing.validate(space, space.baseline, tests, timeout_s=0.0)
    ok, _ = testing.validate(space, space.baseline, tests[:1],
                             timeout_s=600.0)
    assert ok


def test_quarantine_is_final_and_persistent(tmp_path):
    """A genome that repeatedly kills its worker is quarantined with a
    crashed verdict, persisted, and never re-run — even by a new process
    loading the same cache file."""
    space = small_space(TINY)
    ags = roster()
    tests = ags["testing"].generate_tests(space)
    sd = suite_digest(tests)
    victim = dataclasses.replace(space.baseline, block_rows=32)
    chaos = SearchChaosInjector(
        [Fault("kill_worker", digest=genome_digest(victim), times=2)])
    path = str(tmp_path / "cache.jsonl")

    ev = TieredEvaluator()
    cache = EvalCache(persist_path=path)
    with EvalWorkerPool(workers=1, deadline_s=60.0, quarantine_after=2,
                        chaos=chaos, on_stat=ev.bump) as pool:
        ok_res, bad_res = ev.evaluate_many(
            space, [space.baseline, victim], tests, cache=cache,
            tests_digest=sd, isolation="process", pool=pool, **ags)
    assert ok_res.passed and ok_res.finish_reason == "ok"
    assert bad_res.finish_reason == "crashed" and bad_res.failed_infra
    assert not bad_res.passed and not bad_res.validated
    assert "worker died" in bad_res.error
    assert ev.stats.quarantined == 1 and ev.stats.worker_crashes == 2
    # the quarantine profile is the analytic cost model, computed in-parent
    assert bad_res.profile.geomean_latency_us > 0

    # a later process preloads the crashed verdict and never re-runs it:
    # no pool exists here, so a cache miss would raise
    cache2 = EvalCache(persist_path=path)
    assert cache2.preloaded >= 2
    ev2 = TieredEvaluator()
    res2 = ev2.evaluate(space, victim, tests, cache=cache2,
                        tests_digest=sd, **ags)
    assert res2.cached and res2.failed_infra
    assert cache2.stats()["hits"] == 1 and cache2.stats()["misses"] == 0


# -- the chaos acceptance run -----------------------------------------------

def test_search_chaos_acceptance():
    """Worker kill + over-deadline hang + corrupted result injected into a
    beam search: bounded wall-clock, only the deliberately-doomed genome
    quarantined, same best genome as the undisturbed search."""
    space = small_space(TINY)
    undisturbed = SearchOrchestrator(
        cache=EvalCache(), workers=2, **roster()).search(
            space, strategy="beam", rounds=2)
    ref_rows = fingerprint(undisturbed)
    best = undisturbed.best().code
    last_round = max(e.round for e in undisturbed.entries)
    # quarantine target: a final-round genome that is not the best — its
    # children were never explored, so killing it perturbs nothing else
    targets = [e.code for e in undisturbed.entries
               if e.round == last_round
               and genome_digest(e.code) != genome_digest(best)]
    assert targets, "beam search too small to pick a quarantine victim"
    victim = targets[-1]
    # recovery faults on three other genomes (fire once -> retry succeeds)
    others = [e.code for e in undisturbed.entries
              if genome_digest(e.code) != genome_digest(victim)]
    chaos = SearchChaosInjector([
        Fault("kill_worker", digest=genome_digest(others[0])),
        Fault("hang_eval", digest=genome_digest(others[1 % len(others)]),
              seconds=30.0),
        Fault("corrupt_result",
              digest=genome_digest(others[2 % len(others)])),
        Fault("kill_worker", digest=genome_digest(victim), times=2),
    ])

    orch = SearchOrchestrator(
        cache=EvalCache(), workers=2, isolation="process",
        pool_config={"deadline_s": 8.0, "quarantine_after": 2,
                     "chaos": chaos}, **roster())
    t0 = time.monotonic()
    with orch:
        log = orch.search(space, strategy="beam", rounds=2)
    wall = time.monotonic() - t0
    # bounded: evals + one 8s deadline + retries/backoff, never the 30s hang
    assert wall < 300.0, f"chaos search took {wall:.0f}s"

    stats = log.meta["stages"]
    assert stats["quarantined"] == 1, "quarantined more than the victim"
    assert stats["recoveries"] == 3
    assert chaos.exhausted
    assert log.best().code == best, "chaos changed the best genome"
    # every row except the victim's is bit-identical to the undisturbed run
    rows = fingerprint(log)
    assert len(rows) == len(ref_rows)
    vdesc = victim.describe()
    for got, want in zip(rows, ref_rows):
        if want["variant"] == vdesc and want["round"] == last_round:
            assert got["correct"] is False
            assert got["profile"] == want["profile"]  # analytic, in-parent
        else:
            assert got == want


# -- journal resume ---------------------------------------------------------

def _journaled_search(path, *, strategy, rounds, workers=1):
    orch = SearchOrchestrator(cache=EvalCache(), workers=workers, **roster())
    return orch.search(small_space(), strategy=strategy, rounds=rounds,
                       journal=SearchJournal(str(path)))


@pytest.mark.parametrize("strategy,rounds,workers",
                         [("greedy", 3, 1), ("beam", 2, 2)])
def test_resume_from_random_truncation(tmp_path, strategy, rounds, workers):
    """Property: kill the search at ANY journal position (seeded random
    cuts + a torn trailing write), resume, and the Log is bit-identical
    to the uninterrupted run."""
    path = tmp_path / f"{strategy}.jsonl"
    ref = fingerprint(_journaled_search(path, strategy=strategy,
                                        rounds=rounds, workers=workers))
    full = path.read_bytes().split(b"\n")
    rng = random.Random(1234)
    cuts = sorted(rng.sample(range(1, len(full) - 1), k=3))
    for cut in cuts:
        path.write_bytes(b"\n".join(full[:cut]) + b"\n"
                         + b'{"type": "eval", "key": ["torn')
        with pytest.warns(UserWarning, match="torn/corrupt tail"):
            log = _journaled_search(path, strategy=strategy, rounds=rounds,
                                    workers=workers)
        assert fingerprint(log) == ref, f"divergence at cut {cut}"
    # a finished journal resumes as pure replay: zero new evaluations
    path.write_bytes(b"\n".join(full))
    log = _journaled_search(path, strategy=strategy, rounds=rounds,
                            workers=workers)
    assert fingerprint(log) == ref
    assert log.meta["journal"]["resumed"]
    assert log.meta["cache"]["misses"] == 0


def _run_driver(journal, out, *extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return subprocess.run(
        [sys.executable, DRIVER, "--journal", str(journal),
         "--out", str(out), *extra],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=300)


@pytest.mark.parametrize("strategy,rounds,workers,kill_after",
                         [("greedy", 2, 1, 2), ("beam", 2, 2, 3)])
def test_kill9_resume_bit_identical(tmp_path, strategy, rounds, workers,
                                    kill_after):
    """The real thing: SIGKILL the search process mid-run (right after the
    N-th journal eval record), rerun with the same journal, and the final
    Log is bit-identical to an uninterrupted run."""
    args = ("--strategy", strategy, "--rounds", str(rounds),
            "--workers", str(workers))
    ref_out = tmp_path / "ref.json"
    proc = _run_driver(tmp_path / "ref.jsonl", ref_out, *args)
    assert proc.returncode == 0, proc.stderr
    ref = json.loads(ref_out.read_text())
    assert not ref["resumed"]

    journal = tmp_path / "killed.jsonl"
    proc = _run_driver(journal, tmp_path / "dead.json", *args,
                       "--kill-after-evals", str(kill_after))
    assert proc.returncode == -signal.SIGKILL, \
        f"driver survived its own kill -9: rc={proc.returncode} " \
        f"{proc.stderr}"
    assert not (tmp_path / "dead.json").exists()
    assert journal.exists() and journal.stat().st_size > 0

    res_out = tmp_path / "resumed.json"
    proc = _run_driver(journal, res_out, *args)
    assert proc.returncode == 0, proc.stderr
    resumed = json.loads(res_out.read_text())
    assert resumed["resumed"] and resumed["replayed"] >= kill_after - 1
    assert resumed["rows"] == ref["rows"]


def test_journal_header_and_round_guards(tmp_path):
    path = str(tmp_path / "j.jsonl")
    header = dict(kernel="k", strategy="greedy", strategy_config={},
                  rounds=2, tests_digest="d", salt="s")
    j = SearchJournal(path)
    assert j.open(**header) is False
    j.record_round(1, ["aaaa"])
    j.close()
    # same search resumes; re-proposing different candidates is caught
    j2 = SearchJournal(path)
    j2.open(**header)
    j2.record_round(1, ["aaaa"])        # identical replay: fine
    with pytest.raises(JournalMismatch):
        j2.record_round(1, ["bbbb"])
    j2.close()
    # a changed config is a different search: discarded, never replayed
    j3 = SearchJournal(path)
    with pytest.warns(UserWarning, match="header mismatch"):
        resumed = j3.open(**dict(header, rounds=5))
    assert resumed is False and j3.rounds == {}
    j3.close()


# -- satellite: cache torn-tail repair --------------------------------------

def _toy_result(lat=1.0):
    from repro.search.types import EvalResult
    return EvalResult(True, 0.0, Profile([], lat, "memory", {}, 0.0))


def test_cache_truncated_tail_skips_warns_and_repairs(tmp_path):
    path = str(tmp_path / "cache.jsonl")
    c1 = EvalCache(persist_path=path)
    c1.put(("k", "g1", "s"), _toy_result(1.0))
    c1.put(("k", "g2", "s"), _toy_result(2.0))
    with open(path, "ab") as f:         # the kill -9 artifact
        f.write(b'{"salt": "xyz", "key": ["k", "g3"')
    with pytest.warns(UserWarning, match="truncated/corrupt trailing line"):
        c2 = EvalCache(persist_path=path)
    assert c2.preloaded == 2            # valid prefix kept, tail skipped
    # the next flush physically truncates the garbage tail: every line in
    # the repaired file parses, and a third load is clean (no warning)
    c2.put(("k", "g3", "s"), _toy_result(3.0))
    with open(path, "rb") as f:
        for line in f:
            json.loads(line)
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        c3 = EvalCache(persist_path=path)
    assert c3.preloaded == 3


# -- satellite: keep-going --------------------------------------------------

def test_optimize_all_keep_going(monkeypatch):
    """One kernel's infra failure becomes a SearchFailure record; the
    remaining kernels still complete."""
    from repro.search import orchestrator as orch_mod
    real = orch_mod.get_space

    def fake_get_space(kernel):
        if kernel == "boom":
            raise RuntimeError("kernel module exploded")
        return dataclasses.replace(
            real(kernel), suite_shapes=({"batch": 16, "hidden": 1024},))

    monkeypatch.setattr(orch_mod, "get_space", fake_get_space)
    results = optimize_all(kernels=("boom", "silu_and_mul"), rounds=1,
                           workers=1, keep_going=True)
    assert isinstance(results["boom"], SearchFailure)
    assert results["boom"].kernel == "boom"
    assert "exploded" in results["boom"].detail
    assert isinstance(results["silu_and_mul"], Log)
    assert results["silu_and_mul"].best().correct
    # without keep_going the failure propagates (historical behavior)
    with pytest.raises(RuntimeError):
        optimize_all(kernels=("boom",), rounds=1, workers=1)


def test_regression_gate_flags_failed_kernels_and_infra_counters():
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "check_regression",
        os.path.join(REPO, "benchmarks", "check_regression.py"))
    cr = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(cr)
    bench = {
        "kernels": [
            {"kernel": "a", "speedup": 1.5, "correct": True,
             "stages": {}},
            {"kernel": "b", "failed": True, "error": "worker died"},
        ],
        "geomean_speedup": 1.5,
        "stage_totals": {"quarantined": 2, "recoveries": 1},
        "serving": [],
    }
    cur = cr.extract(bench)
    assert cur["search_infra"] == {"quarantined": 2, "recoveries": 1,
                                   "failed_kernels": ["b"]}
    baseline = {"kernels": {"a": {"speedup": 1.5, "correct": True}},
                "geomean_speedup": 1.5, "serving": {},
                "search_infra": {"quarantined": 0, "recoveries": 0,
                                 "failed_kernels": []}}
    bad = cr.compare(cur, baseline, kernel_tol=0.1, serving_tol=0.6)
    assert any("quarantined changed 0 -> 2" in m for m in bad)
    assert any("recoveries changed 0 -> 1" in m for m in bad)
    assert any("kernels failed during the bench run" in m for m in bad)
    # clean run passes the new gate
    clean = cr.extract({"kernels": [{"kernel": "a", "speedup": 1.5,
                                     "correct": True, "stages": {}}],
                        "geomean_speedup": 1.5, "stage_totals": {},
                        "serving": []})
    assert cr.compare(clean, baseline, kernel_tol=0.1, serving_tol=0.6) \
        == []
