"""Page-allocator unit tests: alloc/free invariants, no page aliased by two
live slots, trap-page discipline, and all-or-nothing batch allocation."""

import numpy as np
import pytest

from repro.serving.paging import TRAP_PAGE, PagePool


def test_alloc_release_invariants():
    pool = PagePool(num_pages=8, page_size=16, slots=3, pages_per_slot=4)
    assert pool.num_free == 8 and pool.pages_in_use == 0
    assert pool.alloc(0) and pool.alloc(0) and pool.alloc(1)
    pool.check()
    assert pool.pages_in_use == 3
    assert len(pool.owned[0]) == 2 and len(pool.owned[1]) == 1
    # table rows mirror the owned prefix; everything else traps
    assert list(pool.table[0][:2]) == pool.owned[0]
    assert (pool.table[0][2:] == TRAP_PAGE).all()
    assert (pool.table[2] == TRAP_PAGE).all()
    pool.release(0)
    pool.check()
    assert pool.num_free == 7 and pool.owned[0] == []
    assert (pool.table[0] == TRAP_PAGE).all()


def test_no_page_aliased_by_two_live_slots():
    pool = PagePool(num_pages=6, page_size=8, slots=3, pages_per_slot=3)
    rng = np.random.default_rng(0)
    for _ in range(200):                      # random alloc/release churn
        slot = int(rng.integers(3))
        if rng.random() < 0.4:
            pool.release(slot)
        elif len(pool.owned[slot]) < pool.pages_per_slot:
            pool.alloc(slot)
        pool.check()                          # raises on any aliasing
        live = [p for pages in pool.owned for p in pages]
        assert len(live) == len(set(live))
        assert TRAP_PAGE not in live


def test_exhaustion_and_all_or_nothing():
    pool = PagePool(num_pages=4, page_size=16, slots=2, pages_per_slot=4)
    assert pool.alloc_n(0, 3)
    assert not pool.alloc_n(1, 2), "only 1 page left: must change nothing"
    assert pool.owned[1] == [] and pool.num_free == 1
    assert pool.alloc_n(1, 1)
    assert not pool.alloc(0), "pool exhausted"
    pool.check()
    pool.release(0)
    assert pool.alloc_n(0, 3)                 # freed pages come back
    pool.check()


def test_per_slot_capacity_enforced():
    pool = PagePool(num_pages=8, page_size=16, slots=2, pages_per_slot=2)
    assert pool.alloc_n(0, 2)
    with pytest.raises(RuntimeError):
        pool.alloc(0)                         # table row is full
    assert not pool.alloc_n(1, 3), "cannot exceed pages_per_slot"


def test_pool_too_small_rejected():
    # a pool that cannot hold one full-length request could deadlock the
    # engine's head-of-line admission; the allocator refuses to exist
    with pytest.raises(ValueError):
        PagePool(num_pages=3, page_size=16, slots=2, pages_per_slot=4)
