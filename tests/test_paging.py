"""Page-allocator unit tests: alloc/free invariants, no page aliased by two
live slots, trap-page discipline, and all-or-nothing batch allocation."""

import numpy as np
import pytest

from repro.serving.paging import TRAP_PAGE, PagePool


def test_alloc_release_invariants():
    pool = PagePool(num_pages=8, page_size=16, slots=3, pages_per_slot=4)
    assert pool.num_free == 8 and pool.pages_in_use == 0
    assert pool.alloc(0) and pool.alloc(0) and pool.alloc(1)
    pool.check()
    assert pool.pages_in_use == 3
    assert len(pool.owned[0]) == 2 and len(pool.owned[1]) == 1
    # table rows mirror the owned prefix; everything else traps
    assert list(pool.table[0][:2]) == pool.owned[0]
    assert (pool.table[0][2:] == TRAP_PAGE).all()
    assert (pool.table[2] == TRAP_PAGE).all()
    pool.release(0)
    pool.check()
    assert pool.num_free == 7 and pool.owned[0] == []
    assert (pool.table[0] == TRAP_PAGE).all()


def test_no_page_aliased_by_two_live_slots():
    pool = PagePool(num_pages=6, page_size=8, slots=3, pages_per_slot=3)
    rng = np.random.default_rng(0)
    for _ in range(200):                      # random alloc/release churn
        slot = int(rng.integers(3))
        if rng.random() < 0.4:
            pool.release(slot)
        elif len(pool.owned[slot]) < pool.pages_per_slot:
            pool.alloc(slot)
        pool.check()                          # raises on any aliasing
        live = [p for pages in pool.owned for p in pages]
        assert len(live) == len(set(live))
        assert TRAP_PAGE not in live


def test_exhaustion_and_all_or_nothing():
    pool = PagePool(num_pages=4, page_size=16, slots=2, pages_per_slot=4)
    assert pool.alloc_n(0, 3)
    assert not pool.alloc_n(1, 2), "only 1 page left: must change nothing"
    assert pool.owned[1] == [] and pool.num_free == 1
    assert pool.alloc_n(1, 1)
    assert not pool.alloc(0), "pool exhausted"
    pool.check()
    pool.release(0)
    assert pool.alloc_n(0, 3)                 # freed pages come back
    pool.check()


def test_per_slot_capacity_enforced():
    pool = PagePool(num_pages=8, page_size=16, slots=2, pages_per_slot=2)
    assert pool.alloc_n(0, 2)
    with pytest.raises(RuntimeError):
        pool.alloc(0)                         # table row is full
    assert not pool.alloc_n(1, 3), "cannot exceed pages_per_slot"


def test_pool_too_small_rejected():
    # a pool that cannot hold one full-length request could deadlock the
    # engine's head-of-line admission; the allocator refuses to exist
    with pytest.raises(ValueError):
        PagePool(num_pages=3, page_size=16, slots=2, pages_per_slot=4)


def test_shared_mapping_refcounts():
    pool = PagePool(num_pages=6, page_size=8, slots=3, pages_per_slot=4)
    assert pool.alloc_n(0, 2)
    prefix = list(pool.owned[0])
    for p in prefix:                          # the tree pins the prefix
        pool.retain(p)
    pool.map_shared(1, prefix)                # second slot maps it read-only
    pool.check()
    assert pool.refcnt[prefix[0]] == 3        # slot0 + slot1 + tree
    assert pool.shared[1] == set(prefix) and pool.shared[0] == set()
    pool.release(0)                           # original owner leaves …
    pool.check()
    assert pool.refcnt[prefix[0]] == 2        # … pages stay live
    pool.release(1)
    pool.check()
    assert pool.refcnt[prefix[0]] == 1 and pool.num_free == 4
    for p in prefix:                          # tree eviction frees them
        pool.drop(p)
    pool.check()
    assert pool.num_free == 6


def test_cow_repoints_only_the_writer():
    pool = PagePool(num_pages=6, page_size=8, slots=2, pages_per_slot=3)
    assert pool.alloc_n(0, 2)
    prefix = list(pool.owned[0])
    for p in prefix:
        pool.retain(p)
    pool.map_shared(1, prefix)
    src, dst = pool.cow(1, 1)                 # slot1 writes into page idx 1
    pool.check()
    assert src == prefix[1] and dst not in prefix
    assert pool.owned[1] == [prefix[0], dst]
    assert pool.table[1][1] == dst
    assert pool.owned[0] == prefix, "other mapper untouched"
    assert dst not in pool.shared[1], "the copy is private"
    assert pool.refcnt[src] == 2 and pool.refcnt[dst] == 1
    with pytest.raises(AssertionError):
        pool.cow(1, 1)                        # already private


def test_map_shared_capacity_and_dead_pages():
    pool = PagePool(num_pages=8, page_size=8, slots=2, pages_per_slot=2)
    assert pool.alloc_n(0, 2)
    pages = list(pool.owned[0])
    pool.map_shared(1, pages[:1])
    with pytest.raises(RuntimeError):         # would exceed pages_per_slot
        pool.map_shared(1, pages)
    pool.release(0)
    pool.release(1)
    with pytest.raises(AssertionError):       # pages are dead now
        pool.map_shared(1, pages[:1])
