"""Tiered evaluation engine: cascade correctness, oracle memoization,
concurrent evaluation, persistent cross-process cache, and the
throughput/bit-identity acceptance criteria of the engine PR.

The expensive sweeps (beam counters, greedy bit-identity) run on reduced
float32 suites so interpret-mode validation stays cheap; toy spaces cover
the cascade edge cases exactly.
"""

import dataclasses
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CodingAgent, PlanningAgent, ProfilingAgent,
                        TestingAgent, optimize)
from repro.core import costmodel
from repro.core.agents import Profile
from repro.kernels.registry import (KernelSpace, TestCase, clear_suite_memos,
                                    get_space, oracle_outputs, suite_tests)
from repro.search import (BeamSearch, EvalCache, SearchOrchestrator,
                          TieredEvaluator, code_version_salt, genome_digest)

PAPER_KERNELS = ("merge_attn_states_lse", "fused_add_rmsnorm",
                 "silu_and_mul")

# Small-shape float32 suites (4 cases each): the adversarial structure
# (ragged rows, odd head counts) at a fraction of the interpret-mode cost.
SMALL_SUITES = {
    "silu_and_mul": ({"batch": 16, "hidden": 1024},
                     {"batch": 17, "hidden": 2048},
                     {"batch": 8, "hidden": 1024},
                     {"batch": 33, "hidden": 512}),
    "fused_add_rmsnorm": ({"batch": 64, "hidden": 1024},
                          {"batch": 33, "hidden": 2048},
                          {"batch": 16, "hidden": 1024},
                          {"batch": 8, "hidden": 512}),
    "merge_attn_states_lse": ({"seq": 48, "heads": 7, "head_dim": 64},
                              {"seq": 64, "heads": 4, "head_dim": 64},
                              {"seq": 96, "heads": 8, "head_dim": 128},
                              {"seq": 33, "heads": 2, "head_dim": 64}),
}


def small_space(kernel):
    return dataclasses.replace(get_space(kernel),
                               suite_shapes=SMALL_SUITES[kernel])


def sequential_reference():
    """The pre-engine per-genome pipeline, metered by the same counters:
    no screening, no smoke stage, oracle recomputed for every genome."""
    return TieredEvaluator(screen=False, smoke=False, share_oracle=False)


# ------------------------------------------------------------- toy spaces

@dataclasses.dataclass(frozen=True)
class ToyVariant:
    name: str = "toy"
    knob: int = 1


def _cost_for(latency_us: float) -> costmodel.Cost:
    """A Cost whose roofline latency is ~``latency_us`` (memory-bound)."""
    return costmodel.Cost(hbm_bytes=latency_us * 1e-6 * costmodel.HBM_BW,
                          vpu_ops=0.0)


def toy_space(name, *, cost=None, n_tests=2):
    """Feasible-by-default toy space whose kernel matches its oracle."""
    val = jnp.arange(8, dtype=jnp.float32)
    return KernelSpace(
        name=name, baseline=ToyVariant(),
        run=lambda variant, *a, interpret=True: val,
        oracle=lambda *a: val,
        cost=cost or (lambda variant, **kw: _cost_for(10.0 * variant.knob)),
        knobs=(), suite_shapes=()), [
        TestCase(f"t{i}", (), {"dtype": jnp.float32})
        for i in range(n_tests)]


class RefusingTester(TestingAgent):
    """A testing agent that must never be asked to validate."""

    def validate(self, *a, **kw):           # pragma: no cover - the point
        raise AssertionError("screened genome reached interpret-mode "
                             "validation")


class CountingTester(TestingAgent):
    def __init__(self, **kw):
        super().__init__(**kw)
        self.calls = 0
        self._count_lock = threading.Lock()

    def validate(self, *a, **kw):
        with self._count_lock:
            self.calls += 1
        return super().validate(*a, **kw)


# -------------------------------------------------------- cascade screens

def test_infeasible_genome_is_screened_never_validated():
    def cost(variant, **kw):
        raise costmodel.Infeasible("VMEM over budget")
    space, tests = toy_space("toy_infeasible", cost=cost)
    ev, cache = TieredEvaluator(), EvalCache()
    res = ev.evaluate(space, space.baseline, tests,
                      testing=RefusingTester(), profiling=ProfilingAgent(),
                      cache=cache)
    assert res.screened and not res.validated and not res.passed
    assert ev.stats.screened_infeasible == 1
    assert ev.stats.validation_test_runs == 0
    # the verdict is cached (as screened) so a repeat is a pure hit
    again = ev.evaluate(space, space.baseline, tests,
                        testing=RefusingTester(), profiling=ProfilingAgent(),
                        cache=cache)
    assert again.cached and again.screened and not again.validated


def test_legacy_cache_evaluate_honors_screened_entries():
    """A cache shared between the tiered and legacy paths never re-validates
    (or overwrites) what the cascade already rejected."""
    def cost(variant, **kw):
        raise costmodel.Infeasible("VMEM over budget")
    space, tests = toy_space("toy_screen_legacy", cost=cost)
    cache = EvalCache()
    TieredEvaluator().evaluate(space, space.baseline, tests,
                               testing=RefusingTester(),
                               profiling=ProfilingAgent(), cache=cache)
    res = cache.evaluate(space, space.baseline, tests,
                         testing=RefusingTester(),
                         profiling=ProfilingAgent())
    assert res.cached and res.screened and not res.validated


def test_dominated_genome_is_screened_after_a_validated_best():
    space, tests = toy_space("toy_dominated")
    ev, cache = TieredEvaluator(dominate_factor=3.0), EvalCache()
    kw = dict(testing=TestingAgent(), profiling=ProfilingAgent(),
              cache=cache)
    good = ev.evaluate(space, ToyVariant(knob=1), tests, **kw)   # ~10us
    assert good.validated and good.passed
    bad = ev.evaluate(space, ToyVariant(name="bad", knob=50), tests, **kw)
    assert bad.screened and not bad.validated
    assert ev.stats.screened_dominated == 1
    # 2x worse is NOT "clearly dominated" at factor 3: it still validates
    meh = ev.evaluate(space, ToyVariant(name="meh", knob=2), tests, **kw)
    assert meh.validated and not meh.screened


def test_smoke_stage_charges_one_test_for_a_broken_genome():
    val = jnp.arange(8, dtype=jnp.float32)
    space = KernelSpace(
        name="toy_broken", baseline=ToyVariant(),
        run=lambda variant, *a, interpret=True: val + 1.0,   # always wrong
        oracle=lambda *a: val,
        cost=lambda variant, **kw: _cost_for(10.0),
        knobs=(), suite_shapes=())
    tests = [TestCase(f"t{i}", (), {"dtype": jnp.float32}) for i in range(4)]
    ev, cache = TieredEvaluator(), EvalCache()
    res = ev.evaluate(space, space.baseline, tests, testing=TestingAgent(),
                      profiling=ProfilingAgent(), cache=cache)
    assert res.validated and not res.passed and not res.screened
    assert ev.stats.validation_test_runs == 1       # smoke only, not 4
    assert ev.stats.validations_smoke_failed == 1
    assert ev.stats.validations_full == 0


# ------------------------------------------------- oracle memoization

def test_oracle_outputs_memoized_per_suite():
    space, tests = toy_space("toy_oracle_memo", n_tests=3)
    outs, computed = oracle_outputs(space, tests, digest="d1")
    assert computed and len(outs) == 3
    outs2, computed2 = oracle_outputs(space, tests, digest="d1")
    assert not computed2 and outs2 is outs
    _, computed3 = oracle_outputs(space, tests, digest="d2")
    assert computed3                                 # new suite, new oracle


def test_oracle_locking_is_per_key_not_global():
    """A slow oracle run for one kernel must not serialize concurrent
    oracle computation for a DIFFERENT kernel (the lock is per
    (kernel, digest), not the global memo lock), while racing evaluators
    of the same key still compute exactly once."""
    started = threading.Event()
    release = threading.Event()
    calls = {"slow": 0, "fast": 0}
    count_lock = threading.Lock()

    def slow_oracle(*a):
        with count_lock:
            calls["slow"] += 1
        started.set()
        assert release.wait(timeout=10), "fast oracle never unblocked us"
        return jnp.zeros(2)

    def fast_oracle(*a):
        with count_lock:
            calls["fast"] += 1
        return jnp.ones(2)

    slow, slow_tests = toy_space("toy_lock_slow", n_tests=1)
    fast, fast_tests = toy_space("toy_lock_fast", n_tests=1)
    slow = dataclasses.replace(slow, oracle=slow_oracle)
    fast = dataclasses.replace(fast, oracle=fast_oracle)

    results = {}

    def run_slow():
        results["slow"] = oracle_outputs(slow, slow_tests, digest="dl")

    def run_fast():
        started.wait(timeout=10)
        # the slow kernel is mid-oracle: a different key must proceed
        results["fast"] = oracle_outputs(fast, fast_tests, digest="df")
        release.set()

    threads = [threading.Thread(target=run_slow),
               threading.Thread(target=run_fast)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=20)
    assert not any(t.is_alive() for t in threads), \
        "per-key locking deadlocked/serialized across kernels"
    assert results["slow"][1] and results["fast"][1]
    # racing duplicates of the SAME key still compute once
    _, computed = oracle_outputs(slow, slow_tests, digest="dl")
    assert not computed and calls == {"slow": 1, "fast": 1}


def test_suite_tests_memoized_per_kernel_and_agent():
    clear_suite_memos()
    space = get_space("silu_and_mul")
    t1 = suite_tests(space, TestingAgent(dtypes=(jnp.float32,)))
    t2 = suite_tests(space, TestingAgent(dtypes=(jnp.float32,)))
    assert [t.name for t in t1] == [t.name for t in t2]
    assert t1[0] is t2[0]                            # same memoized cases
    # a different roster or shape spec is a different suite
    t3 = suite_tests(space, TestingAgent(dtypes=(jnp.float32,), seed=7))
    assert t3[0] is not t1[0]
    t4 = suite_tests(small_space("silu_and_mul"),
                     TestingAgent(dtypes=(jnp.float32,)))
    assert len(t4) == 4 and t4[0] is not t1[0]


# ------------------------------------------------------- concurrency

def test_eval_cache_evaluate_is_race_free():
    """N racing threads asking for one genome: one validation, one profile,
    N-1 hits — ``max_evals_per_genome`` stays 1."""
    space, tests = toy_space("toy_race")

    class SlowTester(CountingTester):
        def validate(self, *a, **kw):
            time.sleep(0.05)                # hold the key lock long enough
            return super().validate(*a, **kw)

    tester, profiler = SlowTester(), ProfilingAgent()
    cache = EvalCache()
    barrier = threading.Barrier(8)
    results = [None] * 8

    def worker(i):
        barrier.wait()
        results[i] = cache.evaluate(space, space.baseline, tests,
                                    testing=tester, profiling=profiler)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert tester.calls == 1
    assert cache.max_evals_per_genome() == 1
    assert cache.stats()["hits"] == 7 and cache.stats()["misses"] == 1
    lats = {r.profile.geomean_latency_us for r in results}
    assert len(lats) == 1 and all(r.passed for r in results)


def test_evaluate_many_is_parallel_deterministic_and_dedups():
    space, tests = toy_space("toy_many")
    variants = [ToyVariant(name=f"v{k}", knob=k) for k in (1, 2, 1, 3, 2)]
    tester = CountingTester()

    serial_ev = TieredEvaluator()
    serial = serial_ev.evaluate_many(space, variants, tests,
                                     testing=TestingAgent(),
                                     profiling=ProfilingAgent(),
                                     cache=EvalCache(), workers=1)
    cache = EvalCache()
    par_ev = TieredEvaluator()
    parallel = par_ev.evaluate_many(space, variants, tests, testing=tester,
                                    profiling=ProfilingAgent(), cache=cache,
                                    workers=4)
    assert len(parallel) == len(variants)
    for s, p in zip(serial, parallel):
        assert (s.passed, s.validated, s.screened) == \
            (p.passed, p.validated, p.screened)
        assert s.profile.geomean_latency_us == p.profile.geomean_latency_us
    # duplicates collapsed: 3 unique genomes -> 3 validations, 2 hits
    assert cache.max_evals_per_genome() == 1
    assert len(cache) == 3 and cache.stats()["hits"] == 2


# ------------------------------------------------- persistent cache

def test_persistent_cache_round_trips_across_processes(tmp_path):
    path = str(tmp_path / "evalcache.jsonl")
    testing = TestingAgent(dtypes=(jnp.float32,))
    space = small_space("silu_and_mul")

    orch1 = SearchOrchestrator(testing=testing,
                               cache=EvalCache(persist_path=path))
    log1 = orch1.search(space, rounds=3)
    assert log1.meta["cache"]["misses"] > 0

    # a fresh cache instance = a second orchestrator process
    cache2 = EvalCache(persist_path=path)
    assert cache2.preloaded == log1.meta["cache"]["entries"]
    orch2 = SearchOrchestrator(testing=testing, cache=cache2)
    log2 = orch2.search(space, rounds=3)
    assert log2.meta["cache"]["misses"] == 0
    assert log2.meta["cache"]["hits"] > 0
    assert log2.meta["stages"]["validation_test_runs"] == 0
    assert log2.meta["stages"]["oracle_computations"] == 0
    b1, b2 = log1.best(), log2.best()
    assert b1.code.describe() == b2.code.describe()
    assert b1.perf.geomean_latency_us == b2.perf.geomean_latency_us
    assert b1.max_err == b2.max_err


def test_persistent_cache_ignores_stale_salt_and_torn_lines(tmp_path):
    path = str(tmp_path / "evalcache.jsonl")
    space, tests = toy_space("toy_persist")
    cache = EvalCache(persist_path=path)
    cache.evaluate(space, space.baseline, tests, testing=TestingAgent(),
                   profiling=ProfilingAgent())
    with open(path) as f:
        line = f.read().strip()
    assert code_version_salt() in line
    # a stale-salt entry and a torn line must both be skipped on load
    with open(path, "a") as f:
        f.write(line.replace(code_version_salt(), "deadbeef0000") + "\n")
        f.write('{"torn": \n')
    reloaded = EvalCache(persist_path=path)
    assert reloaded.preloaded == 1


# ------------------------- acceptance: bit-identity + throughput win

def _pre_pr_greedy(space, rounds=5):
    """The pre-engine greedy chain: Algorithm 1 with the digest-memoized
    sequential evaluation exactly as the PR-1 ``GreedyChain`` ran it."""
    tester = TestingAgent(dtypes=(jnp.float32,))
    profiler = ProfilingAgent(reps=100)
    planner, coder = PlanningAgent(), CodingAgent()
    tests = tester.generate_tests(space)
    memo = {}

    def evaluate(v, validate=True):
        dg = genome_digest(v)
        if dg in memo and (memo[dg][3] or not validate):
            return memo[dg]
        if dg in memo:                          # upgrade unvalidated entry
            ok, err = tester.validate(space, v, tests)
            memo[dg] = (ok, err, memo[dg][2], True)
            return memo[dg]
        ok, err = tester.validate(space, v, tests) if validate \
            else (True, 0.0)
        memo[dg] = (ok, err, profiler.profile(space, v, tests), validate)
        return memo[dg]

    s_prev = space.baseline
    _, _, perf0, _ = evaluate(s_prev, validate=False)
    rows = [(0, s_prev.describe(), True, perf0.geomean_latency_us, 0.0,
             "baseline")]
    passed, perf = True, perf0
    history = [{"variant": s_prev, "passed": True, "profile": perf0,
                "suggestion": None}]
    for r in range(1, rounds + 1):
        sugg = planner.suggest(space, s_prev, passed, perf, history)
        s_new = coder.apply(space, s_prev, sugg)
        ok, err, pf, _ = evaluate(s_new)
        rows.append((r, s_new.describe(), ok, pf.geomean_latency_us, err,
                     sugg.rationale))
        history.append({"variant": s_new, "passed": ok, "profile": pf,
                        "suggestion": sugg})
        s_prev, passed, perf = s_new, ok, pf
    return rows


def test_greedy_with_engine_is_bit_identical_to_sequential_chain():
    """`optimize(strategy="greedy")` through the tiered engine reproduces
    the pre-engine chain exactly: same Log entries (round, genome, verdict,
    latency, max_err, rationale), same best variant."""
    for kernel in PAPER_KERNELS:
        space = small_space(kernel)
        ref = _pre_pr_greedy(space, rounds=5)
        log = optimize(space, rounds=5,
                       testing=TestingAgent(dtypes=(jnp.float32,)))
        got = [(e.round, e.code.describe(), e.correct,
                e.perf.geomean_latency_us, e.max_err, e.rationale)
               for e in log.entries]
        assert got == ref, kernel
        best_ref = min((r for r in ref if r[2]), key=lambda r: r[3])
        assert log.best().code.describe() == best_ref[1], kernel


def test_tiered_engine_cuts_oracle_and_validation_work_3x():
    """BeamSearch(width=4, rounds=5) over the paper's three kernels: the
    engine does >=3x less expensive work than the sequential per-genome
    path — oracle computations alone and the combined total of oracle
    computations + full-suite validations."""
    def run_beam(evaluator):
        for kernel in PAPER_KERNELS:
            orch = SearchOrchestrator(
                testing=TestingAgent(dtypes=(jnp.float32,)),
                cache=EvalCache(), evaluator=evaluator, workers=4)
            log = orch.search(small_space(kernel),
                              strategy=BeamSearch(width=4), rounds=5)
            assert log.best().correct, kernel
        return evaluator.stats

    seq = run_beam(sequential_reference())
    clear_suite_memos()                 # tiered must pay for its own oracle
    tier = run_beam(TieredEvaluator())

    assert seq.oracle_computations >= 3 * max(tier.oracle_computations, 1)
    combined_seq = seq.oracle_computations + seq.validations_full
    combined_tier = tier.oracle_computations + tier.validations_full
    assert combined_seq >= 3 * combined_tier
    # the engine never validates more than the sequential path
    assert tier.validation_test_runs <= seq.validation_test_runs
    # and a genome is still never evaluated twice
    assert seq.validations_smoke_failed == 0   # smoke off in the reference


# ------------------------------------------------- bench.json surface

def test_bench_json_reports_wall_clock_and_stage_skips(tmp_path):
    import importlib.util
    import os
    spec = importlib.util.spec_from_file_location(
        "bench_run", os.path.join(os.path.dirname(__file__), "..",
                                  "benchmarks", "run.py"))
    bench = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(bench)

    orch = SearchOrchestrator(testing=TestingAgent(dtypes=(jnp.float32,)))
    results = {"silu_and_mul": orch.search(small_space("silu_and_mul"),
                                           rounds=2)}
    payload = bench.bench_json(results, path=str(tmp_path / "bench.json"))
    (entry,) = payload["kernels"]
    assert entry["wall_s"] > 0
    assert entry["cache_hit_rate"] >= 0.0
    for key in ("oracle_computations", "validation_test_runs",
                "validations_full", "screened_infeasible",
                "screened_dominated", "validations_smoke_failed"):
        assert key in entry["stages"], key
        assert key in payload["stage_totals"], key
    assert payload["geomean_speedup"] > 0
