"""Subprocess driver for the kill -9 resume acceptance test.

Runs one journaled search on a reduced fused_add_rmsnorm suite and dumps
an exact Log fingerprint to ``--out``. With ``--kill-after-evals N`` the
process SIGKILLs *itself* immediately after the N-th evaluation record
hits the journal — a real ``kill -9`` at a deterministic journal
position, not a monkeypatched exception. A second invocation against the
same journal path is the ``--resume`` flow: it replays the journal and
must produce a fingerprint bit-identical to an uninterrupted run.

Named ``driver_*`` (not ``test_*``) so pytest never collects it; it is
only ever launched by ``tests/test_search_chaos.py``.
"""

import argparse
import dataclasses
import json
import os
import signal

import jax.numpy as jnp

from repro.core.agents import ProfilingAgent, TestingAgent
from repro.kernels.registry import get_space
from repro.search import EvalCache, SearchJournal, SearchOrchestrator
from repro.search.cache import _jsonable

SMALL = ({"batch": 16, "hidden": 512}, {"batch": 8, "hidden": 512})


def fingerprint(log):
    """Exact (unrounded) per-entry payload — stricter than LogEntry.row."""
    return [{"round": e.round, "variant": e.code.describe(),
             "correct": bool(e.correct), "rationale": e.rationale,
             "max_err": float(e.max_err),
             "profile": dataclasses.asdict(e.perf)} for e in log.entries]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--journal", required=True)
    ap.add_argument("--out", required=True)
    ap.add_argument("--strategy", default="greedy")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--kill-after-evals", type=int, default=0)
    args = ap.parse_args()

    journal = SearchJournal(args.journal)
    if args.kill_after_evals:
        orig = journal.record_eval
        written = {"n": 0}

        def record_and_maybe_die(key, result):
            orig(key, result)
            written["n"] += 1
            if written["n"] >= args.kill_after_evals:
                os.kill(os.getpid(), signal.SIGKILL)

        journal.record_eval = record_and_maybe_die

    space = dataclasses.replace(get_space("fused_add_rmsnorm"),
                                suite_shapes=SMALL)
    orch = SearchOrchestrator(
        testing=TestingAgent(dtypes=(jnp.float32,), seed=0),
        profiling=ProfilingAgent(reps=100),
        cache=EvalCache(), workers=args.workers)
    log = orch.search(space, strategy=args.strategy, rounds=args.rounds,
                      journal=journal)
    with open(args.out, "w") as f:
        json.dump({"rows": fingerprint(log),
                   "resumed": log.meta["journal"]["resumed"],
                   "replayed": log.meta["journal"]["replayed"]},
                  f, default=_jsonable)


if __name__ == "__main__":
    main()
