"""Layered serving-API tests: SamplingParams validation + the fused
on-device draw, seeded-stream determinism (across engine restarts, across
contiguous vs paged cache managers, and under swap preemption), scheduler
policies (FCFS / priority / SJF) with their exact reorder counters, the
LLMEngine generate/stream facade, the deprecation shims for the old
Engine kwargs, and the one-batched-readback-per-step invariant for
non-greedy decode (sampling must add zero extra host syncs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import registry
from repro.serving import (CacheConfig, LLMEngine, Request, SamplingParams)
from repro.serving.engine import Engine
from repro.serving.sampling import sample_tokens

_PARAMS = {}


def _setup(arch="qwen2-0.5b"):
    if arch not in _PARAMS:
        cfg = configs.smoke(arch)
        _PARAMS[arch] = (cfg, registry.init(cfg, jax.random.PRNGKey(0))[0])
    return _PARAMS[arch]


def _requests(cfg, lens, *, max_new=4, seed=0, sampling=None, prios=None):
    rng = np.random.default_rng(seed)
    out = []
    for rid, n in enumerate(lens):
        prompt = rng.integers(0, cfg.vocab, (n,), dtype=np.int32)
        out.append(Request(rid=rid, prompt=prompt, max_new_tokens=max_new,
                           sampling=sampling,
                           priority=prios[rid] if prios else 0))
    return out


def _streams(eng, cfg, lens, **kw):
    for r in _requests(cfg, lens, **kw):
        eng.submit(r)
    done = eng.run()
    return {r.rid: list(r.out_tokens) for r in done}


# ---------------------------------------------------------------------------
# SamplingParams + the draw itself
# ---------------------------------------------------------------------------

def test_sampling_params_validation():
    assert SamplingParams().greedy
    assert not SamplingParams(temperature=0.7).greedy
    assert SamplingParams(seed=None).resolve_seed(5) == 5
    assert SamplingParams(seed=9).resolve_seed(5) == 9
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=1.5)


def test_sample_tokens_reduces_to_argmax():
    """temperature=0, top_k=1, and a tiny top_p must all pick the argmax
    token; draws stay inside the top-k set; (key, index) determinism."""
    rng = np.random.default_rng(0)
    lg = jnp.asarray(rng.standard_normal((6, 64)).astype(np.float32))
    keys = jnp.stack([jax.random.PRNGKey(i) for i in range(6)])
    idx = jnp.arange(6, dtype=jnp.int32)
    ones = jnp.ones((6,))
    zeros_i = jnp.zeros((6,), jnp.int32)
    argmax = np.asarray(jnp.argmax(lg, -1))

    greedy = sample_tokens(lg, keys, idx, jnp.zeros((6,)), zeros_i, ones)
    np.testing.assert_array_equal(np.asarray(greedy), argmax)
    top1 = sample_tokens(lg, keys, idx, 2.0 * ones,
                         jnp.full((6,), 1, jnp.int32), ones)
    np.testing.assert_array_equal(np.asarray(top1), argmax)
    nucleus = sample_tokens(lg, keys, idx, 2.0 * ones, zeros_i,
                            jnp.full((6,), 1e-9))
    np.testing.assert_array_equal(np.asarray(nucleus), argmax)

    k = 5
    topk = sample_tokens(lg, keys, idx, 5.0 * ones,
                         jnp.full((6,), k, jnp.int32), ones)
    order = np.argsort(-np.asarray(lg), axis=-1)
    for b, t in enumerate(np.asarray(topk)):
        assert t in order[b, :k]

    again = sample_tokens(lg, keys, idx, 5.0 * ones,
                          jnp.full((6,), k, jnp.int32), ones)
    np.testing.assert_array_equal(np.asarray(topk), np.asarray(again))
    other = sample_tokens(lg, keys, idx + 1, 5.0 * ones,
                          jnp.full((6,), k, jnp.int32), ones)
    assert (np.asarray(topk) != np.asarray(other)).any()


# ---------------------------------------------------------------------------
# seeded determinism end to end
# ---------------------------------------------------------------------------

LENS = [3, 5, 7, 9, 11, 4]
SP = SamplingParams(temperature=0.8, top_k=20, top_p=0.95, seed=7)


def test_seeded_streams_deterministic_across_restarts_and_managers():
    """Same seed => identical non-greedy streams from a fresh engine
    (restart) AND across the contiguous vs paged cache managers; a
    different seed diverges; greedy differs from sampled."""
    cfg, params = _setup()
    a = _streams(Engine(params, cfg, slots=3, max_seq=64, sampling=SP),
                 cfg, LENS)
    b = _streams(Engine(params, cfg, slots=3, max_seq=64, sampling=SP),
                 cfg, LENS)
    assert a == b, "engine restart changed seeded streams"
    contig = _streams(
        Engine(params, cfg, slots=3, max_seq=64, sampling=SP,
               cache_manager=CacheConfig(paged=False)), cfg, LENS)
    assert a == contig, "cache-manager layout changed seeded streams"
    other = _streams(
        Engine(params, cfg, slots=3, max_seq=64,
               sampling=SamplingParams(temperature=0.8, top_k=20,
                                       top_p=0.95, seed=8)), cfg, LENS)
    assert a != other, "different seeds must diverge"
    greedy = _streams(Engine(params, cfg, slots=3, max_seq=64), cfg, LENS)
    assert a != greedy


def test_seeded_streams_survive_swap_preemption():
    """Non-greedy + oversubscribed pool: swap preemption restores the key
    state byte-for-byte, so the preempted streams equal the
    never-preempted contiguous streams token for token."""
    cfg, params = _setup()
    lens = [30, 25, 28, 21, 26]
    eng = Engine(params, cfg, slots=3, max_seq=64, sampling=SP,
                 cache_manager=CacheConfig(page_size=16, num_pages=6))
    preempted = _streams(eng, cfg, lens, max_new=20)
    assert eng.stats()["preemptions"] >= 1
    plain = _streams(
        Engine(params, cfg, slots=3, max_seq=64, sampling=SP,
               cache_manager=CacheConfig(paged=False)),
        cfg, lens, max_new=20)
    assert preempted == plain
    eng._pool.check()


# ---------------------------------------------------------------------------
# schedulers
# ---------------------------------------------------------------------------

def test_priority_scheduler_orders_admission():
    """slots=1 serializes the pool, so completion order IS admission
    order: highest priority first, FCFS within a level; the reorder
    counter is exact."""
    cfg, params = _setup()
    eng = Engine(params, cfg, slots=1, max_seq=64, scheduler="priority")
    _streams(eng, cfg, [4, 4, 4], max_new=2, prios=[0, 2, 1])
    assert [r.rid for r in eng.finished] == [1, 2, 0]
    st = eng.stats()
    assert st["scheduler"] == "priority"
    assert st["sched_reorders"] == 2        # rid1 before 0, rid2 before 0
    assert st["sched_admitted"] == 3


def test_sjf_scheduler_orders_by_job_size():
    cfg, params = _setup()
    eng = Engine(params, cfg, slots=1, max_seq=64, scheduler="sjf")
    reqs = _requests(cfg, [12, 4, 8], max_new=2)
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert [r.rid for r in eng.finished] == [1, 2, 0]
    assert eng.stats()["scheduler"] == "sjf"


def test_sorted_scheduler_pops_by_identity():
    """Two waiting requests may share a rid (the engine never enforces
    uniqueness): pop must remove by identity, not dataclass equality —
    comparing the numpy prompt fields raises 'ambiguous truth value'."""
    from repro.serving.scheduler import PriorityScheduler
    sched = PriorityScheduler()
    a = Request(rid=0, prompt=np.array([1, 2, 3], np.int32), arrival=0)
    b = Request(rid=0, prompt=np.array([4, 5, 6], np.int32), arrival=1)
    sched.push(a)
    sched.push(b)
    assert sched.pop() is a and sched.pop() is b and len(sched) == 0


def test_greedy_engine_flips_to_sampling_step_on_demand():
    """A greedy-default engine runs the specialized argmax step until the
    first non-greedy request arrives, then retraces once and serves both
    kinds in the same pool."""
    cfg, params = _setup()
    eng = Engine(params, cfg, slots=2, max_seq=64)
    assert eng._greedy_only
    reqs = _requests(cfg, [5, 6], max_new=3)
    reqs[1].sampling = SP
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert not eng._greedy_only
    assert sorted(r.rid for r in done) == [0, 1]
    assert all(len(r.out_tokens) == 3 for r in done)


def test_fcfs_never_reorders():
    cfg, params = _setup()
    eng = Engine(params, cfg, slots=2, max_seq=64)
    _streams(eng, cfg, [4, 6, 5, 7], max_new=2)
    st = eng.stats()
    assert st["scheduler"] == "fcfs"
    assert st["sched_reorders"] == 0
    with pytest.raises(ValueError):
        Engine(params, cfg, scheduler="lifo")


# ---------------------------------------------------------------------------
# LLMEngine facade
# ---------------------------------------------------------------------------

def test_llm_engine_generate_and_stream_agree():
    cfg, params = _setup()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (n,), dtype=np.int32)
               for n in [4, 7, 5]]
    outs = LLMEngine(params, cfg, slots=2, max_seq=64).generate(
        prompts, SP, max_new_tokens=4)
    assert [o.rid for o in outs] == [0, 1, 2]
    assert all(len(o.tokens) == 4 for o in outs)
    assert all(o.ttft_s is not None and o.ttft_s >= 0 for o in outs)

    events = list(LLMEngine(params, cfg, slots=2, max_seq=64).stream(
        prompts, SP, max_new_tokens=4))
    by_rid = {}
    for ev in events:
        assert ev.index == len(by_rid.setdefault(ev.rid, []))
        by_rid[ev.rid].append(ev.token)
    assert by_rid == {o.rid: o.tokens for o in outs}
    for rid, toks in by_rid.items():
        fin = [ev for ev in events if ev.rid == rid and ev.done]
        assert len(fin) == 1 and fin[0].index == len(toks) - 1


def test_llm_engine_rejects_mismatched_batch_args():
    cfg, params = _setup()
    llm = LLMEngine(params, cfg, slots=2, max_seq=64)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (4,), dtype=np.int32)
               for _ in range(3)]
    with pytest.raises(ValueError):
        llm.generate(prompts, [SP])                     # 1 params, 3 prompts
    with pytest.raises(ValueError):
        llm.generate(prompts, max_new_tokens=[4, 4])    # short list
    with pytest.raises(ValueError):
        llm.generate(prompts, priorities=[1])           # short list


def test_llm_engine_serves_successive_waves():
    cfg, params = _setup()
    llm = LLMEngine(params, cfg, slots=2, max_seq=64)
    rng = np.random.default_rng(1)
    p = [rng.integers(0, cfg.vocab, (5,), dtype=np.int32)]
    first = llm.generate(p, max_new_tokens=3)
    second = llm.generate(p, max_new_tokens=3)
    assert first[0].rid == 0 and second[0].rid == 1
    assert first[0].tokens == second[0].tokens      # same greedy prompt
    # the facade prunes completed waves — a long-lived LLMEngine must not
    # retain every prompt ever served
    assert llm.engine.finished == []


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------

def test_old_engine_kwargs_warn_but_work():
    cfg, params = _setup()
    with pytest.warns(DeprecationWarning):
        eng = Engine(params, cfg, slots=2, max_seq=64, greedy=True)
    assert eng.default_sampling.greedy
    with pytest.warns(DeprecationWarning):
        eng = Engine(params, cfg, slots=2, max_seq=64, greedy=False)
    assert not eng.default_sampling.greedy          # no NotImplementedError
    with pytest.warns(DeprecationWarning):
        eng = Engine(params, cfg, slots=2, max_seq=64, preempt="recompute")
    assert eng.preempt_mode == "recompute"
    with pytest.warns(DeprecationWarning):
        eng = Engine(params, cfg, slots=2, max_seq=64, page_size=16,
                     num_pages=6)
    assert eng.paged and eng.num_pages == 6
    with pytest.raises(ValueError):
        Engine(params, cfg, slots=2, max_seq=64, preemption="drop")


def test_deprecated_greedy_false_produces_sampled_stream():
    cfg, params = _setup()
    with pytest.warns(DeprecationWarning):
        eng = Engine(params, cfg, slots=2, max_seq=64, greedy=False)
    sampled = _streams(eng, cfg, [5, 6], max_new=3)
    greedy = _streams(Engine(params, cfg, slots=2, max_seq=64), cfg,
                      [5, 6], max_new=3)
    assert sorted(sampled) == sorted(greedy)
    assert all(len(v) == 3 for v in sampled.values())


# ---------------------------------------------------------------------------
# non-greedy hot path: still one batched readback per step
# ---------------------------------------------------------------------------

def test_nongreedy_keeps_overlapped_single_readback():
    """Sampling is fused into the donated step: the host applies exactly
    one batched emit per dispatched step (plus nothing extra), and the
    readback of step k stays in flight while step k+1 dispatches."""
    cfg, params = _setup()
    eng = Engine(params, cfg, slots=2, max_seq=64, sampling=SP)
    applies = {"n": 0}
    orig = Engine._apply

    def counting_apply(self, pending):
        applies["n"] += 1
        return orig(self, pending)

    Engine._apply = counting_apply
    try:
        for r in _requests(cfg, [5, 6], max_new=6):
            eng.submit(r)
        overlapped = 0
        while eng.has_work():
            if not eng.step():
                break
            if eng._pending is not None:
                overlapped += 1         # emit still in flight post-dispatch
        eng.flush()
    finally:
        Engine._apply = orig
    assert len(eng.finished) == 2
    assert all(len(r.out_tokens) == 6 for r in eng.finished)
    # one batched apply per dispatched step — sampling added none
    assert applies["n"] == eng.stats()["steps"]
    assert overlapped == eng.stats()["steps"]
