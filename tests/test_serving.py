"""Serving-engine tests: bit-identical token streams vs the host-driven
(pre-refactor) reference engine, slot recycling under ragged admission,
the pow2 prefill retrace bound, paged-pool serving (full subscription,
oversubscription with swap preemption + requeue, recompute mode, per-family
gating), and an engine smoke across all five model families (whose cache
layouts all differ — the scatter is axes-driven)."""

import math

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import registry
from repro.serving.cache_manager import CacheConfig
from repro.serving.engine import Engine, Request
from repro.serving.reference import ReferenceEngine

FAMILY_ARCHS = {
    "dense": "qwen2-0.5b",
    "moe": "olmoe-1b-7b",
    "xlstm": "xlstm-1.3b",
    "hybrid": "recurrentgemma-2b",
    "encdec": "seamless-m4t-large-v2",
}

_PARAMS = {}


def _setup(arch):
    if arch not in _PARAMS:
        cfg = configs.smoke(arch)
        _PARAMS[arch] = (cfg, registry.init(cfg, jax.random.PRNGKey(0))[0])
    return _PARAMS[arch]


def _requests(cfg, lens, *, max_new=5, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for rid, n in enumerate(lens):
        if cfg.frontend == "frames":
            prompt = rng.standard_normal((n, cfg.d_model)).astype(np.float32)
        else:
            prompt = rng.integers(0, cfg.vocab, (n,), dtype=np.int32)
        out.append(Request(rid=rid, prompt=prompt, max_new_tokens=max_new))
    return out


def _streams(engine_cls, cfg, params, lens, **kw):
    eng = engine_cls(params, cfg, slots=kw.pop("slots", 3),
                     max_seq=kw.pop("max_seq", 64))
    for r in _requests(cfg, lens, **kw):
        eng.submit(r)
    done = eng.run()
    return {r.rid: list(r.out_tokens) for r in done}, eng


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "olmoe-1b-7b"])
def test_bit_identical_streams(arch):
    """Device-resident engine == host-driven engine, token for token, on a
    fixed ragged mix — covering the bucketed-pad prefill path (dense) and
    the exact-length path with slot-coupled MoE routing."""
    cfg, params = _setup(arch)
    lens = [3, 5, 7, 9, 11, 4, 6, 13] if cfg.family == "dense" \
        else [4, 6, 9, 5, 7]
    new, _ = _streams(Engine, cfg, params, lens)
    ref, _ = _streams(ReferenceEngine, cfg, params, lens)
    assert new == ref
    assert len(new) == len(lens)


def test_max_seq_stop_matches_reference():
    """The on-device max-seq stop condition fires at the same token index
    as the host engine's."""
    cfg, params = _setup("qwen2-0.5b")
    new, _ = _streams(Engine, cfg, params, [4, 6], max_new=1000,
                      slots=2, max_seq=16)
    ref, _ = _streams(ReferenceEngine, cfg, params, [4, 6], max_new=1000,
                      slots=2, max_seq=16)
    assert new == ref
    assert all(len(v) > 1 for v in new.values())


def test_slot_recycling_ragged():
    """More requests than slots with ragged prompt lengths: every request
    completes with exactly max_new tokens through recycled slots."""
    cfg, params = _setup("qwen2-0.5b")
    lens = [3, 9, 5, 12, 4, 7, 15, 6, 10]
    new, eng = _streams(Engine, cfg, params, lens, max_new=4, slots=2)
    assert sorted(new) == list(range(len(lens)))
    assert all(len(v) == 4 for v in new.values())
    assert all(0 <= t < cfg.vocab for v in new.values() for t in v)
    assert not eng.queue and all(s.req is None for s in eng.slots)


def test_prefill_retrace_bound():
    """8+ distinct prompt lengths must trigger no more prefill compiles
    than the number of pow2 buckets (<= log2(max_seq)+1), strictly fewer
    than the per-unique-length behavior of the host engine."""
    cfg, params = _setup("qwen2-0.5b")
    lens = [3, 4, 5, 7, 9, 12, 17, 23, 29, 31]
    assert len(set(lens)) >= 8
    max_seq = 64
    new, eng = _streams(Engine, cfg, params, lens, max_new=3, slots=3,
                        max_seq=max_seq)
    stats = eng.stats()
    buckets = {1 << max(0, (n - 1).bit_length()) for n in lens}
    assert stats["pad_prefill"]
    assert stats["prefill_compiles"] <= len(buckets)
    assert stats["prefill_compiles"] <= int(math.log2(max_seq)) + 1
    assert stats["prefill_compiles"] < len(set(lens))
    assert len(new) == len(lens)


def test_paged_matches_contiguous_pool():
    """The paged pool (full subscription, no preemption) is a pure layout
    change: token streams equal the contiguous engine's bit-for-bit."""
    cfg, params = _setup("qwen2-0.5b")
    lens = [3, 9, 5, 12, 7]
    paged, eng = _streams(Engine, cfg, params, lens, max_new=4)
    contig, ceng = _streams(
        lambda p, c, **kw: Engine(p, c,
                                  cache_manager=CacheConfig(paged=False),
                                  **kw),
        cfg, params, lens, max_new=4)
    assert eng.stats()["paged"] and not ceng.stats()["paged"]
    assert paged == contig
    assert eng.stats()["preemptions"] == 0


def test_oversubscribed_bit_identical_with_preemption():
    """Oversubscribed pool (requests x lengths > capacity): the engine must
    preempt at least once, swap the victims back in, and still produce
    token streams bit-identical to the never-evicting reference engine."""
    cfg, params = _setup("qwen2-0.5b")
    lens = [30, 25, 28, 21, 26]          # ~130 prompt rows + generation
    kw = dict(max_new=20, slots=3, max_seq=64)
    new, eng = _streams(
        lambda p, c, **k: Engine(
            p, c, cache_manager=CacheConfig(page_size=16, num_pages=6),
            **k),
        cfg, params, lens, **dict(kw))
    ref, _ = _streams(ReferenceEngine, cfg, params, lens, **dict(kw))
    st = eng.stats()
    assert st["paged"] and st["preemptions"] >= 1
    assert st["peak_pages_in_use"] <= 6
    assert new == ref
    eng._pool.check()


def test_forced_preemption_requeue_roundtrip():
    """Minimum-size pool (one full-length slot) under long generations:
    every admission fights for pages, so requests are evicted and swapped
    back repeatedly — streams must survive multiple preemptions of the
    SAME request unchanged."""
    cfg, params = _setup("qwen2-0.5b")
    lens = [20, 17, 23]
    kw = dict(max_new=30, slots=3, max_seq=64)
    new, eng = _streams(
        lambda p, c, **k: Engine(
            p, c, cache_manager=CacheConfig(page_size=16, num_pages=4),
            **k),
        cfg, params, lens, **dict(kw))
    ref, _ = _streams(ReferenceEngine, cfg, params, lens, **dict(kw))
    assert new == ref
    assert eng.stats()["preemptions"] >= 2
    assert max(r.preemptions for r in eng.finished) >= 1
    assert all(r.done for r in eng.finished)
    eng._pool.check()
    # finished requests must release all slot mappings; only radix-cached
    # pages (pinned by the tree alone) may outlive their request
    assert all(not pages for pages in eng._pool.owned), \
        "finished requests must unmap their pages"
    tree_pages = eng.cm.tree.n_pages if eng.cm.prefix_cache else 0
    assert eng._pool.pages_in_use == tree_pages


def test_recompute_preemption_completes():
    """vLLM-style recompute preemption (drop pages, re-prefill the prompt +
    generated prefix): requests complete with exactly the reference token
    counts and keep their pre-eviction prefix. (Token values are only
    greedy-stable, not bit-guaranteed — that is what swap mode is for.)"""
    cfg, params = _setup("qwen2-0.5b")
    lens = [22, 19, 26]
    kw = dict(max_new=25, slots=3, max_seq=64)
    new, eng = _streams(
        lambda p, c, **k: Engine(
            p, c, cache_manager=CacheConfig(page_size=16, num_pages=4),
            preemption="recompute", **k),
        cfg, params, lens, **dict(kw))
    ref, _ = _streams(ReferenceEngine, cfg, params, lens, **dict(kw))
    assert eng.stats()["preemptions"] >= 1
    assert sorted(new) == sorted(ref)
    assert all(len(new[k]) == len(ref[k]) for k in ref)
    eng._pool.check()


def test_paged_gating_per_family():
    """Only PAGED_OK families without a rolling window page; forcing
    paged=True elsewhere is an error, and auto mode falls back."""
    cfg_moe, params_moe = _setup("olmoe-1b-7b")
    assert not registry.paged_ok(cfg_moe)
    eng = Engine(params_moe, cfg_moe, slots=2, max_seq=64)
    assert not eng.stats()["paged"]
    with pytest.raises(ValueError):
        Engine(params_moe, cfg_moe, slots=2, max_seq=64,
               cache_manager=CacheConfig(paged=True))
    cfg_q, params_q = _setup("qwen2-0.5b")
    assert registry.paged_ok(cfg_q)
    with pytest.raises(ValueError):   # page size must tile max_seq
        Engine(params_q, cfg_q, slots=2, max_seq=64,
               cache_manager=CacheConfig(page_size=24))


@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_engine_smoke_all_families(family):
    """Admission scatter + pooled decode across every cache layout:
    positional KV (dense), exact-prefill KV (moe), stacked recurrent
    states (xlstm), mixed KV/recurrent/conv (hybrid), dual self+cross KV
    (encdec)."""
    cfg, params = _setup(FAMILY_ARCHS[family])
    new, eng = _streams(Engine, cfg, params, [5, 8, 6], max_new=3, slots=2)
    assert sorted(new) == [0, 1, 2]
    assert all(len(v) == 3 for v in new.values())
    assert all(0 <= t < cfg.vocab for v in new.values() for t in v)
    assert eng.stats()["steps"] > 0
