"""Docs-consistency gate: every intra-repo reference in README, docs/,
and the sharding/serving module docstrings must point at a real file.

Runs ``tools/check_docs.py`` exactly as the CI docs job does, plus a
negative control proving the checker actually fails on a broken
reference (so a silently-degraded scanner can't pass CI).
"""

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CHECKER = os.path.join(REPO, "tools", "check_docs.py")


def _run(root):
    return subprocess.run(
        [sys.executable, CHECKER, "--root", root, "-v"],
        capture_output=True, text=True, timeout=60)


def test_repo_docs_references_resolve():
    proc = _run(REPO)
    assert proc.returncode == 0, (
        f"broken docs references:\n{proc.stdout}\n{proc.stderr}")
    assert "all intra-repo references resolve" in proc.stdout


def test_checker_fails_on_broken_reference(tmp_path):
    docs = tmp_path / "docs"
    docs.mkdir()
    (tmp_path / "README.md").write_text(
        "See [arch](docs/ARCHITECTURE.md) and `src/repro/gone.py`.\n")
    (docs / "ARCHITECTURE.md").write_text(
        "Back to [readme](../README.md), plus a dead "
        "[link](MISSING.md).\n")
    proc = _run(str(tmp_path))
    assert proc.returncode == 1, proc.stdout
    assert "src/repro/gone.py" in proc.stdout
    assert "MISSING.md" in proc.stdout


def test_checker_passes_on_clean_tree(tmp_path):
    docs = tmp_path / "docs"
    docs.mkdir()
    (tmp_path / "README.md").write_text(
        "See [arch](docs/ARCHITECTURE.md).\n")
    (docs / "ARCHITECTURE.md").write_text(
        "Back to [readme](../README.md).\n")
    proc = _run(str(tmp_path))
    assert proc.returncode == 0, proc.stdout
