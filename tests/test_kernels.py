"""Per-kernel correctness: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes.

Inputs and oracle outputs are generated once per (kernel, shape, dtype)
and shared across the variant axis through a module-scoped cache — the
interpret-mode Pallas run is the thing under test; regenerating identical
oracles per variant was pure overhead. The heaviest interpret-mode cases
carry ``@pytest.mark.slow`` (excluded from the tier-1 run, see pytest.ini).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (flash_decode as fd, fused_add_rmsnorm as rms,
                           merge_attn_states as mrg, ops, ref,
                           silu_and_mul as silu)

F32, BF16 = jnp.float32, jnp.bfloat16

def tol(dtype):
    return dict(rtol=3e-2, atol=3e-2) if dtype == BF16 \
        else dict(rtol=1e-5, atol=1e-4)


def allclose(a, b, dtype):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), **tol(dtype))


@pytest.fixture(scope="module")
def case_cache():
    """Shared (kernel, shape, dtype) -> (inputs, oracle) memo."""
    return {}


def _memo(cache, key, build):
    if key not in cache:
        cache[key] = build()
    return cache[key]


SILU_SHAPES = [(1, 128), (16, 4096), (33, 5120), (7, 256), (128, 11008)]


@pytest.mark.parametrize("dtype", [F32, BF16])
@pytest.mark.parametrize("shape", SILU_SHAPES)
@pytest.mark.parametrize("variant", [silu.BASELINE, silu.OPTIMIZED,
                                     silu.SiluMulVariant(block_rows=8,
                                                         fast_exp=True)])
def test_silu_and_mul(shape, dtype, variant, case_cache):
    def build():
        x = jax.random.normal(jax.random.PRNGKey(0),
                              (shape[0], 2 * shape[1]), dtype) * 3
        return x, ref.silu_and_mul(x)
    x, want = _memo(case_cache, ("silu", shape, str(dtype)), build)
    got = silu.silu_and_mul(x, variant, interpret=True)
    allclose(got, want, dtype)


RMS_SHAPES = [(1, 128), (256, 4096), (33, 5120), (512, 14336)]


@pytest.mark.parametrize("dtype", [F32, BF16])
@pytest.mark.parametrize("shape", RMS_SHAPES)
@pytest.mark.parametrize("variant", [rms.BASELINE, rms.OPTIMIZED])
def test_fused_add_rmsnorm(shape, dtype, variant, case_cache):
    def build():
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        x = jax.random.normal(ks[0], shape, dtype)
        r = jax.random.normal(ks[1], shape, dtype)
        w = (1 + 0.1 * jax.random.normal(ks[2], (shape[1],))).astype(dtype)
        return (x, r, w), ref.fused_add_rmsnorm(x, r, w)
    (x, r, w), (wy, wr) = _memo(case_cache, ("rms", shape, str(dtype)), build)
    y, ro = rms.fused_add_rmsnorm(x, r, w, variant=variant, interpret=True)
    allclose(y, wy, dtype)
    allclose(ro, wr, dtype)


MERGE_SHAPES = [(17, 1, 128), (512, 32, 256), (100, 7, 128),
                pytest.param((512, 64, 128), marks=pytest.mark.slow)]


@pytest.mark.parametrize("dtype", [F32, BF16])
@pytest.mark.parametrize("shape", MERGE_SHAPES)
@pytest.mark.parametrize("variant", [mrg.BASELINE, mrg.OPTIMIZED,
                                     mrg.MergeVariant(fuse_s_out=False)])
def test_merge_attn_states(shape, dtype, variant, case_cache):
    s, h, d = shape

    def build():
        ks = jax.random.split(jax.random.PRNGKey(2), 5)
        va = jax.random.normal(ks[0], (s, h, d), dtype)
        vb = jax.random.normal(ks[1], (s, h, d), dtype)
        sa = jax.random.normal(ks[2], (s, h)) * 8
        sb = jax.random.normal(ks[3], (s, h)) * 8
        sb = jnp.where(jax.random.uniform(ks[4], (s, h)) < 0.1, -jnp.inf, sb)
        return (va, sa, vb, sb), ref.merge_attn_states_lse(va, sa, vb, sb)
    (va, sa, vb, sb), (wv, ws) = _memo(case_cache,
                                       ("merge", shape, str(dtype)), build)
    vo, so = mrg.merge_attn_states_lse(va, sa, vb, sb, variant,
                                       interpret=True)
    allclose(vo, wv, dtype)
    np.testing.assert_allclose(np.asarray(so), np.asarray(ws),
                               rtol=1e-5, atol=1e-5)


FLASH_SHAPES = [  # (b, hq, hkv, dh, s)
    (1, 8, 8, 64, 257),
    pytest.param((3, 14, 2, 128, 1000), marks=pytest.mark.slow),
    pytest.param((2, 16, 4, 64, 2048), marks=pytest.mark.slow)]


@pytest.mark.parametrize("dtype", [F32, BF16])
@pytest.mark.parametrize("shape", FLASH_SHAPES)
@pytest.mark.parametrize("variant", [fd.BASELINE, fd.OPTIMIZED])
def test_flash_decode(shape, dtype, variant, case_cache):
    b, hq, hkv, dh, s = shape

    def build():
        ks = jax.random.split(jax.random.PRNGKey(3), 4)
        q = jax.random.normal(ks[0], (b, hq, dh), dtype)
        k = jax.random.normal(ks[1], (b, s, hkv, dh), dtype)
        v = jax.random.normal(ks[2], (b, s, hkv, dh), dtype)
        kv_len = jax.random.randint(ks[3], (b,), 1, s + 1)
        return ((q, k, v, kv_len),
                ref.flash_decode_attention(q, k, v, kv_len=kv_len))
    (q, k, v, kv_len), want = _memo(case_cache,
                                    ("flash", shape, str(dtype)), build)
    got = fd.flash_decode_attention(q, k, v, kv_len=kv_len, variant=variant,
                                    interpret=True)
    allclose(got, want, dtype)


PAGED_FLASH_SHAPES = [  # (b, hq, hkv, dh, s)
    (2, 8, 2, 64, 256),
    pytest.param((3, 12, 4, 64, 512), marks=pytest.mark.slow)]


@pytest.mark.parametrize("dtype", [F32, BF16])
@pytest.mark.parametrize("shape", PAGED_FLASH_SHAPES)
@pytest.mark.parametrize("variant", [fd.PAGED_BASELINE, fd.PAGED_OPTIMIZED,
                                     fd.PagedFlashDecodeVariant(
                                         page_size=32, mask_oob=True)])
def test_paged_flash_decode(shape, dtype, variant, case_cache):
    """The paged kernel gathers K/V through a shuffled page table yet must
    reproduce contiguous decode attention (the space's oracle)."""
    b, hq, hkv, dh, s = shape

    def build():
        ks = jax.random.split(jax.random.PRNGKey(6), 4)
        q = jax.random.normal(ks[0], (b, hq, dh), dtype)
        k = jax.random.normal(ks[1], (b, s, hkv, dh), dtype)
        v = jax.random.normal(ks[2], (b, s, hkv, dh), dtype)
        kv_len = jax.random.randint(ks[3], (b,), 1, s + 1)
        return ((q, k, v, kv_len),
                ref.flash_decode_attention(q, k, v, kv_len=kv_len))
    (q, k, v, kv_len), want = _memo(case_cache,
                                    ("paged_flash", shape, str(dtype)), build)
    got = fd._paged_run(variant, q, k, v, kv_len, interpret=True)
    allclose(got, want, dtype)


def test_paged_ref_gather_is_bitwise_contiguous():
    """ops CPU dispatch: gathering pages through the table then attending
    must be BITWISE equal to contiguous attention — the serving engine's
    stream equivalence rests on this."""
    b, hq, hkv, dh, s = 2, 8, 2, 64, 128
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    q = jax.random.normal(ks[0], (b, hq, dh))
    k = jax.random.normal(ks[1], (b, s, hkv, dh))
    v = jax.random.normal(ks[2], (b, s, hkv, dh))
    kv_len = jnp.array([100, 37])
    k_pages, v_pages, table = fd._page_kv(k, v, 16)
    got = ops.paged_flash_decode_attention(q, k_pages, v_pages, table,
                                           kv_len=kv_len)
    want = ref.flash_decode_attention(q, k, v, kv_len=kv_len)
    assert bool(jnp.all(got == want))


def test_split_kv_merge_identity():
    """Distributed split-KV invariant: merging per-shard partial states with
    Kernel 1 equals attention over the whole cache."""
    b, hq, hkv, dh, s = 2, 8, 2, 64, 512
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    q = jax.random.normal(ks[0], (b, hq, dh))
    k = jax.random.normal(ks[1], (b, s, hkv, dh))
    v = jax.random.normal(ks[2], (b, s, hkv, dh))
    kv_len = jnp.array([400, 150])
    want = ref.flash_decode_attention(q, k, v, kv_len=kv_len)
    half = s // 2
    l1 = jnp.minimum(kv_len, half)
    l2 = jnp.maximum(kv_len - half, 0)
    o1 = ref.flash_decode_attention(q, k[:, :half], v[:, :half], kv_len=l1)
    s1 = ref.flash_decode_lse(q, k[:, :half], kv_len=l1)
    o2 = ref.flash_decode_attention(q, k[:, half:], v[:, half:], kv_len=l2)
    s2 = ref.flash_decode_lse(q, k[:, half:], kv_len=l2)
    o2 = jnp.where(jnp.isneginf(s2)[..., None], 0.0, o2)
    om, sm = ref.merge_attn_states_lse(o1, s1, o2, s2)
    np.testing.assert_allclose(np.asarray(om), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ops_dispatch_and_reintegration():
    """ops.* dispatches to ref on CPU; set_variants installs tuned kernels
    (the paper's post-processing)."""
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 512))
    np.testing.assert_allclose(np.asarray(ops.silu_and_mul(x)),
                               np.asarray(ref.silu_and_mul(x)), rtol=1e-6)
    old = ops.get_variant("silu_and_mul")
    try:
        tuned = silu.SiluMulVariant(name="tuned", block_rows=64)
        ops.set_variants(silu_and_mul=tuned)
        assert ops.get_variant("silu_and_mul").name == "tuned"
        y = ops.silu_and_mul(x, impl="pallas")
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(ref.silu_and_mul(x)),
                                   rtol=1e-5, atol=1e-5)
    finally:
        ops.set_variants(silu_and_mul=old)
    with pytest.raises(KeyError):
        ops.set_variants(nonexistent_kernel=None)
