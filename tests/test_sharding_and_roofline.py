"""Sharding rules, HLO parser, roofline arithmetic, serving engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.roofline import analysis
from repro.roofline.hlo_parser import Module, _shape_elems_bytes
from repro.sharding import rules


@pytest.fixture(scope="module")
def mesh16():
    # 1 real device: build an abstract 4x4 mesh over fake ids is not
    # possible; use a 1x1 mesh for API checks and a fake-device mesh for
    # rule checks via mesh shape introspection only.
    return jax.make_mesh((1, 1), ("data", "model"))


def test_spec_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # with axis size 1 everything divides; exercise the structure
    s = rules.spec_for(("embed", "heads", "head_dim"), (896, 14, 64), mesh)
    assert isinstance(s, P)


class _FakeMesh:
    """Shape-only mesh stand-in for rule arithmetic."""
    def __init__(self, shape, names):
        self.axis_names = names
        import numpy as np
        self.devices = np.zeros(shape)
        self.shape = dict(zip(names, shape))


def test_rules_respect_divisibility():
    mesh = _FakeMesh((16, 16), ("data", "model"))
    # 14 heads don't divide 16 -> replicated; 64000 vocab does -> sharded
    assert rules._resolve("heads", 14, mesh) is None
    assert rules._resolve("heads", 32, mesh) == "model"
    assert rules._resolve("vocab", 64000, mesh) == "model"
    assert rules._resolve("embed", 896, mesh) == "data"
    assert rules._resolve("embed_vocab", 152064, mesh) is None


def test_rules_multipod_batch():
    mesh = _FakeMesh((2, 16, 16), ("pod", "data", "model"))
    assert rules._resolve("batch", 256, mesh) == ("pod", "data")
    assert rules._resolve("batch", 16, mesh) == "data"  # 16 % 32 != 0


# Real-mesh rule checks need >1 device, and the in-process jax backend is
# already initialized single-CPU — so they run in a subprocess that sets
# --xla_force_host_platform_device_count before importing jax.
_MESH_RULES_SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4")
import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.sharding import batch_spec, rules, sharding_for, spec_for, tp

m22 = jax.make_mesh((2, 2), ("data", "model"))
m14 = jax.make_mesh((1, 4), ("data", "model"))
pod = jax.make_mesh((2, 2, 1), ("pod", "data", "model"))

# logical -> physical resolution on a real mesh
qwen2 = configs.get("qwen2-0.5b")     # 14 heads, 2 kv heads, d_ff 4864
yi = configs.get("yi-34b")            # 56 heads, 8 kv heads
assert qwen2.n_heads == 14 and yi.n_heads == 56

# non-divisible-axis replication fallback: qwen2's 14 heads on a 4-way
# model axis replicate; yi's 56 shard; both shard on a 2-way axis
assert rules._resolve("heads", qwen2.n_heads, m14) is None
assert rules._resolve("heads", qwen2.n_heads, m22) == "model"
assert rules._resolve("heads", yi.n_heads, m14) == "model"
assert rules._resolve("kv_heads", yi.n_kv_heads, m14) == "model"
# the fallback keeps MLP/vocab sharded
assert rules._resolve("mlp", qwen2.d_ff, m14) == "model"
assert rules._resolve("vocab", qwen2.padded_vocab, m14) == "model"

# spec_for: per-axis resolution with one-mesh-axis-at-most-once dedup
assert spec_for(("embed", "heads", "head_dim"),
                (qwen2.d_model, 14, 64), m14) == P("data", None, None)
assert spec_for(("embed", "heads", "head_dim"),
                (yi.d_model, 56, 128), m14) == P("data", "model", None)

# pod+data composition on the multi-pod mesh (pod*data = 4 here)
assert rules._resolve("batch", 8, pod) == ("pod", "data")
assert rules._resolve("batch", 2, pod) == "data"     # 2 % 4 != 0
assert batch_spec(pod, None) == P(("pod", "data"), None)
assert batch_spec(m22, None) == P("data", None)

# sharding_for round-trips through a real device_put
x = jax.numpy.zeros((yi.d_model, 56, 128))
s = sharding_for(("embed", "heads", "head_dim"), x.shape, m14)
assert isinstance(s, NamedSharding)
xs = jax.device_put(x, s)
assert xs.sharding.spec == P("data", "model", None)

# the serving plan resolves through the same rules
plan = tp.make_plan(configs.smoke("qwen3-8b"), m22, slots=4)
assert plan.describe() == {"data": 2, "model": 2, "heads_tp": True,
                           "mlp_tp": True, "vocab_tp": True,
                           "batch_dp": True}
plan = tp.make_plan(configs.smoke("qwen2-0.5b"), m14, slots=4)
assert plan.describe() == {"data": 1, "model": 4, "heads_tp": False,
                           "mlp_tp": True, "vocab_tp": True,
                           "batch_dp": False}
print("MESH_RULES_OK")
"""


def test_rules_on_real_forced_host_mesh():
    import os
    import subprocess
    import sys
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _MESH_RULES_SCRIPT],
                          env=env, cwd=repo, capture_output=True,
                          text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr
    assert "MESH_RULES_OK" in proc.stdout


def test_tree_shardings_structure(mesh16):
    params = {"a": jnp.zeros((8, 4)), "b": {"c": jnp.zeros((4,))}}
    axes = {"a": ("embed", "mlp"), "b": {"c": ("embed",)}}
    sh = rules.tree_shardings(params, axes, mesh16)
    assert jax.tree.structure(sh) == jax.tree.structure(params)


# ---------------------------------------------------------------------------
# HLO parser
# ---------------------------------------------------------------------------

_TOY = """
HloModule toy

%body (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %ag = f32[128,256]{1,0} all-gather(%gte), replica_groups={}
  %dot.1 = f32[128,128]{1,0} dot(%ag, %ag), lhs_contracting_dims={1}, rhs_contracting_dims={1}
  ROOT %t = (s32[], f32[128,256]) tuple(%c, %ag)
}

%cond (p: (s32[], f32[128,256])) -> pred[] {
  %p = (s32[], f32[128,256]) parameter(0)
  ROOT %cmp = pred[] compare(%gte, %k), direction=LT
}

ENTRY %main (x: f32[128,256]) -> f32[128,256] {
  %x = f32[128,256]{1,0} parameter(0)
  %w = (s32[], f32[128,256]) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
  %ar = f32[128,256]{1,0} all-reduce(%x), to_apply=%sum
  ROOT %out = f32[128,256]{1,0} copy(%x)
}
"""


def test_hlo_parser_trip_counts_and_collectives():
    m = Module(_TOY)
    c = m.entry_cost()
    ag_bytes = 128 * 256 * 4
    ar_bytes = 128 * 256 * 4 * 2.0          # ring all-reduce factor
    assert c["coll"]["all-gather"] == pytest.approx(12 * ag_bytes)
    assert c["coll"]["all-reduce"] == pytest.approx(ar_bytes)
    # dot: 2 * 128*128 out * 256 contract, counted x12 trips
    assert c["flops"] == pytest.approx(12 * 2 * 128 * 128 * 256, rel=0.01)


def test_shape_parsing():
    assert _shape_elems_bytes("f32[128,256]{1,0}") == (128 * 256,
                                                       128 * 256 * 4)
    e, b = _shape_elems_bytes("(bf16[8,4], pred[16])")
    assert e == 32 + 16 and b == 64 + 16


def test_flash_scope_traffic_is_skipped():
    hlo = """
ENTRY %main (x: f32[1024,1024]) -> f32[1024,1024] {
  %x = f32[1024,1024]{1,0} parameter(0)
  %big = f32[1024,1024]{1,0} copy(%x), metadata={op_name="jit(f)/flash_kernel/softmax"}
  ROOT %o = f32[1024,1024]{1,0} copy(%big)
}
"""
    c = Module(hlo).entry_cost()
    assert c["traffic"] == pytest.approx(2 * 1024 * 1024 * 4)  # ROOT only


# ---------------------------------------------------------------------------
# roofline arithmetic
# ---------------------------------------------------------------------------

def test_roofline_terms_and_dominance():
    r = analysis.Roofline(
        arch="x", shape="train_4k", mesh="16x16", chips=256,
        flops_per_chip=197e12 * 0.010,          # 10 ms of compute
        bytes_per_chip=819e9 * 0.002,           # 2 ms of HBM
        coll_bytes_per_chip=50e9 * 0.020,       # 20 ms of ICI
        coll_breakdown={}, model_flops_global=197e12 * 0.010 * 256 * 0.5,
        peak_memory_per_chip=8 * 2**30)
    assert r.compute_s == pytest.approx(0.010)
    assert r.memory_s == pytest.approx(0.002)
    assert r.collective_s == pytest.approx(0.020)
    assert r.dominant == "collective"
    assert r.step_time_s == pytest.approx(0.020)
    assert r.useful_ratio == pytest.approx(0.5)
    assert r.mfu == pytest.approx(0.010 * 0.5 / 0.020)


def test_model_flops_accounting():
    cfg = [c for c in [__import__("repro.configs", fromlist=["get"])
           .get("olmoe-1b-7b")]][0]
    n = cfg.activated_params
    assert analysis.model_flops(cfg, "train", 1000) == 6.0 * n * 1000
    assert analysis.model_flops(cfg, "decode", 128) == 2.0 * n * 128
    # MoE activated params exclude inactive experts
    dense_equiv = cfg.n_layers * 3 * cfg.d_model * cfg.expert_ff \
        * cfg.n_experts
    active = cfg.n_layers * 3 * cfg.d_model * cfg.expert_ff * cfg.top_k
    assert n < dense_equiv
    assert n > active


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

def test_engine_continuous_batching():
    from repro.serving.engine import Engine, Request
    cfg = __import__("repro.configs", fromlist=["smoke"]).smoke("qwen2-0.5b")
    from repro.models import registry
    params, _ = registry.init(cfg, jax.random.PRNGKey(0))
    eng = Engine(params, cfg, slots=2, max_seq=64)
    rng = np.random.default_rng(0)
    for rid in range(4):
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab, (8,),
                                               dtype=np.int32),
                           max_new_tokens=4))
    done = eng.run(max_steps=200)
    assert len(done) == 4
    for r in done:
        assert len(r.out_tokens) == 4
        assert all(0 <= t < cfg.padded_vocab for t in r.out_tokens)
