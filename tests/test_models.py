"""Per-architecture smoke tests: reduced same-family configs, one
forward/train step on CPU, shape + finiteness assertions, and the
prefill/decode consistency invariant."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import registry

ARCHS = list(configs.ARCH_IDS)


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch, rng):
    """One forward+backward on the reduced config: finite loss & grads,
    correct logits shape."""
    cfg = configs.smoke(arch)
    params, axes = registry.init(cfg, rng)
    # axes tree mirrors params structure
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda x: isinstance(x, tuple))
    batch = registry.make_batch(cfg, 2, 32, rng)
    loss, grads = jax.value_and_grad(
        lambda p: registry.loss_fn(p, cfg, batch))(params)
    assert jnp.isfinite(loss)
    for g in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(g, np.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_matches_forward(arch, rng):
    """prefill(tokens) last-position logits == teacher-forced forward's."""
    cfg = configs.smoke(arch)
    params, _ = registry.init(cfg, rng)
    batch = registry.make_batch(cfg, 2, 16, rng)
    mod = registry.module_for(cfg)
    if cfg.family == "encdec":
        pytest.skip("enc-dec prefill returns BOS logits, not last-position")
    logits_fwd = mod.forward(params, cfg, batch["tokens"])
    logits_pre, _ = registry.prefill(params, cfg, batch["tokens"])
    np.testing.assert_allclose(
        np.asarray(logits_pre, np.float32),
        np.asarray(logits_fwd[:, -1], np.float32), rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch, rng):
    """decode_step over a prefilled cache reproduces the full forward's
    next-position logits — the KV-cache/state correctness invariant."""
    cfg = configs.smoke(arch)
    if cfg.family == "encdec":
        pytest.skip("enc-dec decode consistency covered in its own test")
    import dataclasses
    if cfg.family == "moe":
        # capacity-based routing drops depend on the token grouping, which
        # differs between teacher-forced and decode; remove drops so the
        # invariant isolates CACHE correctness.
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    if cfg.family == "xlstm":
        # bf16 noise through 48 recurrent steps swamps the tolerance; the
        # state math is exact (<1e-6) in fp32.
        cfg = dataclasses.replace(cfg, dtype="float32")
    params, _ = registry.init(cfg, rng)
    t = 12
    batch = registry.make_batch(cfg, 2, t + 1, rng)
    tokens = batch["tokens"]
    mod = registry.module_for(cfg)
    logits_fwd = mod.forward(params, cfg, tokens)        # [B, t+1, V]
    _, cache = registry.prefill(params, cfg, tokens[:, :t],
                                cache_len=t + 1)
    pos = jnp.full((2,), t, jnp.int32)
    logits_dec, _ = registry.decode_step(params, cfg, cache,
                                         tokens[:, t], pos)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_fwd[:, t], np.float32), rtol=5e-2, atol=5e-2)


def test_encdec_decode_consistency(rng):
    """Seamless: two sequential decode_steps shift positions correctly."""
    cfg = configs.smoke("seamless-m4t-large-v2")
    params, _ = registry.init(cfg, rng)
    batch = registry.make_batch(cfg, 2, 16, rng)
    logits, cache = registry.prefill(params, cfg, batch["frames"])
    assert logits.shape == (2, cfg.padded_vocab)
    tok = jnp.argmax(logits[:, :cfg.vocab], -1).astype(jnp.int32)
    l2, cache = registry.decode_step(params, cfg, cache, tok,
                                     jnp.ones((2,), jnp.int32))
    assert np.all(np.isfinite(np.asarray(l2, np.float32)))


def test_sliding_window_limits_context(rng):
    """Sliding-window arch: tokens beyond the RECEPTIVE FIELD cannot
    influence the output. Stacked window layers widen the field by one
    window per layer (Mistral's long-context mechanism), so the strict
    single-window property is tested with one layer."""
    import dataclasses
    cfg = dataclasses.replace(configs.smoke("h2o-danube-1.8b"), n_layers=1)
    assert cfg.window == 64
    params, _ = registry.init(cfg, rng)
    mod = registry.module_for(cfg)
    s = 80
    tokens = registry.make_batch(cfg, 1, s, rng)["tokens"]
    # perturb a token far outside the last position's window
    tokens2 = tokens.at[0, 2].set((tokens[0, 2] + 1) % cfg.vocab)
    l1 = mod.forward(params, cfg, tokens)[:, -1]
    l2 = mod.forward(params, cfg, tokens2)[:, -1]
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               rtol=1e-4, atol=1e-4)


def test_xlstm_state_is_constant_size(rng):
    """The recurrent 'cache' does not grow with sequence length."""
    cfg = configs.smoke("xlstm-1.3b")
    spec_short, _ = registry.cache_spec(cfg, 2, 128)
    spec_long, _ = registry.cache_spec(cfg, 2, 524288)
    for a, b in zip(jax.tree.leaves(spec_short), jax.tree.leaves(spec_long)):
        assert a.shape == b.shape


def test_moe_capacity_and_routing(rng):
    """MoE block preserves shape; capacity drops are bounded."""
    from repro.models import moe
    cfg = configs.smoke("olmoe-1b-7b")
    params, _ = registry.init(cfg, rng)
    p0 = jax.tree.map(lambda t: t[0], params["layers"])
    x = jax.random.normal(rng, (2, 32, cfg.d_model), cfg.jnp_dtype)
    y = moe.moe_block(p0, x, cfg)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y, np.float32)))
    assert moe.capacity(cfg, 64) >= cfg.top_k


def test_long_context_gating():
    ok = {a for a in ARCHS if configs.long_context_ok(configs.get(a))}
    assert ok == {"h2o-danube-1.8b", "xlstm-1.3b", "recurrentgemma-2b"}
